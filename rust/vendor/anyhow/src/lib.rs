//! Minimal offline drop-in for the [`anyhow`](https://crates.io/crates/anyhow)
//! error crate.
//!
//! This build runs without network access, so instead of the crates.io
//! dependency the workspace vendors the small slice of the `anyhow` surface
//! the codebase actually uses:
//!
//! * [`Error`] — an opaque, `Display`-able error value,
//! * [`Result<T>`](Result) — `std::result::Result<T, Error>`,
//! * [`anyhow!`] / [`ensure!`] — ad-hoc error construction macros,
//! * [`Context`] — `.context(...)` / `.with_context(...)` adapters.
//!
//! Error messages are flattened into a single string at construction time
//! (context is prepended `"{context}: {cause}"`), which matches how every
//! call site in this repository formats and prints errors.

use std::fmt;

/// An opaque error: a rendered message, optionally chained onto a cause.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context line (used by the [`Context`] adapters).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `Result` specialized to [`Error`], mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error as it propagates (mirrors `anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an ad-hoc error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 7;
        let b = anyhow!("value {n}");
        assert_eq!(b.to_string(), "value 7");
        let c = anyhow!("value {}", n);
        assert_eq!(c.to_string(), "value 7");
        let d = anyhow!(io_err());
        assert_eq!(d.to_string(), "gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let r: std::result::Result<u32, std::io::Error> = Err(io_err());
            Ok(r?)
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e2.to_string(), "step 3: inner");
    }

    #[test]
    fn ensure_returns_err() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
    }
}
