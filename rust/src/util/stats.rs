//! Summary statistics over experiment repetitions.
//!
//! The paper reports averages and standard deviations over 4–20 runs per
//! configuration; [`Summary`] is the container every experiment in
//! [`crate::bench`] reports through.

/// Online (Welford) accumulator plus retained samples for percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    /// Build from an iterator of samples.
    pub fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut s = Summary::new();
        for v in iter {
            s.add(v);
        }
        s
    }

    /// Record one sample.
    pub fn add(&mut self, v: f64) {
        debug_assert!(v.is_finite(), "non-finite sample {v}");
        self.samples.push(v);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n−1); 0.0 for fewer than two samples.
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    /// Minimum sample; 0.0 when empty.
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min).min_or_zero()
    }

    /// Maximum sample; 0.0 when empty.
    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
            .max_or_zero()
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`; 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    /// Median (p50).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// All samples, in insertion order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// `mean ± stddev` rendered for reports, e.g. `"12.34 ± 0.56"`.
    pub fn display(&self) -> String {
        format!("{:.2} ± {:.2}", self.mean(), self.stddev())
    }
}

trait OrZero {
    fn min_or_zero(self) -> f64;
    fn max_or_zero(self) -> f64;
}

impl OrZero for f64 {
    fn min_or_zero(self) -> f64 {
        if self.is_finite() {
            self
        } else {
            0.0
        }
    }
    fn max_or_zero(self) -> f64 {
        if self.is_finite() {
            self
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample stddev of this classic set is ~2.138
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.percentile(90.0), 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_iter([3.5]);
        assert_eq!(s.mean(), 3.5);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 3.5);
        assert_eq!(s.min(), 3.5);
        assert_eq!(s.max(), 3.5);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::from_iter([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let s = Summary::from_iter([5.0, -1.0, 3.0]);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 5.0);
    }
}
