//! Plain-text table rendering for experiment reports.
//!
//! Every figure/table reproduction in [`crate::bench`] renders through
//! this so `woss experiment <id>` output looks like the paper's tables.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title line.
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    /// Set the header row.
    pub fn header<S: Into<String>, I: IntoIterator<Item = S>>(mut self, cols: I) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    /// Append a data row.
    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cols: I) {
        self.rows.push(cols.into_iter().map(Into::into).collect());
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the table.
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let measure = |row: &[String], widths: &mut [usize]| {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        };
        measure(&self.header, &mut widths);
        for row in &self.rows {
            measure(row, &mut widths);
        }

        let render_row = |row: &[String]| -> String {
            let cells: Vec<String> = (0..ncols)
                .map(|i| {
                    let cell = row.get(i).map(String::as_str).unwrap_or("");
                    format!("{:<width$}", cell, width = widths[i])
                })
                .collect();
            format!("| {} |", cells.join(" | "))
        };

        let sep = format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );

        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        if !self.header.is_empty() {
            out.push_str(&render_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&render_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format seconds human-readably (`1.2 s`, `830 ms`).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.1} s")
    } else if s >= 1e-3 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Format a byte count (`1.8 GB`, `204 KB`).
pub fn fmt_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.1} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.1} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig X").header(["system", "runtime (s)"]);
        t.row(["NFS", "320.0"]);
        t.row(["WOSS-RAM", "31.5"]);
        let out = t.render();
        assert!(out.contains("## Fig X"));
        assert!(out.contains("| NFS      | 320.0       |"));
        assert!(out.lines().count() == 5);
    }

    #[test]
    fn ragged_rows_padded() {
        let mut t = Table::new("").header(["a", "b", "c"]);
        t.row(["1"]);
        let out = t.render();
        assert!(out.contains("| 1 |   |   |"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(2.5), "2.5 s");
        assert_eq!(fmt_secs(0.05), "50.0 ms");
        assert_eq!(fmt_secs(2e-5), "20.0 µs");
        assert_eq!(fmt_bytes(1024), "1.0 KB");
        assert_eq!(fmt_bytes(1_887_436_800), "1.8 GB");
        assert_eq!(fmt_bytes(100), "100 B");
    }
}
