//! In-tree substrates. This build is fully offline (no crates.io
//! access), so the small library pieces a project would normally pull
//! from crates.io — deterministic RNG, statistics, a CLI parser, a JSON
//! emitter, table rendering, a property-testing harness — are
//! implemented here.

pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use stats::Summary;
