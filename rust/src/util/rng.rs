//! Deterministic pseudo-random number generation.
//!
//! The simulator must be reproducible run-to-run (experiments are averaged
//! over seeded repetitions, like the paper's 20-run averages), so we use a
//! self-contained xoshiro256** generator seeded via SplitMix64 rather than
//! OS entropy.

/// xoshiro256** PRNG (Blackman & Vigna). Deterministic, 64-bit output,
/// period 2^256 − 1. Not cryptographic — simulation only.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid:
    /// the state is expanded with SplitMix64 so it is never all-zero.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next_sm(), next_sm(), next_sm(), next_sm()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`. Uses Lemire rejection to
    /// avoid modulo bias.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range_usize(0, items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Derive an independent child generator (for parallel experiment
    /// repetitions that must not share a stream).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Lognormal-ish service-time jitter: multiply a base duration by a
    /// factor in `[1-spread, 1+spread]`. The paper reports run-to-run
    /// variance; this is how repetitions differ.
    pub fn jitter(&mut self, base: f64, spread: f64) -> f64 {
        base * self.range_f64(1.0 - spread, 1.0 + spread)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(11);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn jitter_within_spread() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let v = r.jitter(10.0, 0.05);
            assert!((9.5..=10.5).contains(&v));
        }
    }
}
