//! Property-based testing harness (offline substitute for `proptest`).
//!
//! Coordinator invariants (routing, batching, placement, scheduler state)
//! are checked with randomized cases generated from a seeded [`Rng`], with
//! greedy input shrinking on failure. Set `WOSS_PROP_SEED` to replay a
//! failing seed and `WOSS_PROP_CASES` to change the case count.

use super::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Number of cases per property (default 256, env-overridable).
pub fn cases() -> usize {
    std::env::var("WOSS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Default seed: ASCII "WOSS 13".
const DEFAULT_SEED: u64 = 0x57_4F_53_53_20_31_33;

fn base_seed() -> u64 {
    std::env::var("WOSS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

/// Run `prop` against `cases()` values produced by `gen`. On failure,
/// greedily shrink via `shrink` and panic with the minimal failing input.
pub fn forall<T, G, S, P>(name: &str, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool + std::panic::RefUnwindSafe,
{
    let seed = base_seed();
    let n = cases();
    for case in 0..n {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9));
        let input = gen(&mut rng);
        if !holds(&prop, &input) {
            let minimal = shrink_loop(input, &shrink, &prop);
            panic!(
                "property '{name}' failed (seed={seed}, case={case}).\n\
                 minimal failing input: {minimal:#?}\n\
                 replay with WOSS_PROP_SEED={seed}"
            );
        }
    }
}

/// Like [`forall`], without shrinking.
pub fn forall_noshrink<T, G, P>(name: &str, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool + std::panic::RefUnwindSafe,
{
    forall(name, gen, |_| Vec::new(), prop);
}

fn holds<T, P: Fn(&T) -> bool + std::panic::RefUnwindSafe>(prop: &P, input: &T) -> bool {
    catch_unwind(AssertUnwindSafe(|| prop(input))).unwrap_or(false)
}

fn shrink_loop<T, S, P>(mut failing: T, shrink: &S, prop: &P) -> T
where
    T: Clone + std::fmt::Debug,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool + std::panic::RefUnwindSafe,
{
    // Greedy descent: take the first shrink candidate that still fails,
    // repeat until no candidate fails. Bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in shrink(&failing) {
            if !holds(prop, &cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

/// Shrink helper for vectors: halves, and single-element removals.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 16 {
        for i in 0..v.len() {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Shrink helper for unsigned integers: 0, halves, decrement.
pub fn shrink_u64(v: &u64) -> Vec<u64> {
    let v = *v;
    let mut out = Vec::new();
    if v == 0 {
        return out;
    }
    out.push(0);
    out.push(v / 2);
    out.push(v - 1);
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall_noshrink(
            "reverse-reverse-id",
            |rng| (0..rng.range_usize(0, 20)).map(|_| rng.next_u64()).collect::<Vec<_>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                w == *v
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-small'")]
    fn failing_property_reports() {
        forall(
            "always-small",
            |rng| rng.gen_range(1000),
            shrink_u64,
            |&v| v < 500,
        );
    }

    #[test]
    fn shrinker_finds_small_counterexample() {
        // shrink from a big failing value down: minimal failing for v>=500
        // under shrink_u64 descent should be <= the original.
        let minimal = shrink_loop(900u64, &shrink_u64, &|&v: &u64| v < 500);
        assert!(minimal >= 500, "still failing");
        assert!(minimal <= 900);
    }

    #[test]
    fn shrink_vec_candidates() {
        let c = shrink_vec(&[1, 2, 3, 4]);
        assert!(c.contains(&vec![1, 2]));
        assert!(c.contains(&vec![3, 4]));
        assert!(c.contains(&vec![2, 3, 4]));
    }
}
