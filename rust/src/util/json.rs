//! Minimal JSON value + serializer (offline substitute for `serde_json`).
//!
//! Experiment reports are emitted as JSON so EXPERIMENTS.md numbers are
//! regenerable and machine-diffable. Only serialization is needed by the
//! harness; a small parser is included for round-trip tests and config
//! overrides.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (all JSON numbers are f64 here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Insert into an object value; panics if not an object.
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Access an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close_pad = "  ".repeat(depth);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close_pad);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close_pad);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document. Supports the full value grammar the emitter
    /// produces (no exotic escapes beyond \uXXXX).
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        other => return Err(format!("bad array sep {other:?}")),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    let val = self.value()?;
                    map.insert(key, val);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        other => return Err(format!("bad object sep {other:?}")),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::obj([
            ("name", "fig5".into()),
            ("mean", 12.5.into()),
            ("runs", 20u64.into()),
            ("ok", true.into()),
            ("series", vec![1.0, 2.0, 3.5].into()),
        ]);
        let s = v.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\te".into());
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[{"b":null},{"c":[1,2]}],"d":-1.5e2}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-150.0));
    }

    #[test]
    fn parse_rejects_trailing() {
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj([("x", vec![1.0, 2.0].into()), ("y", Json::Null)]);
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }
}
