//! Minimal command-line argument parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments. The `woss` binary and all examples parse through
//! this.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, named options, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` and `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit token stream.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Option value by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value or default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse an option as `T`, with default when absent. Panics with a
    /// readable message on malformed input (CLI boundary).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(raw) => raw
                .parse()
                .unwrap_or_else(|e| panic!("--{key} {raw}: {e}")),
        }
    }

    /// Is a bare flag present? (accepts both `--quiet` and `--quiet=true`)
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.get(name).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment fig5 --runs 20 --seed=7 --quiet");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig5"]);
        assert_eq!(a.get("runs"), Some("20"));
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.has_flag("quiet"));
    }

    #[test]
    fn get_parse_with_default() {
        let a = parse("run --nodes 50");
        assert_eq!(a.get_parse("nodes", 20usize), 50);
        assert_eq!(a.get_parse("runs", 5usize), 5);
    }

    #[test]
    #[should_panic(expected = "--nodes")]
    fn get_parse_malformed_panics() {
        let a = parse("run --nodes banana");
        let _: usize = a.get_parse("nodes", 0);
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("run --verbose --nodes 3");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("nodes"), Some("3"));
    }

    #[test]
    fn empty_input() {
        let a = parse("");
        assert!(a.command.is_none());
        assert!(a.options.is_empty());
    }
}
