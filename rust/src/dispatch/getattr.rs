//! Bottom-up information-retrieval modules (paper §3.2, "passing hints
//! bottom-up").
//!
//! These serve reserved extended attributes from manager-internal state,
//! triggered by a plain POSIX `getxattr` — the storage-to-application
//! half of the bidirectional channel. The flagship provider is
//! [`LocationProvider`]: the workflow scheduler `get`s `location` and
//! schedules the consuming task on a node that holds the data.
//!
//! Two reserved attributes are *not* provider-backed: `cache_state`
//! (which chunk backend — `tier=mem|disk|seg` — plus per-node cache
//! residency) and the live countdown behind `consumers_left` are
//! deployment-local state only the live store can see, so
//! [`crate::live::LiveStore::get_xattr`] serves `cache_state` directly
//! while [`ConsumersLeftProvider`] merely reflects the tag the store
//! maintains.

use super::GetAttrProvider;
use crate::storage::types::{FileMeta, NodeState};

/// Reserved `location` attribute: the set of storage nodes holding the
/// file, rendered as a comma-separated node list (primary holders first,
/// in chunk order).
pub struct LocationProvider;

impl GetAttrProvider for LocationProvider {
    fn key(&self) -> &'static str {
        crate::hints::LOCATION_ATTR
    }

    fn get(&self, file: &FileMeta, _nodes: &[NodeState]) -> String {
        let holders = file.holders();
        holders
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// Reserved `chunk_location` attribute: fine-grained per-chunk map
/// (`idx:node;...`), used by the scatter benchmark where readers align
/// with their disjoint region.
pub struct ChunkLocationProvider;

impl GetAttrProvider for ChunkLocationProvider {
    fn key(&self) -> &'static str {
        "chunk_location"
    }

    fn get(&self, file: &FileMeta, _nodes: &[NodeState]) -> String {
        file.chunks
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{}:{}", i, c.primary()))
            .collect::<Vec<_>>()
            .join(";")
    }
}

/// Reserved `system_status` attribute: storage-pool usage summary —
/// an example of exposing broader internal state (§5 lists replication
/// counts, device status, caching status as candidates). The live
/// store extends the value this provider renders with a
/// ` recovered=<n>` field (files its last re-open salvaged); the
/// count is deployment-local restart state only the store can see,
/// exactly like `cache_state`.
pub struct SystemStatusProvider;

impl GetAttrProvider for SystemStatusProvider {
    fn key(&self) -> &'static str {
        crate::hints::SYSTEM_STATUS_ATTR
    }

    fn get(&self, _file: &FileMeta, nodes: &[NodeState]) -> String {
        let total: u64 = nodes.iter().map(|n| n.capacity).sum();
        let used: u64 = nodes.iter().map(|n| n.used).sum();
        format!("nodes={} used={} capacity={}", nodes.len(), used, total)
    }
}

/// Reserved `consumers_left` attribute: declared consumer reads
/// remaining before a scratch file is dead. The live store keeps the
/// countdown in the file's own `Consumers` tag (decremented under the
/// namespace lock on every whole-file read when lifetime enforcement
/// is on), so this provider simply reflects it; files that declared no
/// consumer count report `untracked`. The workflow runtime reads this
/// to verify the reclamation protocol bottom-up.
pub struct ConsumersLeftProvider;

impl GetAttrProvider for ConsumersLeftProvider {
    fn key(&self) -> &'static str {
        crate::hints::CONSUMERS_LEFT_ATTR
    }

    fn get(&self, file: &FileMeta, _nodes: &[NodeState]) -> String {
        file.tags
            .get(crate::hints::keys::CONSUMERS)
            .map(str::to_string)
            .unwrap_or_else(|| "untracked".to_string())
    }
}

/// Reserved `replication_state` attribute: achieved replica count per
/// chunk (min across chunks) — lets an application judge data-loss risk.
pub struct ReplicationStateProvider;

impl GetAttrProvider for ReplicationStateProvider {
    fn key(&self) -> &'static str {
        "replication_state"
    }

    fn get(&self, file: &FileMeta, _nodes: &[NodeState]) -> String {
        let min = file
            .chunks
            .iter()
            .map(|c| c.replicas.len())
            .min()
            .unwrap_or(0);
        format!("{min}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hints::TagSet;
    use crate::storage::types::{ChunkMeta, FileId, NodeId};

    fn file() -> FileMeta {
        FileMeta {
            id: FileId(9),
            size: 3072,
            chunk_size: 1024,
            tags: TagSet::new(),
            chunks: vec![
                ChunkMeta {
                    replicas: vec![NodeId(4), NodeId(2)],
                },
                ChunkMeta {
                    replicas: vec![NodeId(4)],
                },
                ChunkMeta {
                    replicas: vec![NodeId(7)],
                },
            ],
            creator: NodeId(4),
        }
    }

    #[test]
    fn location_lists_distinct_holders() {
        let s = LocationProvider.get(&file(), &[]);
        assert_eq!(s, "n2,n4,n7");
    }

    #[test]
    fn chunk_location_fine_grained() {
        let s = ChunkLocationProvider.get(&file(), &[]);
        assert_eq!(s, "0:n4;1:n4;2:n7");
    }

    #[test]
    fn system_status_sums_pool() {
        let nodes = vec![
            NodeState {
                node: NodeId(1),
                capacity: 100,
                used: 25,
            },
            NodeState {
                node: NodeId(2),
                capacity: 100,
                used: 50,
            },
        ];
        let s = SystemStatusProvider.get(&file(), &nodes);
        assert_eq!(s, "nodes=2 used=75 capacity=200");
    }

    #[test]
    fn replication_state_is_min() {
        let s = ReplicationStateProvider.get(&file(), &[]);
        assert_eq!(s, "1");
    }

    #[test]
    fn consumers_left_reflects_tag() {
        let mut f = file();
        assert_eq!(ConsumersLeftProvider.get(&f, &[]), "untracked");
        f.tags.set("Consumers", "3");
        assert_eq!(ConsumersLeftProvider.get(&f, &[]), "3");
        f.tags.set("Consumers", "0");
        assert_eq!(ConsumersLeftProvider.get(&f, &[]), "0", "fan-out complete");
    }
}
