//! Data-placement optimization modules (paper Table 3, top half).
//!
//! Each module claims an allocation request when the file's tags carry
//! its hint, and *declines* (returns `None`) otherwise — including when
//! the hint cannot be honored (full node, missing group), in which case
//! the dispatcher falls through to default round-robin. Hints are hints.

use super::{PlacementCtx, PlacementPolicy};
use crate::hints::Hint;
use crate::storage::types::NodeId;

/// `DP=local` — pipeline pattern. Prefer the writer's own storage node so
/// the next pipeline stage (scheduled location-aware) reads locally.
pub struct LocalPlacement;

impl PlacementPolicy for LocalPlacement {
    fn name(&self) -> &'static str {
        "placement.local"
    }

    fn place(
        &self,
        ctx: &mut PlacementCtx<'_>,
        _chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        if !matches!(ctx.tags.placement(), Some(Hint::PlacementLocal)) {
            return None;
        }
        // "if space is available" — otherwise decline and let the
        // default policy stripe it.
        if ctx.fits(ctx.client, chunk_bytes) {
            Some(ctx.client)
        } else {
            None
        }
    }
}

/// `DP=collocation <group>` — reduce pattern. All files tagged with the
/// same group land on one anchor node so the reduce task can be scheduled
/// there and consume every input locally.
pub struct CollocatePlacement;

impl PlacementPolicy for CollocatePlacement {
    fn name(&self) -> &'static str {
        "placement.collocate"
    }

    fn place(
        &self,
        ctx: &mut PlacementCtx<'_>,
        _chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        let group = match ctx.tags.placement() {
            Some(Hint::PlacementCollocate(g)) => g,
            _ => return None,
        };
        if let Some(&anchor) = ctx.state.groups.get(&group) {
            if ctx.fits(anchor, chunk_bytes) {
                return Some(anchor);
            }
            // Anchor full *or dead* — `fits` covers both, because node
            // churn zeroes a failed node's capacity. Re-anchor the group
            // on the current most-free node instead of declining
            // forever: before this fix every later file in the group
            // silently fell through to round-robin, scattering exactly
            // the files the reduce task was promised together. Files
            // already on the old anchor stay where they are (hints are
            // hints); new ones collocate on the fresh anchor.
        }
        // First file of the group, or a re-anchor after churn: anchor
        // on the most-free node.
        let anchor = ctx.most_free(chunk_bytes)?;
        ctx.state.groups.insert(group, anchor);
        Some(anchor)
    }
}

/// `DP=scatter <n>` — scatter pattern. Every group of `n` contiguous
/// chunks goes to one node, groups round-robin across the pool, so each
/// downstream reader's disjoint region lives on one node and fine-grained
/// location exposure lets the scheduler line readers up with their
/// region.
pub struct ScatterPlacement;

impl PlacementPolicy for ScatterPlacement {
    fn name(&self) -> &'static str {
        "placement.scatter"
    }

    fn place(
        &self,
        ctx: &mut PlacementCtx<'_>,
        chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        let group_size = match ctx.tags.placement() {
            Some(Hint::PlacementScatter(n)) => n,
            _ => return None,
        };
        // `scatter 0` parses as `Hint::Malformed` and never reaches this
        // module; the guard keeps the modulo safe even against a caller
        // constructing the hint directly.
        if group_size == 0 {
            return None;
        }
        let n = ctx.nodes.len() as u64;
        if n == 0 {
            return None;
        }
        let slot = (chunk_idx / group_size) % n;
        let node = ctx.nodes[slot as usize].node;
        if ctx.fits(node, chunk_bytes) {
            Some(node)
        } else {
            None
        }
    }
}

/// Cost-based default placement — the adaptive replacement for blind
/// round-robin striping when no hint module claims a chunk.
///
/// `costs[i]` scores `nodes[i]`: lower is cheaper to write right now.
/// The live store computes it from its bottom-up load plane (capacity
/// fraction × EWMA write latency × in-flight I/O depth — see
/// `LiveStore`'s cost formula); this function stays policy-free so the
/// dispatch layer needs no handle on live-store internals and unit
/// tests can feed synthetic scores. Only nodes with room for `bytes`
/// are candidates; ties break on the lowest slice position, so equal
/// scores (the cold-start case: no samples anywhere) degrade to
/// first-fit determinism. Returns `None` when nothing fits — the
/// caller's round-robin fallback applies, exactly as for a declining
/// hint module.
pub fn place_cost_based(nodes: &[NodeState], costs: &[f64], bytes: u64) -> Option<NodeId> {
    debug_assert_eq!(nodes.len(), costs.len());
    let mut best: Option<(f64, usize)> = None;
    for (i, n) in nodes.iter().enumerate() {
        if !n.fits(bytes) {
            continue;
        }
        let cost = costs.get(i).copied().unwrap_or(f64::INFINITY);
        let better = match best {
            None => true,
            Some((b, _)) => cost < b,
        };
        if better {
            best = Some((cost, i));
        }
    }
    best.map(|(_, i)| nodes[i].node)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::PlacementState;
    use crate::hints::TagSet;
    use crate::storage::types::NodeState;

    fn nodes(n: usize) -> Vec<NodeState> {
        (0..n)
            .map(|i| NodeState {
                node: NodeId(i + 1),
                capacity: 1 << 30,
                used: 0,
            })
            .collect()
    }

    fn ctx<'a>(
        client: NodeId,
        tags: &'a TagSet,
        nodes: &'a [NodeState],
        state: &'a mut PlacementState,
    ) -> PlacementCtx<'a> {
        PlacementCtx {
            client,
            tags,
            nodes,
            state,
        }
    }

    #[test]
    fn local_places_on_writer() {
        let tags = TagSet::from_pairs([("DP", "local")]);
        let ns = nodes(4);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(2), &tags, &ns, &mut st);
        assert_eq!(LocalPlacement.place(&mut c, 0, 100), Some(NodeId(2)));
        assert_eq!(LocalPlacement.place(&mut c, 5, 100), Some(NodeId(2)));
    }

    #[test]
    fn local_declines_when_writer_full() {
        let tags = TagSet::from_pairs([("DP", "local")]);
        let mut ns = nodes(4);
        ns[1].used = ns[1].capacity; // client NodeId(2) is index 1
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(2), &tags, &ns, &mut st);
        assert_eq!(LocalPlacement.place(&mut c, 0, 100), None);
    }

    #[test]
    fn local_declines_untagged() {
        let tags = TagSet::new();
        let ns = nodes(4);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(2), &tags, &ns, &mut st);
        assert_eq!(LocalPlacement.place(&mut c, 0, 100), None);
    }

    #[test]
    fn collocate_sticky_anchor() {
        let tags = TagSet::from_pairs([("DP", "collocation g")]);
        let ns = nodes(4);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        let a = CollocatePlacement.place(&mut c, 0, 100).unwrap();
        // different writer, same group → same anchor
        let mut c2 = ctx(NodeId(3), &tags, &ns, &mut st);
        let b = CollocatePlacement.place(&mut c2, 0, 100).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn collocate_groups_independent() {
        let t1 = TagSet::from_pairs([("DP", "collocation g1")]);
        let t2 = TagSet::from_pairs([("DP", "collocation g2")]);
        let mut ns = nodes(4);
        let mut st = PlacementState::default();
        let a = CollocatePlacement
            .place(&mut ctx(NodeId(1), &t1, &ns, &mut st), 0, 100)
            .unwrap();
        // consume capacity on the anchor so g2 picks a different most-free
        ns.iter_mut().find(|n| n.node == a).unwrap().used = 500;
        let b = CollocatePlacement
            .place(&mut ctx(NodeId(1), &t2, &ns, &mut st), 0, 100)
            .unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn collocate_reanchors_after_churn() {
        let tags = TagSet::from_pairs([("DP", "collocation g")]);
        let mut ns = nodes(4);
        let mut st = PlacementState::default();
        let anchor = CollocatePlacement
            .place(&mut ctx(NodeId(1), &tags, &ns, &mut st), 0, 100)
            .unwrap();
        // Churn kills the anchor: fail_node zeroes its capacity, so
        // nothing fits there any more.
        {
            let dead = ns.iter_mut().find(|n| n.node == anchor).unwrap();
            dead.capacity = 0;
            dead.used = 0;
        }
        let fresh = CollocatePlacement
            .place(&mut ctx(NodeId(1), &tags, &ns, &mut st), 0, 100)
            .expect("group must re-anchor, not decline forever");
        assert_ne!(fresh, anchor, "re-anchor must leave the dead node");
        assert_eq!(
            st.groups.get("g"),
            Some(&fresh),
            "the group record must follow the new anchor"
        );
        // Later files in the group stick to the fresh anchor.
        let again = CollocatePlacement
            .place(&mut ctx(NodeId(3), &tags, &ns, &mut st), 0, 100)
            .unwrap();
        assert_eq!(again, fresh);
    }

    #[test]
    fn collocate_reanchors_when_anchor_fills() {
        let tags = TagSet::from_pairs([("DP", "collocation g")]);
        let mut ns = nodes(2);
        let mut st = PlacementState::default();
        let anchor = CollocatePlacement
            .place(&mut ctx(NodeId(1), &tags, &ns, &mut st), 0, 100)
            .unwrap();
        ns.iter_mut().find(|n| n.node == anchor).unwrap().used = 1 << 30;
        let fresh = CollocatePlacement
            .place(&mut ctx(NodeId(1), &tags, &ns, &mut st), 0, 100)
            .expect("a full anchor re-anchors on the remaining node");
        assert_ne!(fresh, anchor);
    }

    #[test]
    fn cost_based_prefers_cheapest_fitting_node() {
        let mut ns = nodes(3);
        // Node 3 is cheapest but full; node 2 is next.
        ns[2].used = ns[2].capacity;
        let picked = place_cost_based(&ns, &[3.0, 1.5, 0.5], 100);
        assert_eq!(picked, Some(NodeId(2)));
    }

    #[test]
    fn cost_based_ties_break_on_position() {
        let ns = nodes(3);
        // Cold start: every score identical → first fit wins, so the
        // degenerate case is deterministic.
        assert_eq!(place_cost_based(&ns, &[1.0, 1.0, 1.0], 100), Some(NodeId(1)));
    }

    #[test]
    fn cost_based_declines_when_pool_full() {
        let mut ns = nodes(2);
        for n in &mut ns {
            n.used = n.capacity;
        }
        assert_eq!(place_cost_based(&ns, &[1.0, 2.0], 1), None);
    }

    #[test]
    fn scatter_stripes_groups() {
        let tags = TagSet::from_pairs([("DP", "scatter 2")]);
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        let places: Vec<_> = (0..8)
            .map(|i| ScatterPlacement.place(&mut c, i, 100).unwrap().0)
            .collect();
        // groups of 2 chunks, round-robin over nodes 1,2,3
        assert_eq!(places, vec![1, 1, 2, 2, 3, 3, 1, 1]);
    }

    #[test]
    fn scatter_zero_stride_declines() {
        // `scatter 0` is malformed; the module must decline (default
        // striping applies) rather than divide by a zero stride.
        let tags = TagSet::from_pairs([("DP", "scatter 0")]);
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        assert_eq!(ScatterPlacement.place(&mut c, 0, 100), None);
    }

    #[test]
    fn scatter_declines_other_tags() {
        let tags = TagSet::from_pairs([("DP", "local")]);
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        assert_eq!(ScatterPlacement.place(&mut c, 0, 100), None);
    }
}
