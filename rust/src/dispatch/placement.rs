//! Data-placement optimization modules (paper Table 3, top half).
//!
//! Each module claims an allocation request when the file's tags carry
//! its hint, and *declines* (returns `None`) otherwise — including when
//! the hint cannot be honored (full node, missing group), in which case
//! the dispatcher falls through to default round-robin. Hints are hints.

use super::{PlacementCtx, PlacementPolicy};
use crate::hints::Hint;
use crate::storage::types::NodeId;

/// `DP=local` — pipeline pattern. Prefer the writer's own storage node so
/// the next pipeline stage (scheduled location-aware) reads locally.
pub struct LocalPlacement;

impl PlacementPolicy for LocalPlacement {
    fn name(&self) -> &'static str {
        "placement.local"
    }

    fn place(
        &self,
        ctx: &mut PlacementCtx<'_>,
        _chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        if !matches!(ctx.tags.placement(), Some(Hint::PlacementLocal)) {
            return None;
        }
        // "if space is available" — otherwise decline and let the
        // default policy stripe it.
        if ctx.fits(ctx.client, chunk_bytes) {
            Some(ctx.client)
        } else {
            None
        }
    }
}

/// `DP=collocation <group>` — reduce pattern. All files tagged with the
/// same group land on one anchor node so the reduce task can be scheduled
/// there and consume every input locally.
pub struct CollocatePlacement;

impl PlacementPolicy for CollocatePlacement {
    fn name(&self) -> &'static str {
        "placement.collocate"
    }

    fn place(
        &self,
        ctx: &mut PlacementCtx<'_>,
        _chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        let group = match ctx.tags.placement() {
            Some(Hint::PlacementCollocate(g)) => g,
            _ => return None,
        };
        if let Some(&anchor) = ctx.state.groups.get(&group) {
            if ctx.fits(anchor, chunk_bytes) {
                return Some(anchor);
            }
            // Anchor full: decline (files will spill via default path —
            // the reduce task still finds most inputs on the anchor).
            return None;
        }
        // First file of the group: anchor on the most-free node.
        let anchor = ctx.most_free(chunk_bytes)?;
        ctx.state.groups.insert(group, anchor);
        Some(anchor)
    }
}

/// `DP=scatter <n>` — scatter pattern. Every group of `n` contiguous
/// chunks goes to one node, groups round-robin across the pool, so each
/// downstream reader's disjoint region lives on one node and fine-grained
/// location exposure lets the scheduler line readers up with their
/// region.
pub struct ScatterPlacement;

impl PlacementPolicy for ScatterPlacement {
    fn name(&self) -> &'static str {
        "placement.scatter"
    }

    fn place(
        &self,
        ctx: &mut PlacementCtx<'_>,
        chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        let group_size = match ctx.tags.placement() {
            Some(Hint::PlacementScatter(n)) => n,
            _ => return None,
        };
        // `scatter 0` parses as `Hint::Malformed` and never reaches this
        // module; the guard keeps the modulo safe even against a caller
        // constructing the hint directly.
        if group_size == 0 {
            return None;
        }
        let n = ctx.nodes.len() as u64;
        if n == 0 {
            return None;
        }
        let slot = (chunk_idx / group_size) % n;
        let node = ctx.nodes[slot as usize].node;
        if ctx.fits(node, chunk_bytes) {
            Some(node)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::PlacementState;
    use crate::hints::TagSet;
    use crate::storage::types::NodeState;

    fn nodes(n: usize) -> Vec<NodeState> {
        (0..n)
            .map(|i| NodeState {
                node: NodeId(i + 1),
                capacity: 1 << 30,
                used: 0,
            })
            .collect()
    }

    fn ctx<'a>(
        client: NodeId,
        tags: &'a TagSet,
        nodes: &'a [NodeState],
        state: &'a mut PlacementState,
    ) -> PlacementCtx<'a> {
        PlacementCtx {
            client,
            tags,
            nodes,
            state,
        }
    }

    #[test]
    fn local_places_on_writer() {
        let tags = TagSet::from_pairs([("DP", "local")]);
        let ns = nodes(4);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(2), &tags, &ns, &mut st);
        assert_eq!(LocalPlacement.place(&mut c, 0, 100), Some(NodeId(2)));
        assert_eq!(LocalPlacement.place(&mut c, 5, 100), Some(NodeId(2)));
    }

    #[test]
    fn local_declines_when_writer_full() {
        let tags = TagSet::from_pairs([("DP", "local")]);
        let mut ns = nodes(4);
        ns[1].used = ns[1].capacity; // client NodeId(2) is index 1
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(2), &tags, &ns, &mut st);
        assert_eq!(LocalPlacement.place(&mut c, 0, 100), None);
    }

    #[test]
    fn local_declines_untagged() {
        let tags = TagSet::new();
        let ns = nodes(4);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(2), &tags, &ns, &mut st);
        assert_eq!(LocalPlacement.place(&mut c, 0, 100), None);
    }

    #[test]
    fn collocate_sticky_anchor() {
        let tags = TagSet::from_pairs([("DP", "collocation g")]);
        let ns = nodes(4);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        let a = CollocatePlacement.place(&mut c, 0, 100).unwrap();
        // different writer, same group → same anchor
        let mut c2 = ctx(NodeId(3), &tags, &ns, &mut st);
        let b = CollocatePlacement.place(&mut c2, 0, 100).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn collocate_groups_independent() {
        let t1 = TagSet::from_pairs([("DP", "collocation g1")]);
        let t2 = TagSet::from_pairs([("DP", "collocation g2")]);
        let mut ns = nodes(4);
        let mut st = PlacementState::default();
        let a = CollocatePlacement
            .place(&mut ctx(NodeId(1), &t1, &ns, &mut st), 0, 100)
            .unwrap();
        // consume capacity on the anchor so g2 picks a different most-free
        ns.iter_mut().find(|n| n.node == a).unwrap().used = 500;
        let b = CollocatePlacement
            .place(&mut ctx(NodeId(1), &t2, &ns, &mut st), 0, 100)
            .unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn scatter_stripes_groups() {
        let tags = TagSet::from_pairs([("DP", "scatter 2")]);
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        let places: Vec<_> = (0..8)
            .map(|i| ScatterPlacement.place(&mut c, i, 100).unwrap().0)
            .collect();
        // groups of 2 chunks, round-robin over nodes 1,2,3
        assert_eq!(places, vec![1, 1, 2, 2, 3, 3, 1, 1]);
    }

    #[test]
    fn scatter_zero_stride_declines() {
        // `scatter 0` is malformed; the module must decline (default
        // striping applies) rather than divide by a zero stride.
        let tags = TagSet::from_pairs([("DP", "scatter 0")]);
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        assert_eq!(ScatterPlacement.place(&mut c, 0, 100), None);
    }

    #[test]
    fn scatter_declines_other_tags() {
        let tags = TagSet::from_pairs([("DP", "local")]);
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut c = ctx(NodeId(1), &tags, &ns, &mut st);
        assert_eq!(ScatterPlacement.place(&mut c, 0, 100), None);
    }
}
