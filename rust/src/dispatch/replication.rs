//! Replication optimization modules (paper §3.3).
//!
//! The prototype implements two policies at the storage nodes, selected
//! per file through tags:
//!
//! * **eager parallel** — replicas are created while each block is being
//!   written, fanning out from the primary to distinct nodes; used to
//!   pre-spread hot-spot files (broadcast pattern).
//! * **lazy chained** — replicas trickle down a chain in the background;
//!   reliability without front-loading overhead (and the DSS default).
//!
//! Whether replica creation blocks write completion is governed by the
//! `RepSmntc` tag (optimistic vs pessimistic), honoring the paper's
//! Table 3 semantics.

use super::{PlacementCtx, ReplicationPolicy};
use crate::hints::{RepSemantics, TagSet};
use crate::storage::types::NodeId;

/// Pick `count` replica holders distinct from `primary` (and each other),
/// round-robin from the manager cursor, capacity-checked.
fn pick_targets(
    ctx: &mut PlacementCtx<'_>,
    primary: NodeId,
    count: usize,
    chunk_bytes: u64,
) -> Vec<NodeId> {
    let mut targets = Vec::with_capacity(count);
    let n = ctx.nodes.len();
    if n == 0 {
        return targets;
    }
    let start = ctx.state.rr_cursor;
    for probe in 0..n {
        if targets.len() == count {
            break;
        }
        let cand = &ctx.nodes[(start + probe) % n];
        if cand.node != primary && cand.fits(chunk_bytes) && !targets.contains(&cand.node) {
            targets.push(cand.node);
        }
    }
    ctx.state.rr_cursor = (start + 1) % n;
    targets
}

/// Eager parallel replication: used for broadcast-pattern hot files.
pub struct EagerParallel;

impl ReplicationPolicy for EagerParallel {
    fn name(&self) -> &'static str {
        "replication.eager_parallel"
    }

    fn replica_targets(
        &self,
        ctx: &mut PlacementCtx<'_>,
        primary: NodeId,
        factor: u32,
        chunk_bytes: u64,
    ) -> Vec<NodeId> {
        let extra = factor.saturating_sub(1) as usize;
        pick_targets(ctx, primary, extra, chunk_bytes)
    }

    fn blocking(&self, tags: &TagSet) -> bool {
        // Optimistic (default): return to the application after the first
        // replica (the primary write); replication proceeds eagerly in
        // the background. Pessimistic: block until well replicated.
        matches!(tags.replication_semantics(), RepSemantics::Pessimistic)
    }
}

/// Lazy chained replication: reliability-oriented background chaining.
pub struct LazyChained;

impl ReplicationPolicy for LazyChained {
    fn name(&self) -> &'static str {
        "replication.lazy_chained"
    }

    fn replica_targets(
        &self,
        ctx: &mut PlacementCtx<'_>,
        primary: NodeId,
        factor: u32,
        chunk_bytes: u64,
    ) -> Vec<NodeId> {
        let extra = factor.saturating_sub(1) as usize;
        pick_targets(ctx, primary, extra, chunk_bytes)
    }

    fn blocking(&self, _tags: &TagSet) -> bool {
        false // lazy: never blocks the writer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::PlacementState;
    use crate::storage::types::NodeState;

    fn nodes(n: usize) -> Vec<NodeState> {
        (0..n)
            .map(|i| NodeState {
                node: NodeId(i + 1),
                capacity: 1 << 30,
                used: 0,
            })
            .collect()
    }

    #[test]
    fn eager_picks_distinct_non_primary() {
        let tags = TagSet::from_pairs([("Replication", "4")]);
        let ns = nodes(8);
        let mut st = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(1),
            tags: &tags,
            nodes: &ns,
            state: &mut st,
        };
        let targets = EagerParallel.replica_targets(&mut ctx, NodeId(2), 4, 1024);
        assert_eq!(targets.len(), 3, "factor 4 = primary + 3 replicas");
        assert!(!targets.contains(&NodeId(2)));
        let mut dedup = targets.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), targets.len());
    }

    #[test]
    fn factor_capped_by_pool() {
        let tags = TagSet::new();
        let ns = nodes(3);
        let mut st = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(1),
            tags: &tags,
            nodes: &ns,
            state: &mut st,
        };
        let targets = EagerParallel.replica_targets(&mut ctx, NodeId(1), 16, 1024);
        assert_eq!(targets.len(), 2, "only 2 other nodes exist");
    }

    #[test]
    fn semantics_drive_blocking() {
        assert!(!EagerParallel.blocking(&TagSet::new()), "optimistic default");
        assert!(!EagerParallel.blocking(&TagSet::from_pairs([("RepSmntc", "optimistic")])));
        assert!(EagerParallel.blocking(&TagSet::from_pairs([("RepSmntc", "pessimistic")])));
        assert!(
            !LazyChained.blocking(&TagSet::from_pairs([("RepSmntc", "pessimistic")])),
            "lazy chaining never blocks"
        );
    }

    #[test]
    fn full_nodes_skipped() {
        let tags = TagSet::new();
        let mut ns = nodes(4);
        ns[2].used = ns[2].capacity;
        let mut st = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(1),
            tags: &tags,
            nodes: &ns,
            state: &mut st,
        };
        let targets = EagerParallel.replica_targets(&mut ctx, NodeId(1), 4, 1024);
        assert!(!targets.contains(&NodeId(3)), "full node must be skipped");
        assert_eq!(targets.len(), 2);
    }
}
