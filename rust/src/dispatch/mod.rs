//! The extensible dispatcher (paper §3.2, Figure 3).
//!
//! Every storage component processes requests through a dispatcher:
//! requests arrive stamped with the file's [`TagSet`]; the dispatcher
//! routes them to the optimization module registered for the matching
//! hint, or to a default implementation when no hint matches. Extending
//! the system = pick the `<key, value>` hint + implement the callback +
//! register it — exactly the paper's developer story, expressed here as
//! three trait surfaces:
//!
//! * [`PlacementPolicy`] — chunk allocation (manager side),
//! * [`ReplicationPolicy`] — replica creation (storage-node side),
//! * [`GetAttrProvider`] — bottom-up information retrieval (manager side,
//!   triggered by POSIX `getxattr`).
//!
//! [`Registry`] wires hints to modules. The DSS baseline uses
//! [`Registry::baseline`] (default modules only — hints are carried but
//! never interpreted); WOSS uses [`Registry::woss`].

pub mod getattr;
pub mod placement;
pub mod replication;

use crate::hints::{Hint, TagSet};
use crate::storage::types::{FileMeta, NodeId, NodeState};
use std::collections::BTreeMap;

/// Mutable manager-side state placement decisions may consult/update.
#[derive(Debug, Default)]
pub struct PlacementState {
    /// Round-robin cursor for default striping.
    pub rr_cursor: usize,
    /// Collocation group → chosen anchor node.
    pub groups: BTreeMap<String, NodeId>,
}

/// Which of `shards` namespace shards owns `path` (FNV-1a over the path
/// bytes). Both metadata layers route by this function — the simulated
/// [`Manager`](crate::storage::Manager) and the live store's lock
/// stripes — so a path's shard is stable across the whole stack.
/// `shards` is clamped to ≥ 1.
pub fn shard_for_path(path: &str, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in path.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    (h % shards as u64) as usize
}

/// Placement state for a sharded metadata manager.
///
/// The round-robin cursor is the placement path's only always-written
/// state, so a sharded manager gives **each shard its own cursor** — in a
/// threaded deployment that is the difference between a shared atomic hot
/// spot and shard-private (lock-free) state, and in the simulator it
/// removes any cross-shard ordering coupling. Collocation anchors stay
/// **global**: a `DP=collocation <group>` must resolve to one anchor node
/// no matter which shard each member file's path hashes to. Shards borrow
/// a [`PlacementState`]-shaped view through [`ShardedPlacementState::with_view`],
/// so every [`PlacementPolicy`] runs unchanged against either layout.
#[derive(Debug)]
pub struct ShardedPlacementState {
    /// Global collocation-group anchors (shared across shards).
    groups: BTreeMap<String, NodeId>,
    /// Per-shard round-robin cursors.
    cursors: Vec<usize>,
}

impl ShardedPlacementState {
    /// State for `shards` metadata shards (`shards` is clamped to ≥ 1).
    pub fn new(shards: usize) -> Self {
        ShardedPlacementState {
            groups: BTreeMap::new(),
            cursors: vec![0; shards.max(1)],
        }
    }

    /// Number of shards this state serves.
    pub fn shard_count(&self) -> usize {
        self.cursors.len()
    }

    /// Run `f` against shard `shard`'s placement view. The view combines
    /// the shard-private cursor with the global group anchors; updates to
    /// both are written back when `f` returns.
    pub fn with_view<R>(
        &mut self,
        shard: usize,
        f: impl FnOnce(&mut PlacementState) -> R,
    ) -> R {
        let shard = shard % self.cursors.len();
        let mut view = PlacementState {
            rr_cursor: self.cursors[shard],
            groups: std::mem::take(&mut self.groups),
        };
        let out = f(&mut view);
        self.cursors[shard] = view.rr_cursor;
        self.groups = view.groups;
        out
    }
}

/// Everything a placement decision may look at.
pub struct PlacementCtx<'a> {
    /// The client (SAI) node writing the file.
    pub client: NodeId,
    /// The file's tags (already cached at the SAI, stamped on the
    /// allocation request).
    pub tags: &'a TagSet,
    /// Registry view of the storage nodes (usage is maintained by the
    /// manager as allocations commit).
    pub nodes: &'a [NodeState],
    /// Manager placement state (round-robin cursor, collocation anchors).
    pub state: &'a mut PlacementState,
}

impl<'a> PlacementCtx<'a> {
    /// Does `node` have room for `bytes` more?
    pub fn fits(&self, node: NodeId, bytes: u64) -> bool {
        self.nodes
            .iter()
            .find(|n| n.node == node)
            .map(|n| n.fits(bytes))
            .unwrap_or(false)
    }

    /// Next node from the round-robin cursor with room for `bytes`;
    /// `None` if the whole pool is full.
    pub fn next_rr(&mut self, bytes: u64) -> Option<NodeId> {
        let n = self.nodes.len();
        for probe in 0..n {
            let idx = (self.state.rr_cursor + probe) % n;
            if self.nodes[idx].fits(bytes) {
                self.state.rr_cursor = (idx + 1) % n;
                return Some(self.nodes[idx].node);
            }
        }
        None
    }

    /// Node with the most free space (collocation anchor selection).
    pub fn most_free(&self, bytes: u64) -> Option<NodeId> {
        self.nodes
            .iter()
            .filter(|s| s.fits(bytes))
            .max_by_key(|s| s.free())
            .map(|s| s.node)
    }
}

/// A chunk-placement optimization module.
pub trait PlacementPolicy: Send + Sync {
    /// Module name (diagnostics, table6-style breakdowns).
    fn name(&self) -> &'static str;
    /// Choose the primary holder for chunk `chunk_idx` (`chunk_bytes`
    /// long). Returning `None` falls back to the default policy —
    /// *hints, not directives*.
    fn place(&self, ctx: &mut PlacementCtx<'_>, chunk_idx: u64, chunk_bytes: u64)
        -> Option<NodeId>;
}

/// A replica-creation optimization module (runs at the storage nodes).
pub trait ReplicationPolicy: Send + Sync {
    /// Module name.
    fn name(&self) -> &'static str;
    /// Pick replica holders (excluding the primary) for one chunk.
    fn replica_targets(
        &self,
        ctx: &mut PlacementCtx<'_>,
        primary: NodeId,
        factor: u32,
        chunk_bytes: u64,
    ) -> Vec<NodeId>;
    /// Whether replica creation blocks write completion (pessimistic) or
    /// proceeds in the background (optimistic / lazy chained).
    fn blocking(&self, tags: &TagSet) -> bool;
}

/// Bottom-up information retrieval module (paper's `GetAttrib` design):
/// maps a reserved attribute name to internal system state.
pub trait GetAttrProvider: Send + Sync {
    /// Attribute key this provider serves (e.g. `"location"`).
    fn key(&self) -> &'static str;
    /// Produce the value for `file` given the manager's node view.
    fn get(&self, file: &FileMeta, nodes: &[NodeState]) -> String;
}

/// The per-deployment module registry: the concrete form of the paper's
/// "extensible storage system components".
pub struct Registry {
    placements: Vec<Box<dyn PlacementPolicy>>,
    replication: Box<dyn ReplicationPolicy>,
    getattrs: BTreeMap<&'static str, Box<dyn GetAttrProvider>>,
    /// When false (DSS baseline) tags are stored but never dispatched on.
    hints_enabled: bool,
}

impl Registry {
    /// Traditional distributed storage system: round-robin placement,
    /// chained lazy replication, no hint dispatch, no location exposure.
    /// This is the paper's DSS baseline.
    pub fn baseline() -> Registry {
        Registry {
            placements: vec![],
            replication: Box::new(replication::LazyChained),
            getattrs: BTreeMap::new(),
            hints_enabled: false,
        }
    }

    /// The full WOSS registry: all Table 3 modules.
    pub fn woss() -> Registry {
        let mut r = Registry {
            placements: vec![
                Box::new(placement::LocalPlacement),
                Box::new(placement::CollocatePlacement),
                Box::new(placement::ScatterPlacement),
            ],
            replication: Box::new(replication::EagerParallel),
            getattrs: BTreeMap::new(),
            hints_enabled: true,
        };
        r.register_getattr(Box::new(getattr::LocationProvider));
        r.register_getattr(Box::new(getattr::ChunkLocationProvider));
        r.register_getattr(Box::new(getattr::SystemStatusProvider));
        r.register_getattr(Box::new(getattr::ReplicationStateProvider));
        r.register_getattr(Box::new(getattr::ConsumersLeftProvider));
        r
    }

    /// Are hint-triggered optimizations active?
    pub fn hints_enabled(&self) -> bool {
        self.hints_enabled
    }

    /// Register an additional placement module (the extensibility path a
    /// developer takes to add a new optimization).
    pub fn register_placement(&mut self, policy: Box<dyn PlacementPolicy>) {
        self.placements.push(policy);
    }

    /// Register/replace the replication policy.
    pub fn set_replication(&mut self, policy: Box<dyn ReplicationPolicy>) {
        self.replication = policy;
    }

    /// Register a bottom-up provider.
    pub fn register_getattr(&mut self, provider: Box<dyn GetAttrProvider>) {
        self.getattrs.insert(provider.key(), provider);
    }

    /// Dispatch a chunk allocation through the hint-triggered modules
    /// only; `None` means no module claimed it (default layout applies).
    pub fn place_hinted(
        &self,
        ctx: &mut PlacementCtx<'_>,
        chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        if self.hints_enabled {
            for policy in &self.placements {
                if let Some(node) = policy.place(ctx, chunk_idx, chunk_bytes) {
                    return Some(node);
                }
            }
        }
        None
    }

    /// Dispatch a chunk allocation: first registered module that accepts
    /// the tagged request wins; otherwise the default round-robin path.
    pub fn place_chunk(
        &self,
        ctx: &mut PlacementCtx<'_>,
        chunk_idx: u64,
        chunk_bytes: u64,
    ) -> Option<NodeId> {
        self.place_hinted(ctx, chunk_idx, chunk_bytes)
            .or_else(|| ctx.next_rr(chunk_bytes))
    }

    /// Which placement module would claim this tag set (diagnostics).
    pub fn placement_module(&self, tags: &TagSet) -> &'static str {
        if self.hints_enabled {
            match tags.placement() {
                Some(Hint::PlacementLocal) => return "placement.local",
                Some(Hint::PlacementCollocate(_)) => return "placement.collocate",
                Some(Hint::PlacementScatter(_)) => return "placement.scatter",
                _ => {}
            }
        }
        "placement.default"
    }

    /// Replication policy in force.
    pub fn replication(&self) -> &dyn ReplicationPolicy {
        self.replication.as_ref()
    }

    /// Requested replication factor for a file: the `Replication` tag if
    /// hints are enabled, else 1 (the DSS baseline stores one copy of
    /// intermediate scratch data).
    pub fn replication_factor(&self, tags: &TagSet) -> u32 {
        if self.hints_enabled {
            tags.replication().unwrap_or(1)
        } else {
            1
        }
    }

    /// Would [`Registry::get_system_attr`] serve this key? A cheap
    /// pre-check callers use to avoid assembling the node view (which
    /// may sit behind a contended lock) for plain user attributes.
    pub fn serves_attr(&self, key: &str) -> bool {
        self.hints_enabled && self.getattrs.contains_key(key)
    }

    /// Serve a `getxattr` through the bottom-up providers. `None` means
    /// the attribute is not system-provided (fall through to the plain
    /// xattr store).
    pub fn get_system_attr(
        &self,
        key: &str,
        file: &FileMeta,
        nodes: &[NodeState],
    ) -> Option<String> {
        if !self.hints_enabled {
            return None;
        }
        self.getattrs.get(key).map(|p| p.get(file, nodes))
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry")
            .field("hints_enabled", &self.hints_enabled)
            .field("placements", &self.placements.iter().map(|p| p.name()).collect::<Vec<_>>())
            .field("replication", &self.replication.name())
            .field("getattrs", &self.getattrs.keys().collect::<Vec<_>>())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hints::TagSet;

    fn nodes(n: usize, capacity: u64) -> Vec<NodeState> {
        (0..n)
            .map(|i| NodeState {
                node: NodeId(i + 1),
                capacity,
                used: 0,
            })
            .collect()
    }

    #[test]
    fn baseline_ignores_hints() {
        let reg = Registry::baseline();
        let tags = TagSet::from_pairs([("DP", "local")]);
        let nodes = nodes(4, 1 << 30);
        let mut state = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(3),
            tags: &tags,
            nodes: &nodes,
            state: &mut state,
        };
        // Round-robin, not local: DSS carries tags but never dispatches.
        let first = reg.place_chunk(&mut ctx, 0, 1024).unwrap();
        let second = reg.place_chunk(&mut ctx, 1, 1024).unwrap();
        assert_eq!(first, NodeId(1));
        assert_eq!(second, NodeId(2));
        assert_eq!(reg.placement_module(&tags), "placement.default");
        assert_eq!(reg.replication_factor(&TagSet::from_pairs([("Replication", "8")])), 1);
    }

    #[test]
    fn woss_dispatches_local() {
        let reg = Registry::woss();
        let tags = TagSet::from_pairs([("DP", "local")]);
        let nodes = nodes(4, 1 << 30);
        let mut state = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(3),
            tags: &tags,
            nodes: &nodes,
            state: &mut state,
        };
        assert_eq!(reg.place_chunk(&mut ctx, 0, 1024), Some(NodeId(3)));
        assert_eq!(reg.placement_module(&tags), "placement.local");
    }

    #[test]
    fn custom_module_registration() {
        struct Pin7;
        impl PlacementPolicy for Pin7 {
            fn name(&self) -> &'static str {
                "placement.pin7"
            }
            fn place(
                &self,
                ctx: &mut PlacementCtx<'_>,
                _idx: u64,
                bytes: u64,
            ) -> Option<NodeId> {
                if ctx.tags.get("Pin") == Some("7") && ctx.fits(NodeId(7), bytes) {
                    Some(NodeId(7))
                } else {
                    None
                }
            }
        }
        let mut reg = Registry::woss();
        reg.register_placement(Box::new(Pin7));
        let tags = TagSet::from_pairs([("Pin", "7")]);
        let nodes = nodes(8, 1 << 30);
        let mut state = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(1),
            tags: &tags,
            nodes: &nodes,
            state: &mut state,
        };
        assert_eq!(reg.place_chunk(&mut ctx, 0, 1024), Some(NodeId(7)));
    }

    #[test]
    fn shard_for_path_stable_and_in_range() {
        assert_eq!(shard_for_path("/any/path", 1), 0);
        assert_eq!(shard_for_path("/any/path", 0), 0, "clamped to one shard");
        for shards in [2usize, 4, 8] {
            for p in ["/a", "/b", "/wf/out17", ""] {
                let s = shard_for_path(p, shards);
                assert!(s < shards);
                assert_eq!(s, shard_for_path(p, shards), "routing is stable");
            }
        }
        // The hash actually spreads paths (FNV-1a, not constant).
        let spread: std::collections::BTreeSet<usize> = (0..64)
            .map(|i| shard_for_path(&format!("/wf/out{i}"), 8))
            .collect();
        assert!(spread.len() >= 4, "64 paths landed on {} shards", spread.len());
    }

    #[test]
    fn sharded_cursors_are_independent() {
        let reg = Registry::baseline();
        let ns = nodes(4, 1 << 30);
        let mut sharded = ShardedPlacementState::new(2);
        assert_eq!(sharded.shard_count(), 2);
        let tags = TagSet::new();
        // Two allocations through shard 0 advance its cursor twice...
        let (a, b) = sharded.with_view(0, |st| {
            let mut ctx = PlacementCtx {
                client: NodeId(1),
                tags: &tags,
                nodes: &ns,
                state: st,
            };
            (
                reg.place_chunk(&mut ctx, 0, 1024).unwrap(),
                reg.place_chunk(&mut ctx, 1, 1024).unwrap(),
            )
        });
        assert_eq!((a, b), (NodeId(1), NodeId(2)));
        // ...while shard 1's cursor still starts from the beginning.
        let c = sharded.with_view(1, |st| {
            let mut ctx = PlacementCtx {
                client: NodeId(1),
                tags: &tags,
                nodes: &ns,
                state: st,
            };
            reg.place_chunk(&mut ctx, 0, 1024).unwrap()
        });
        assert_eq!(c, NodeId(1), "shard 1 unaffected by shard 0 traffic");
    }

    #[test]
    fn sharded_collocation_anchors_are_global() {
        let reg = Registry::woss();
        let ns = nodes(4, 1 << 30);
        let mut sharded = ShardedPlacementState::new(4);
        let tags = TagSet::from_pairs([("DP", "collocation g")]);
        let place = |sharded: &mut ShardedPlacementState, shard: usize| {
            sharded.with_view(shard, |st| {
                let mut ctx = PlacementCtx {
                    client: NodeId(2),
                    tags: &tags,
                    nodes: &ns,
                    state: st,
                };
                reg.place_chunk(&mut ctx, 0, 1024).unwrap()
            })
        };
        let a = place(&mut sharded, 0);
        let b = place(&mut sharded, 3);
        assert_eq!(a, b, "same group must anchor together across shards");
    }

    #[test]
    fn full_pool_returns_none() {
        let reg = Registry::woss();
        let tags = TagSet::new();
        let mut ns = nodes(2, 1000);
        ns[0].used = 1000;
        ns[1].used = 1000;
        let mut state = PlacementState::default();
        let mut ctx = PlacementCtx {
            client: NodeId(1),
            tags: &tags,
            nodes: &ns,
            state: &mut state,
        };
        assert_eq!(reg.place_chunk(&mut ctx, 0, 1024), None);
    }
}
