//! Workflow runtime (pyFlow-equivalent) with WOSS integration.
//!
//! Mirrors §3.4: the runtime owns the DAG, tags files with
//! access-pattern hints derived from the workflow structure, queries the
//! storage's `location` attribute, and schedules tasks location-aware.
//! The Swift personality (per-tag-op task launch cost) is modelled via
//! `Calib::swift_tag_task_ms`.

pub mod dag;
pub mod engine;
pub mod scheduler;

pub use dag::{ReadSpec, TaskSpec, Tier, Workflow, WriteSpec};
pub use engine::{run_workflow, Engine, EngineConfig, RunResult, TaskRecord};
pub use scheduler::{LeastLoaded, LocalityInfo, LocationAware, NodeView, Scheduler};
