//! The workflow execution engine (pyFlow-equivalent).
//!
//! List-scheduling in virtual time: tasks become ready when their
//! producers finish, the scheduler picks a node (optionally
//! location-aware), and the task's life-cycle charges every cost the
//! paper's §4.4 microbenchmark itemizes — forking the tag helper,
//! `set-attribute` round-trips, `get location` queries, the scheduler
//! decision, input reads, compute, output writes. The Swift personality
//! (per-tag-op task launch, `Calib::swift_tag_task_ms`) reproduces the
//! fig11 regression; the pyFlow personality sets it to zero.

use crate::sim::{Cluster, Dur, Metrics, SimTime};
use crate::storage::model::StorageModel;
use crate::storage::types::{NodeId, StorageError};
use crate::util::Rng;
use crate::workflow::dag::{TaskSpec, Tier, Workflow};
use crate::workflow::scheduler::{LocalityInfo, NodeView, Scheduler};
use std::collections::BinaryHeap;
use std::collections::{BTreeMap, HashMap};

/// Engine configuration: which cross-layer steps are performed. The
/// Table 6 overhead ladder is expressed by toggling these.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Runtime tags outputs with the workload's hints (`set-attribute`).
    pub tag_outputs: bool,
    /// Replace every hint with an inert tag (same overhead, no
    /// optimization triggered) — Table 6's "useless tags" rungs.
    pub useless_tags: bool,
    /// Runtime queries `location` for task inputs.
    pub query_location: bool,
    /// Charge the fork of the `setfattr` helper per tag operation (the
    /// prototype's implementation shortcut).
    pub charge_fork: bool,
    /// Fork the helper but skip the actual `set-attribute` RPC — the
    /// "DSS + fork" rung of Table 6.
    pub fork_only: bool,
    /// Service-time jitter spread (run-to-run variance, e.g. 0.03).
    pub jitter: f64,
    /// RNG seed for this run.
    pub seed: u64,
    /// Run stage-in as a separate phase: no workflow task starts before
    /// every `stageIn` task has finished (the paper's scripts stage the
    /// whole dataset, then start the benchmark and time it separately).
    pub stage_in_barrier: bool,
    /// Additionally tag every consumed intermediate output with
    /// `Lifetime=scratch` + `Consumers=<n>` derived from the DAG —
    /// the lifetime protocol's top-down half. The simulated stores
    /// carry the tags (and the run pays the extra `set-attribute`
    /// traffic, batched like every other tag); enforcement itself is a
    /// live-store feature. Off by default so existing figures are
    /// untouched.
    pub tag_lifetime: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            tag_outputs: true,
            useless_tags: false,
            query_location: true,
            charge_fork: true,
            fork_only: false,
            jitter: 0.03,
            seed: 1,
            stage_in_barrier: true,
            tag_lifetime: false,
        }
    }
}

impl EngineConfig {
    /// Full WOSS integration.
    pub fn woss(seed: u64) -> Self {
        EngineConfig {
            seed,
            ..EngineConfig::default()
        }
    }

    /// Plain baseline: no tagging, no location queries (DSS/NFS runs).
    pub fn plain(seed: u64) -> Self {
        EngineConfig {
            tag_outputs: false,
            useless_tags: false,
            query_location: false,
            charge_fork: false,
            fork_only: false,
            jitter: 0.03,
            seed,
            stage_in_barrier: true,
            tag_lifetime: false,
        }
    }
}

/// Execution record for one task.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    /// Task id within the workflow.
    pub id: usize,
    /// Stage label.
    pub stage: String,
    /// Node the task executed on.
    pub node: NodeId,
    /// When every dependency had finished.
    pub ready: SimTime,
    /// When the task's reads began (after tagging + scheduling).
    pub start: SimTime,
    /// When the last output write completed.
    pub end: SimTime,
}

/// Result of one simulated workflow run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// All-tasks makespan, seconds.
    pub makespan: f64,
    /// Per-task execution records.
    pub tasks: Vec<TaskRecord>,
    /// Merged counters (intermediate + backend + engine).
    pub metrics: Metrics,
}

impl RunResult {
    /// Latest finish among tasks whose stage matches.
    pub fn stage_end(&self, stage: &str) -> f64 {
        self.tasks
            .iter()
            .filter(|t| t.stage == stage)
            .map(|t| t.end.as_secs_f64())
            .fold(0.0, f64::max)
    }

    /// Earliest start among tasks whose stage matches.
    pub fn stage_start(&self, stage: &str) -> f64 {
        self.tasks
            .iter()
            .filter(|t| t.stage == stage)
            .map(|t| t.start.as_secs_f64())
            .fold(f64::INFINITY, f64::min)
    }

    /// Wall-clock duration of one stage.
    pub fn stage_duration(&self, stage: &str) -> f64 {
        let s = self.stage_start(stage);
        if s.is_finite() {
            self.stage_end(stage) - s
        } else {
            0.0
        }
    }

    /// Workflow-only span: first start to last finish over tasks that
    /// are neither stage-in nor stage-out. Figures 5–8 report this
    /// ("reports stage-in/out ... separately from the workflow time").
    pub fn workflow_span(&self) -> f64 {
        let core = |t: &TaskRecord| t.stage != "stageIn" && t.stage != "stageOut";
        let start = self
            .tasks
            .iter()
            .filter(|t| core(t))
            .map(|t| t.start.as_secs_f64())
            .fold(f64::INFINITY, f64::min);
        let end = self
            .tasks
            .iter()
            .filter(|t| core(t))
            .map(|t| t.end.as_secs_f64())
            .fold(0.0, f64::max);
        if start.is_finite() {
            end - start
        } else {
            0.0
        }
    }

    /// Percentile of finish times over tasks matching `filter`
    /// (Table 4's "90% of workflow tasks" row).
    pub fn finish_percentile<F: Fn(&TaskRecord) -> bool>(&self, p: f64, filter: F) -> f64 {
        let mut ends: Vec<f64> = self
            .tasks
            .iter()
            .filter(|t| filter(t))
            .map(|t| t.end.as_secs_f64())
            .collect();
        if ends.is_empty() {
            return 0.0;
        }
        ends.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (ends.len() - 1) as f64).round() as usize;
        ends[rank]
    }
}

/// The engine.
pub struct Engine<'a> {
    /// Simulated hardware the run executes on.
    pub cluster: &'a mut Cluster,
    /// Intermediate (scratch) storage under test.
    pub inter: &'a mut dyn StorageModel,
    /// Persistent backend (stage-in source / stage-out sink).
    pub backend: &'a mut dyn StorageModel,
    /// Task-placement policy.
    pub scheduler: &'a mut dyn Scheduler,
    /// Which cross-layer steps the runtime performs.
    pub config: EngineConfig,
}

impl<'a> Engine<'a> {
    /// Execute `workflow` to completion; returns per-task records.
    pub fn run(&mut self, workflow: &Workflow) -> Result<RunResult, StorageError> {
        workflow
            .validate()
            .map_err(StorageError::Invalid)?;
        // Hoisted out of the loop: `cluster_backend()` borrows `self`
        // shared, which must not overlap the `self.cluster` reborrow the
        // write call takes.
        let backend_node = self.cluster_backend();
        for (path, size) in &workflow.backend_preload {
            // Datasets already on the backend: materialize instantly.
            self.backend
                .write_file(self.cluster, backend_node, path, *size, &Default::default(), SimTime::ZERO)?;
        }

        let deps = workflow.dependencies();
        let mut remaining: Vec<usize> = deps.iter().map(|d| d.len()).collect();
        let mut rdeps: Vec<Vec<usize>> = vec![Vec::new(); workflow.tasks.len()];
        for (b, ds) in deps.iter().enumerate() {
            for &a in ds {
                rdeps[a].push(b);
            }
        }
        let mut finish: Vec<Option<SimTime>> = vec![None; workflow.tasks.len()];
        let mut ready_at: Vec<SimTime> = vec![SimTime::ZERO; workflow.tasks.len()];

        let mut rng = Rng::new(self.config.seed);
        let mut records: Vec<Option<TaskRecord>> = vec![None; workflow.tasks.len()];
        let mut engine_metrics = Metrics::new();
        // Finish times of tasks per node: the scheduler's in-flight view.
        let mut node_ends: HashMap<usize, Vec<SimTime>> = HashMap::new();
        // Consumed-intermediate counts for lifetime tagging (empty map
        // when the protocol is off — no per-task cost).
        let lifetime_consumers = if self.config.tag_lifetime {
            workflow.consumer_counts()
        } else {
            BTreeMap::new()
        };

        // Stage-in phase: when the barrier is on, all `stageIn` tasks run
        // to completion before any workflow task becomes ready.
        let mut barrier = SimTime::ZERO;
        if self.config.stage_in_barrier {
            for (id, task) in workflow.tasks.iter().enumerate() {
                if task.stage == "stageIn" && remaining[id] == 0 {
                    let end = self.execute_task(
                        task,
                        SimTime::ZERO,
                        &mut rng,
                        &mut engine_metrics,
                        &mut records,
                        &mut node_ends,
                        &lifetime_consumers,
                    )?;
                    finish[id] = Some(end);
                    barrier = barrier.max(end);
                }
            }
        }

        // Min-heap of (ready time, id).
        let mut heap: BinaryHeap<std::cmp::Reverse<(SimTime, usize)>> = BinaryHeap::new();
        for (id, _task) in workflow.tasks.iter().enumerate() {
            if finish[id].is_some() {
                continue; // already ran in the stage-in phase
            }
            if remaining[id] == 0 {
                heap.push(std::cmp::Reverse((barrier, id)));
            }
        }
        // Credit finished stage-in tasks to their dependents.
        if self.config.stage_in_barrier {
            for (id, f) in finish.clone().iter().enumerate() {
                if let Some(end) = f {
                    for &b in &rdeps[id] {
                        remaining[b] -= 1;
                        ready_at[b] = ready_at[b].max(*end).max(barrier);
                        if remaining[b] == 0 {
                            heap.push(std::cmp::Reverse((ready_at[b], b)));
                        }
                    }
                }
            }
        }

        while let Some(std::cmp::Reverse((ready, id))) = heap.pop() {
            let task = &workflow.tasks[id];
            let end = self.execute_task(
                task,
                ready,
                &mut rng,
                &mut engine_metrics,
                &mut records,
                &mut node_ends,
                &lifetime_consumers,
            )?;
            finish[id] = Some(end);
            for &b in &rdeps[id] {
                remaining[b] -= 1;
                ready_at[b] = ready_at[b].max(end);
                if remaining[b] == 0 {
                    heap.push(std::cmp::Reverse((ready_at[b], b)));
                }
            }
        }

        let makespan = finish
            .iter()
            .map(|f| f.expect("all tasks ran").as_secs_f64())
            .fold(0.0, f64::max);
        let mut metrics = engine_metrics;
        metrics.merge(self.inter.metrics());
        metrics.merge(self.backend.metrics());
        Ok(RunResult {
            makespan,
            tasks: records.into_iter().map(|r| r.expect("recorded")).collect(),
            metrics,
        })
    }

    fn cluster_backend(&self) -> NodeId {
        // Preloads are written "from" the backend endpoint itself: no
        // cluster traffic is charged for data that starts on the backend.
        self.cluster.backend()
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_task(
        &mut self,
        task: &TaskSpec,
        ready: SimTime,
        rng: &mut Rng,
        em: &mut Metrics,
        records: &mut [Option<TaskRecord>],
        node_ends: &mut HashMap<usize, Vec<SimTime>>,
        lifetime_consumers: &BTreeMap<String, u32>,
    ) -> Result<SimTime, StorageError> {
        let calib = self.cluster.calib().clone();
        let mut t = ready + Dur::from_millis_f64(calib.sched_decision_ms);

        // --- location queries (bottom-up channel) ---
        let mut locality = LocalityInfo::default();
        if self.config.query_location && self.scheduler.wants_location() {
            for read in crate::workflow::scheduler::intermediate_reads(task) {
                // Swift personality launches a task per query.
                t = t + Dur::from_millis_f64(calib.swift_tag_task_ms);
                let (_, done) = self.inter.get_xattr(
                    self.cluster,
                    NodeId(0),
                    &read.path,
                    crate::hints::LOCATION_ATTR,
                    t,
                )?;
                t = done;
                let (holders, bytes) = match read.range {
                    Some((off, len)) => (
                        self.inter.locations_range(&read.path, off, len),
                        len,
                    ),
                    None => (
                        self.inter.locations(&read.path),
                        self.inter.file_size(&read.path).unwrap_or(0),
                    ),
                };
                locality.inputs.push((holders, bytes));
            }
        }

        // --- scheduling decision ---
        // Tasks are scheduled in ready-time order (min-heap), so finish
        // times at or before `ready` can be pruned permanently — keeps
        // the in-flight scan O(active) instead of O(all tasks so far)
        // (perf pass, EXPERIMENTS.md §Perf).
        let views: Vec<NodeView> = self
            .cluster
            .nodes()
            .skip(1) // node 0 hosts the manager / coordination scripts
            .map(|n| NodeView {
                node: n,
                next_free: self.cluster.cores[n.0].free_at(),
                in_flight: match node_ends.get_mut(&n.0) {
                    Some(ends) => {
                        ends.retain(|&e| e > ready);
                        ends.len()
                    }
                    None => 0,
                },
            })
            .collect();
        let node = if views.is_empty() {
            NodeId(0)
        } else {
            self.scheduler.pick(task, &views, &locality)
        };
        if !locality.inputs.is_empty() {
            let local = locality
                .inputs
                .iter()
                .any(|(holders, _)| holders.contains(&node));
            if local {
                em.local_placements += 1;
            } else {
                em.remote_placements += 1;
            }
        }

        // --- tag outputs (top-down channel) ---
        // Tags go through the batched set-attribute API: the runtime
        // groups a file's tags into batches of `Calib::setattr_batch` and
        // issues one helper fork + one RPC per batch. The default batch
        // of 1 reproduces the prototype's one-fork-one-RPC-per-tag
        // behaviour (the Table 6 ladder); larger batches amortize the
        // fork, the Swift task launch, and the manager queue slot.
        if self.config.tag_outputs {
            let batch = calib.setattr_batch.max(1);
            for write in &task.writes {
                if write.tier != Tier::Intermediate {
                    continue;
                }
                let mut pairs: Vec<(String, String)> = write
                    .tags
                    .iter()
                    .map(|(key, value)| {
                        if self.config.useless_tags {
                            (format!("junk_{key}"), value.to_string())
                        } else {
                            (key.to_string(), value.to_string())
                        }
                    })
                    .collect();
                // Lifetime protocol, top-down half: declare the DAG's
                // consumer count so an enforcing store could reclaim
                // the intermediate after its last read. Rides the same
                // batched set-attribute path (and pays its cost). A
                // workload-authored Lifetime or Consumers tag is never
                // clobbered — it may declare readers beyond the DAG.
                if self.config.tag_lifetime
                    && write.tags.get(crate::hints::keys::LIFETIME).is_none()
                    && write.tags.get(crate::hints::keys::CONSUMERS).is_none()
                {
                    if let Some(n) = lifetime_consumers.get(&write.path) {
                        pairs.push((crate::hints::keys::LIFETIME.to_string(), "scratch".into()));
                        pairs.push((crate::hints::keys::CONSUMERS.to_string(), n.to_string()));
                    }
                }
                for chunk in pairs.chunks(batch) {
                    if self.config.charge_fork {
                        t = t + Dur::from_millis_f64(calib.fork_ms);
                        em.forks += 1;
                    }
                    if self.config.fork_only {
                        continue; // helper forked, no RPC issued
                    }
                    t = t + Dur::from_millis_f64(calib.swift_tag_task_ms);
                    t = self
                        .inter
                        .set_xattrs_bulk(self.cluster, node, &write.path, chunk, t)?;
                }
            }
        }

        let start = t;

        // --- input reads ---
        for read in &task.reads {
            let storage: &mut dyn StorageModel = match read.tier {
                Tier::Intermediate => self.inter,
                Tier::Backend => self.backend,
            };
            t = match read.range {
                Some((off, len)) => {
                    storage.read_range(self.cluster, node, &read.path, off, len, t)?
                }
                None => storage.read_file(self.cluster, node, &read.path, t)?,
            };
        }

        // --- compute ---
        if task.cpu_secs > 0.0 {
            let secs = rng.jitter(task.cpu_secs, self.config.jitter);
            let span = self.cluster.compute(node, secs, t);
            t = span.end;
        }

        // --- output writes ---
        for write in &task.writes {
            let storage: &mut dyn StorageModel = match write.tier {
                Tier::Intermediate => self.inter,
                Tier::Backend => self.backend,
            };
            // Hints travel through the xattr channel (set above, pending
            // at the manager); the write itself carries none — except
            // when tagging is off entirely (plain DSS/NFS runs), where
            // there are none anyway.
            t = storage.write_file(
                self.cluster,
                node,
                &write.path,
                write.size,
                &Default::default(),
                t,
            )?;
        }

        node_ends.entry(node.0).or_default().push(t);
        records[task.id] = Some(TaskRecord {
            id: task.id,
            stage: task.stage.clone(),
            node,
            ready,
            start,
            end: t,
        });
        Ok(t)
    }
}

/// Convenience wrapper: run `workflow` once over the given pieces.
pub fn run_workflow(
    cluster: &mut Cluster,
    inter: &mut dyn StorageModel,
    backend: &mut dyn StorageModel,
    scheduler: &mut dyn Scheduler,
    config: EngineConfig,
    workflow: &Workflow,
) -> Result<RunResult, StorageError> {
    Engine {
        cluster,
        inter,
        backend,
        scheduler,
        config,
    }
    .run(workflow)
}

/// Aggregate stage-level summary used by several experiment tables.
pub fn stage_table(result: &RunResult) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for t in &result.tasks {
        let e = out.entry(t.stage.clone()).or_insert(0.0f64);
        *e = e.max(t.end.as_secs_f64());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hints::TagSet;
    use crate::nfs::NfsServer;
    use crate::sim::{Calib, DiskKind};
    use crate::storage::standard_deployment;
    use crate::workflow::dag::TaskSpec;
    use crate::workflow::scheduler::{LeastLoaded, LocationAware};

    const MB: u64 = 1024 * 1024;

    /// A 3-stage, 4-wide pipeline with local tags.
    fn pipelines(width: usize, tagged: bool) -> Workflow {
        let mut w = Workflow::new();
        w.preload("/backend/in", 100 * MB);
        for p in 0..width {
            let tags = if tagged {
                TagSet::from_pairs([("DP", "local")])
            } else {
                TagSet::new()
            };
            let stage_in = w.push(
                TaskSpec::new(0, "stageIn")
                    .read("/backend/in", Tier::Backend)
                    .write(&format!("/p{p}/a"), Tier::Intermediate, 100 * MB, tags.clone()),
            );
            let _ = stage_in;
            w.push(
                TaskSpec::new(0, "s1")
                    .read(&format!("/p{p}/a"), Tier::Intermediate)
                    .write(&format!("/p{p}/b"), Tier::Intermediate, 200 * MB, tags.clone())
                    .compute(1.0),
            );
            w.push(
                TaskSpec::new(0, "s2")
                    .read(&format!("/p{p}/b"), Tier::Intermediate)
                    .write(&format!("/p{p}/c"), Tier::Intermediate, 10 * MB, tags.clone())
                    .compute(1.0),
            );
            w.push(
                TaskSpec::new(0, "stageOut")
                    .read(&format!("/p{p}/c"), Tier::Intermediate)
                    .write(&format!("/backend/out{p}"), Tier::Backend, 10 * MB, TagSet::new()),
            );
        }
        w
    }

    fn run_config(
        woss: bool,
    ) -> (RunResult, f64) {
        let calib = Calib::default();
        let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
        let mut inter = standard_deployment(&cluster, woss, true, 7);
        let mut backend = NfsServer::new(&calib);
        let wf = pipelines(4, woss);
        let result = if woss {
            let mut sched = LocationAware::new();
            run_workflow(
                &mut cluster,
                &mut inter,
                &mut backend,
                &mut sched,
                EngineConfig::woss(3),
                &wf,
            )
            .unwrap()
        } else {
            let mut sched = LeastLoaded::new();
            run_workflow(
                &mut cluster,
                &mut inter,
                &mut backend,
                &mut sched,
                EngineConfig::plain(3),
                &wf,
            )
            .unwrap()
        };
        let makespan = result.makespan;
        (result, makespan)
    }

    #[test]
    fn runs_to_completion_and_orders_stages() {
        let (res, makespan) = run_config(true);
        assert_eq!(res.tasks.len(), 16);
        assert!(makespan > 0.0);
        assert!(res.stage_end("stageIn") <= res.stage_end("s1"));
        assert!(res.stage_end("s1") <= res.stage_end("s2"));
        assert!(res.stage_end("s2") <= res.stage_end("stageOut"));
    }

    #[test]
    fn woss_pipeline_beats_dss() {
        let (_, woss) = run_config(true);
        let (_, dss) = run_config(false);
        assert!(
            woss < dss,
            "WOSS ({woss:.2}s) must beat DSS ({dss:.2}s) on the pipeline pattern"
        );
    }

    #[test]
    fn woss_achieves_locality() {
        let (res, _) = run_config(true);
        assert!(
            res.metrics.local_placements > 0,
            "location-aware scheduling found local placements"
        );
        assert!(res.metrics.local_bytes > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (_, a) = run_config(true);
        let (_, b) = run_config(true);
        assert_eq!(a, b, "same seed, same makespan");
    }

    #[test]
    fn percentile_and_stage_helpers() {
        let (res, makespan) = run_config(true);
        let p90 = res.finish_percentile(90.0, |t| t.stage != "stageIn" && t.stage != "stageOut");
        assert!(p90 > 0.0 && p90 <= makespan);
        let table = stage_table(&res);
        assert_eq!(table.len(), 4);
    }

    #[test]
    fn setattr_batching_amortizes_tagging() {
        // A heavily-tagged output: 6 attributes on one intermediate file.
        let build = || {
            let mut w = Workflow::new();
            w.preload("/backend/in", 4 * MB);
            let tags = TagSet::from_pairs([
                ("DP", "local"),
                ("Replication", "2"),
                ("RepSmntc", "optimistic"),
                ("CacheSize", "64M"),
                ("BlockSize", "1M"),
                ("app.provenance", "stage-1"),
            ]);
            w.push(
                TaskSpec::new(0, "stageIn")
                    .read("/backend/in", Tier::Backend)
                    .write("/w/tagged", Tier::Intermediate, 4 * MB, tags),
            );
            w.push(
                TaskSpec::new(0, "s1")
                    .read("/w/tagged", Tier::Intermediate)
                    .write("/w/out", Tier::Intermediate, MB, TagSet::new())
                    .compute(0.1),
            );
            w
        };
        let run = |batch: usize| {
            let mut calib = Calib::default();
            calib.setattr_batch = batch;
            let mut cluster = Cluster::new(6, DiskKind::RamDisk, &calib);
            let mut inter = standard_deployment(&cluster, true, true, 5);
            let mut backend = NfsServer::new(&calib);
            let mut sched = LocationAware::new();
            let cfg = EngineConfig {
                jitter: 0.0,
                ..EngineConfig::woss(5)
            };
            run_workflow(&mut cluster, &mut inter, &mut backend, &mut sched, cfg, &build())
                .unwrap()
        };
        let unbatched = run(1);
        let batched = run(6);
        assert!(
            batched.makespan < unbatched.makespan,
            "batch=6 ({:.4}s) must beat batch=1 ({:.4}s)",
            batched.makespan,
            unbatched.makespan
        );
        // Same attributes reach the store either way.
        assert_eq!(batched.metrics.setattr_ops, unbatched.metrics.setattr_ops);
        // One fork per batch instead of one per tag.
        assert!(batched.metrics.forks < unbatched.metrics.forks);
    }

    #[test]
    fn tag_lifetime_charges_extra_setattr_traffic() {
        let run = |tag_lifetime: bool| {
            let calib = Calib::default();
            let mut cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
            let mut inter = standard_deployment(&cluster, true, true, 7);
            let mut backend = NfsServer::new(&calib);
            let mut sched = LocationAware::new();
            let cfg = EngineConfig {
                tag_lifetime,
                jitter: 0.0,
                ..EngineConfig::woss(9)
            };
            run_workflow(&mut cluster, &mut inter, &mut backend, &mut sched, cfg, &pipelines(2, true))
                .unwrap()
        };
        let plain = run(false);
        let tagged = run(true);
        // Every consumed intermediate gains Lifetime + Consumers: two
        // more set-attribute ops per such file, paid in virtual time.
        assert!(
            tagged.metrics.setattr_ops > plain.metrics.setattr_ops,
            "lifetime tagging must show in the top-down channel: {} vs {}",
            tagged.metrics.setattr_ops,
            plain.metrics.setattr_ops
        );
        assert!(tagged.makespan >= plain.makespan, "the traffic is not free");
        assert_eq!(tagged.tasks.len(), plain.tasks.len());
    }

    #[test]
    fn swift_personality_slower() {
        let calib = Calib::default();
        let mut swift_calib = calib.clone();
        swift_calib.swift_tag_task_ms = 50.0;

        let mut c1 = Cluster::new(8, DiskKind::RamDisk, &calib);
        let mut i1 = standard_deployment(&c1, true, true, 7);
        let mut b1 = NfsServer::new(&calib);
        let mut s1 = LocationAware::new();
        let r1 = run_workflow(&mut c1, &mut i1, &mut b1, &mut s1, EngineConfig::woss(3), &pipelines(4, true)).unwrap();

        let mut c2 = Cluster::new(8, DiskKind::RamDisk, &swift_calib);
        let mut i2 = standard_deployment(&c2, true, true, 7);
        let mut b2 = NfsServer::new(&swift_calib);
        let mut s2 = LocationAware::new();
        let r2 = run_workflow(&mut c2, &mut i2, &mut b2, &mut s2, EngineConfig::woss(3), &pipelines(4, true)).unwrap();

        assert!(r2.makespan > r1.makespan, "swift tag-task overhead must show");
    }
}
