//! Task schedulers.
//!
//! The paper's integration modifies pyFlow/Swift to (a) tag files with
//! access-pattern hints and (b) query the storage's `location` attribute
//! and schedule the consuming task on a node that holds the data. Both
//! schedulers here implement the same simple heuristics the paper calls
//! "relatively naïve" — round-robin/least-loaded without locality
//! (baseline) vs locality-first (WOSS integration).

use crate::sim::SimTime;
use crate::storage::types::NodeId;
use crate::workflow::dag::{ReadSpec, TaskSpec, Tier};

/// The engine's per-node view offered to schedulers.
#[derive(Debug, Clone)]
pub struct NodeView {
    /// The node this view describes.
    pub node: NodeId,
    /// When the node's cores are estimated to be next free.
    pub next_free: SimTime,
    /// Tasks assigned to this node that have not finished yet (the
    /// engine's own bookkeeping — the robust load signal).
    pub in_flight: usize,
}

/// Input-locality information for a task: per read, the nodes holding
/// the data and the byte count (empty when the storage does not expose
/// location — DSS/NFS).
#[derive(Debug, Clone, Default)]
pub struct LocalityInfo {
    /// (holders, bytes) per intermediate read.
    pub inputs: Vec<(Vec<NodeId>, u64)>,
}

/// Scheduler decision surface.
pub trait Scheduler: Send {
    /// Name for reports.
    fn name(&self) -> &'static str;
    /// Pick a node for `task`. `nodes` is never empty.
    fn pick(
        &mut self,
        task: &TaskSpec,
        nodes: &[NodeView],
        locality: &LocalityInfo,
    ) -> NodeId;
    /// Whether this scheduler wants the engine to pay for `location`
    /// queries (WOSS integration does; the baseline does not).
    fn wants_location(&self) -> bool {
        false
    }
}

/// Baseline: least-loaded, round-robin tie-break. This is what pyFlow
/// and Swift do without the WOSS integration.
pub struct LeastLoaded {
    cursor: usize,
}

impl LeastLoaded {
    /// Fresh scheduler with the rotation cursor at zero.
    pub fn new() -> Self {
        LeastLoaded { cursor: 0 }
    }
}

impl Default for LeastLoaded {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(
        &mut self,
        task: &TaskSpec,
        nodes: &[NodeView],
        _locality: &LocalityInfo,
    ) -> NodeId {
        if let Some(pin) = task.pin {
            return pin;
        }
        let min_load = nodes.iter().map(|n| n.in_flight).min().expect("non-empty");
        // Rotate over the *stable node order*, not the tie-set: indexing
        // the tie-set by a shared cursor could starve a member outright
        // whenever the tie-set size varied between calls (cursor % 2 vs
        // cursor % 3 land on different nodes for the same cursor).
        // Scanning from a monotonically advancing start slot guarantees
        // every tie member is picked at least once per lap of the
        // cursor (no starvation) and is perfectly even when the tie
        // spans the whole pool; a persistent interior gap in the
        // tie-set can still skew the split — acceptable for the
        // paper's "relatively naïve" baseline heuristic.
        let n = nodes.len();
        let start = self.cursor % n;
        self.cursor = self.cursor.wrapping_add(1);
        (0..n)
            .map(|i| &nodes[(start + i) % n])
            .find(|v| v.in_flight == min_load)
            .expect("a node carrying the minimum load exists")
            .node
    }
}

/// WOSS integration: schedule on the node holding the most input bytes,
/// provided it is not overloaded relative to the least-loaded node;
/// otherwise fall back to least-loaded.
pub struct LocationAware {
    fallback: LeastLoaded,
    /// Don't chase locality onto a node more than this many tasks deeper
    /// than the least-loaded node (naïve heuristic, per the paper).
    pub max_queue: usize,
    /// Ignore gravity below this many bytes: moving a few hundred KB is
    /// cheaper than unbalancing the compute placement.
    pub min_gravity_bytes: f64,
}

impl LocationAware {
    /// Scheduler with the paper's naïve defaults (queue budget 4,
    /// 8 MB gravity floor).
    pub fn new() -> Self {
        LocationAware {
            fallback: LeastLoaded::new(),
            max_queue: 4,
            min_gravity_bytes: 8.0 * 1024.0 * 1024.0,
        }
    }
}

impl Default for LocationAware {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for LocationAware {
    fn name(&self) -> &'static str {
        "location-aware"
    }

    fn wants_location(&self) -> bool {
        true
    }

    fn pick(
        &mut self,
        task: &TaskSpec,
        nodes: &[NodeView],
        locality: &LocalityInfo,
    ) -> NodeId {
        if let Some(pin) = task.pin {
            return pin;
        }
        // Score nodes by local input bytes. A file striped over k
        // holders contributes bytes/k to each — a fully-striped file is
        // weak gravity, a `DP=local`/collocated file is strong gravity.
        let mut scores: Vec<(NodeId, f64)> = Vec::new();
        for (holders, bytes) in &locality.inputs {
            if holders.is_empty() {
                continue;
            }
            let share = *bytes as f64 / holders.len() as f64;
            for h in holders {
                match scores.iter_mut().find(|(n, _)| n == h) {
                    Some((_, b)) => *b += share,
                    None => scores.push((*h, share)),
                }
            }
        }
        let min_load = nodes
            .iter()
            .map(|n| n.in_flight)
            .min()
            .unwrap_or(0);
        let best = scores.iter().map(|(_, b)| *b).fold(0.0f64, f64::max);
        if best < self.min_gravity_bytes {
            return self.fallback.pick(task, nodes, locality);
        }
        // Among near-equally attractive holders (replicas of a broadcast
        // file, stripes of equal size), spread load: pick the least
        // loaded, provided it is within the queue budget.
        let mut candidates: Vec<(NodeId, usize)> = scores
            .iter()
            .filter(|(_, b)| *b >= 0.99 * best)
            .filter_map(|(n, _)| {
                nodes
                    .iter()
                    .find(|v| v.node == *n)
                    .map(|v| (*n, v.in_flight))
            })
            .collect();
        candidates.sort_by_key(|&(n, load)| (load, n));
        if let Some(&(node, load)) = candidates.first() {
            if load <= min_load + self.max_queue {
                return node;
            }
        }
        self.fallback.pick(task, nodes, locality)
    }
}

/// Overhead-probe scheduler (Table 6's "get location" rung): pays for
/// `location` queries like the WOSS integration but schedules exactly
/// like [`LeastLoaded`] — isolating the query cost from its benefit.
pub struct ProbeLocation {
    inner: LeastLoaded,
}

impl ProbeLocation {
    /// Fresh probe scheduler.
    pub fn new() -> Self {
        ProbeLocation {
            inner: LeastLoaded::new(),
        }
    }
}

impl Default for ProbeLocation {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for ProbeLocation {
    fn name(&self) -> &'static str {
        "probe-location"
    }

    fn wants_location(&self) -> bool {
        true
    }

    fn pick(
        &mut self,
        task: &TaskSpec,
        nodes: &[NodeView],
        locality: &LocalityInfo,
    ) -> NodeId {
        self.inner.pick(task, nodes, locality)
    }
}

/// Extract the intermediate-tier reads a locality query covers.
pub fn intermediate_reads(task: &TaskSpec) -> Vec<&ReadSpec> {
    task.reads
        .iter()
        .filter(|r| r.tier == Tier::Intermediate)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::dag::TaskSpec;

    /// Views where each entry is (next_free_secs, in_flight).
    fn views(free: &[f64]) -> Vec<NodeView> {
        free.iter()
            .enumerate()
            .map(|(i, &f)| NodeView {
                node: NodeId(i + 1),
                next_free: SimTime::from_secs_f64(f),
                in_flight: f.round() as usize,
            })
            .collect()
    }

    #[test]
    fn least_loaded_picks_idle() {
        let mut s = LeastLoaded::new();
        let node = s.pick(
            &TaskSpec::new(0, "t"),
            &views(&[3.0, 0.0, 5.0]),
            &LocalityInfo::default(),
        );
        assert_eq!(node, NodeId(2));
    }

    #[test]
    fn least_loaded_rotates_ties() {
        let mut s = LeastLoaded::new();
        let v = views(&[0.0, 0.0, 0.0]);
        let picks: Vec<_> = (0..3)
            .map(|_| s.pick(&TaskSpec::new(0, "t"), &v, &LocalityInfo::default()).0)
            .collect();
        assert_eq!(picks, vec![1, 2, 3]);
    }

    #[test]
    fn least_loaded_spreads_evenly_when_tie_set_varies() {
        // Regression: the old rotation indexed the tie-set by a shared
        // cursor, so alternating tie-set sizes skewed the spread. Here
        // every even call sees 4 tied nodes and every odd call sees the
        // same 4 — but interleaved with picks over a 2-node tie-set the
        // old code would double-pick some nodes and starve others.
        let mut s = LeastLoaded::new();
        let all_tied = views(&[0.0, 0.0, 0.0, 0.0]);
        let mut counts = [0usize; 4];
        for round in 0..8 {
            // Interleave a call over a smaller tie-set to perturb the
            // cursor the way a real varying workload does.
            if round % 2 == 1 {
                let _ = s.pick(
                    &TaskSpec::new(0, "t"),
                    &views(&[0.0, 0.0, 5.0, 5.0]),
                    &LocalityInfo::default(),
                );
            }
            let node = s.pick(&TaskSpec::new(0, "t"), &all_tied, &LocalityInfo::default());
            counts[node.0 - 1] += 1;
        }
        // 8 all-tied picks over 4 nodes: stable-order rotation gives each
        // node exactly 2, regardless of the interleaved small-tie calls.
        assert_eq!(counts, [2, 2, 2, 2], "uneven spread: {counts:?}");
    }

    #[test]
    fn least_loaded_never_starves_a_tie_member() {
        // Regression: alternating a unique-minimum call with a two-node
        // tie call left the old tie-set indexing at `cursor % 2 == 0` on
        // every tie call — the first tie member got ALL the work. The
        // stable-order rotation must keep both members in play.
        let mut s = LeastLoaded::new();
        let tie = views(&[0.0, 0.0, 9.0]);
        let unique = views(&[9.0, 9.0, 0.0]);
        let mut counts = [0usize; 2];
        for _ in 0..6 {
            let node = s.pick(&TaskSpec::new(0, "t"), &tie, &LocalityInfo::default());
            counts[node.0 - 1] += 1;
            let u = s.pick(&TaskSpec::new(0, "t"), &unique, &LocalityInfo::default());
            assert_eq!(u, NodeId(3), "a unique minimum always wins");
        }
        assert!(
            counts[0] >= 2 && counts[1] >= 2,
            "a tie member was starved: {counts:?}"
        );
    }

    #[test]
    fn pinned_task_respected() {
        let mut s = LocationAware::new();
        let t = TaskSpec::new(0, "t").pin_to(NodeId(9));
        assert_eq!(
            s.pick(&t, &views(&[0.0]), &LocalityInfo::default()),
            NodeId(9)
        );
    }

    #[test]
    fn location_aware_follows_data() {
        let mut s = LocationAware::new();
        let loc = LocalityInfo {
            inputs: vec![(vec![NodeId(3)], 100 << 20)],
        };
        let node = s.pick(&TaskSpec::new(0, "t"), &views(&[0.0, 0.0, 1.0]), &loc);
        assert_eq!(node, NodeId(3), "data gravity beats 1s of queueing");
    }

    #[test]
    fn location_aware_abandons_overloaded_holder() {
        let mut s = LocationAware::new();
        let loc = LocalityInfo {
            inputs: vec![(vec![NodeId(3)], 100 << 20)],
        };
        let node = s.pick(&TaskSpec::new(0, "t"), &views(&[0.0, 0.0, 60.0]), &loc);
        assert_ne!(node, NodeId(3), "60s queue exceeds the wait budget");
    }

    #[test]
    fn location_aware_without_info_falls_back() {
        let mut s = LocationAware::new();
        let node = s.pick(
            &TaskSpec::new(0, "t"),
            &views(&[1.0, 0.0]),
            &LocalityInfo::default(),
        );
        assert_eq!(node, NodeId(2));
    }

    #[test]
    fn multi_input_gravity_sums() {
        let mut s = LocationAware::new();
        const MB: u64 = 1 << 20;
        let loc = LocalityInfo {
            inputs: vec![
                (vec![NodeId(1)], 10 * MB),
                (vec![NodeId(2)], 6 * MB),
                (vec![NodeId(2)], 6 * MB),
            ],
        };
        let node = s.pick(&TaskSpec::new(0, "t"), &views(&[0.0, 0.0]), &loc);
        assert_eq!(node, NodeId(2), "12 MB on n2 beat 10 MB on n1");
    }

    #[test]
    fn tiny_gravity_ignored() {
        let mut s = LocationAware::new();
        // 150 KB of gravity on a node 3 tasks deep: load wins.
        let loc = LocalityInfo {
            inputs: vec![(vec![NodeId(2)], 150 * 1024)],
        };
        let node = s.pick(&TaskSpec::new(0, "t"), &views(&[0.0, 3.0]), &loc);
        assert_eq!(node, NodeId(1), "tiny files must not drive placement");
    }
}
