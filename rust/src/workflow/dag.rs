//! Workflow DAG specification.
//!
//! A workflow is a set of tasks communicating through intermediary files
//! (the many-task model of §2). Dependencies are derived from the
//! producer/consumer relation over file paths — a task is ready when
//! every file it reads from intermediate storage has been produced.
//! Stage-in/out tasks cross the backend boundary (dashed line in the
//! paper's Figure 4).

use crate::hints::TagSet;
use crate::storage::types::NodeId;
use std::collections::{BTreeMap, BTreeSet};

/// Where a file access is served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// The shared intermediate (scratch) storage under evaluation.
    Intermediate,
    /// The persistent backend (NFS / GPFS).
    Backend,
}

/// One file read performed by a task.
#[derive(Debug, Clone)]
pub struct ReadSpec {
    /// File path read.
    pub path: String,
    /// Tier the read is served from.
    pub tier: Tier,
    /// Byte range; `None` reads the whole file (scatter readers use
    /// disjoint ranges).
    pub range: Option<(u64, u64)>,
}

/// One file write performed by a task.
#[derive(Debug, Clone)]
pub struct WriteSpec {
    /// File path written.
    pub path: String,
    /// Tier the write lands on.
    pub tier: Tier,
    /// Bytes written.
    pub size: u64,
    /// Cross-layer hints the runtime attaches to this output.
    pub tags: TagSet,
}

/// One workflow task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Unique id within the workflow.
    pub id: usize,
    /// Stage label ("stageIn", "mProject", "dock", ...).
    pub stage: String,
    /// Files read.
    pub reads: Vec<ReadSpec>,
    /// Files written.
    pub writes: Vec<WriteSpec>,
    /// Pure compute time (seconds on the reference cluster CPU).
    pub cpu_secs: f64,
    /// Pin execution to a node (stage-in scripts, manager-side merges);
    /// `None` lets the scheduler choose.
    pub pin: Option<NodeId>,
}

impl TaskSpec {
    /// New task with the given id and stage label.
    pub fn new(id: usize, stage: &str) -> Self {
        TaskSpec {
            id,
            stage: stage.to_string(),
            reads: Vec::new(),
            writes: Vec::new(),
            cpu_secs: 0.0,
            pin: None,
        }
    }

    /// Add a whole-file read.
    pub fn read(mut self, path: &str, tier: Tier) -> Self {
        self.reads.push(ReadSpec {
            path: path.to_string(),
            tier,
            range: None,
        });
        self
    }

    /// Add a range read (scatter consumers).
    pub fn read_range(mut self, path: &str, tier: Tier, offset: u64, len: u64) -> Self {
        self.reads.push(ReadSpec {
            path: path.to_string(),
            tier,
            range: Some((offset, len)),
        });
        self
    }

    /// Add a write.
    pub fn write(mut self, path: &str, tier: Tier, size: u64, tags: TagSet) -> Self {
        self.writes.push(WriteSpec {
            path: path.to_string(),
            tier,
            size,
            tags,
        });
        self
    }

    /// Set compute time.
    pub fn compute(mut self, cpu_secs: f64) -> Self {
        self.cpu_secs = cpu_secs;
        self
    }

    /// Pin to a node.
    pub fn pin_to(mut self, node: NodeId) -> Self {
        self.pin = Some(node);
        self
    }
}

/// A whole workflow.
#[derive(Debug, Clone, Default)]
pub struct Workflow {
    /// Tasks, indexed by id.
    pub tasks: Vec<TaskSpec>,
    /// Files resident on the backend before the run (stage-in sources).
    pub backend_preload: Vec<(String, u64)>,
}

impl Workflow {
    /// Empty workflow.
    pub fn new() -> Self {
        Workflow::default()
    }

    /// Append a task, assigning its id.
    pub fn push(&mut self, mut task: TaskSpec) -> usize {
        let id = self.tasks.len();
        task.id = id;
        self.tasks.push(task);
        id
    }

    /// Declare a backend-resident input dataset.
    pub fn preload(&mut self, path: &str, size: u64) {
        self.backend_preload.push((path.to_string(), size));
    }

    /// Derive dependency edges: task B depends on task A when A writes a
    /// file (on either tier) that B reads. Returns `deps[b] = {a, ...}`.
    pub fn dependencies(&self) -> Vec<BTreeSet<usize>> {
        let mut producer: BTreeMap<&str, usize> = BTreeMap::new();
        for t in &self.tasks {
            for w in &t.writes {
                producer.insert(w.path.as_str(), t.id);
            }
        }
        self.tasks
            .iter()
            .map(|t| {
                t.reads
                    .iter()
                    .filter_map(|r| producer.get(r.path.as_str()).copied())
                    .filter(|&p| p != t.id)
                    .collect()
            })
            .collect()
    }

    /// Validate: every intermediate read has a producer or preload, and
    /// the dependency graph is acyclic. Returns a topological order.
    pub fn validate(&self) -> Result<Vec<usize>, String> {
        let preloaded: BTreeSet<&str> = self
            .backend_preload
            .iter()
            .map(|(p, _)| p.as_str())
            .collect();
        let produced: BTreeSet<&str> = self
            .tasks
            .iter()
            .flat_map(|t| t.writes.iter().map(|w| w.path.as_str()))
            .collect();
        for t in &self.tasks {
            for r in &t.reads {
                if !produced.contains(r.path.as_str()) && !preloaded.contains(r.path.as_str()) {
                    return Err(format!(
                        "task {} ({}) reads {} which nothing produces",
                        t.id, t.stage, r.path
                    ));
                }
            }
        }
        // Kahn topological sort.
        let deps = self.dependencies();
        let mut indeg: Vec<usize> = deps.iter().map(BTreeSet::len).collect();
        let mut rdeps: Vec<Vec<usize>> = vec![Vec::new(); self.tasks.len()];
        for (b, ds) in deps.iter().enumerate() {
            for &a in ds {
                rdeps[a].push(b);
            }
        }
        let mut queue: Vec<usize> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(self.tasks.len());
        while let Some(t) = queue.pop() {
            order.push(t);
            for &b in &rdeps[t] {
                indeg[b] -= 1;
                if indeg[b] == 0 {
                    queue.push(b);
                }
            }
        }
        if order.len() != self.tasks.len() {
            return Err("workflow has a dependency cycle".to_string());
        }
        Ok(order)
    }

    /// Read operations per intermediate path across the whole workflow
    /// — the declared consumer count the runtime attaches via
    /// `Consumers=<n>` when lifetime tagging is on. Counts read
    /// *operations* (a task listing a path twice counts twice), so one
    /// decrement per storage read lands at exactly zero after the last
    /// consumer; backend-tier reads are excluded (stage-in sources are
    /// not workflow scratch).
    pub fn consumer_counts(&self) -> BTreeMap<String, u32> {
        let mut counts: BTreeMap<String, u32> = BTreeMap::new();
        for t in &self.tasks {
            for r in &t.reads {
                if r.tier == Tier::Intermediate {
                    *counts.entry(r.path.clone()).or_insert(0) += 1;
                }
            }
        }
        counts
    }

    /// Total bytes written by all tasks (workload characterization).
    pub fn bytes_written(&self) -> u64 {
        self.tasks
            .iter()
            .flat_map(|t| t.writes.iter().map(|w| w.size))
            .sum()
    }

    /// Distinct stage labels in task order.
    pub fn stages(&self) -> Vec<String> {
        let mut seen = BTreeSet::new();
        let mut out = Vec::new();
        for t in &self.tasks {
            if seen.insert(t.stage.clone()) {
                out.push(t.stage.clone());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipeline3() -> Workflow {
        let mut w = Workflow::new();
        w.preload("/in", 1024);
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/in", Tier::Backend)
                .write("/a", Tier::Intermediate, 1024, TagSet::new()),
        );
        w.push(
            TaskSpec::new(0, "s1")
                .read("/a", Tier::Intermediate)
                .write("/b", Tier::Intermediate, 2048, TagSet::new())
                .compute(1.0),
        );
        w.push(
            TaskSpec::new(0, "stageOut")
                .read("/b", Tier::Intermediate)
                .write("/out", Tier::Backend, 2048, TagSet::new()),
        );
        w
    }

    #[test]
    fn dependencies_via_files() {
        let w = pipeline3();
        let deps = w.dependencies();
        assert!(deps[0].is_empty());
        assert_eq!(deps[1], BTreeSet::from([0]));
        assert_eq!(deps[2], BTreeSet::from([1]));
    }

    #[test]
    fn validates_and_orders() {
        let w = pipeline3();
        let order = w.validate().unwrap();
        let pos = |id: usize| order.iter().position(|&t| t == id).unwrap();
        assert!(pos(0) < pos(1));
        assert!(pos(1) < pos(2));
    }

    #[test]
    fn missing_producer_rejected() {
        let mut w = Workflow::new();
        w.push(TaskSpec::new(0, "t").read("/ghost", Tier::Intermediate));
        assert!(w.validate().is_err());
    }

    #[test]
    fn cycle_rejected() {
        let mut w = Workflow::new();
        w.push(
            TaskSpec::new(0, "a")
                .read("/y", Tier::Intermediate)
                .write("/x", Tier::Intermediate, 1, TagSet::new()),
        );
        w.push(
            TaskSpec::new(0, "b")
                .read("/x", Tier::Intermediate)
                .write("/y", Tier::Intermediate, 1, TagSet::new()),
        );
        assert!(w.validate().is_err());
    }

    #[test]
    fn characterization() {
        let w = pipeline3();
        assert_eq!(w.bytes_written(), 1024 + 2048 + 2048);
        assert_eq!(w.stages(), vec!["stageIn", "s1", "stageOut"]);
    }

    #[test]
    fn consumer_counts_count_reads_not_tasks() {
        let mut w = pipeline3(); // /a read once, /b read once, /in is backend
        w.push(
            TaskSpec::new(0, "audit")
                .read("/a", Tier::Intermediate)
                .read("/a", Tier::Intermediate),
        );
        let counts = w.consumer_counts();
        assert_eq!(counts.get("/a"), Some(&3), "1 pipeline read + 2 audit reads");
        assert_eq!(counts.get("/b"), Some(&1));
        assert_eq!(counts.get("/in"), None, "backend reads excluded");
        assert_eq!(counts.get("/out"), None, "never-read outputs untracked");
    }
}
