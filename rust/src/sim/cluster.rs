//! Simulated cluster hardware: nodes (cores + device) on a fabric.
//!
//! This is the virtual testbed every storage configuration is deployed
//! onto. Matching the paper's methodology, node 0 hosts the metadata
//! manager / coordination scripts, a dedicated *backend* endpoint hosts
//! the NFS server or GPFS I/O-server pool, and the remaining nodes run
//! storage nodes + SAI + application tasks.

use super::calib::Calib;
use super::disk::{Disk, DiskKind};
use super::net::Fabric;
use super::resource::MultiResource;
use super::time::{Dur, SimTime, Span};
use crate::storage::types::NodeId;

/// Simulated hardware state for one deployment.
#[derive(Debug)]
pub struct Cluster {
    /// Interconnect. Index space: `0..n_nodes` are cluster nodes,
    /// `n_nodes` is the backend server endpoint.
    pub fabric: Fabric,
    /// Per-cluster-node device (index = node id).
    pub disks: Vec<Disk>,
    /// Per-cluster-node CPU cores.
    pub cores: Vec<MultiResource>,
    /// Backend storage endpoint id (NFS server / GPFS pool).
    backend: NodeId,
    n_nodes: usize,
    calib: Calib,
}

impl Cluster {
    /// Build a cluster of `n_nodes` whose storage nodes use `disk_kind`,
    /// plus one backend endpoint with its own NIC.
    pub fn new(n_nodes: usize, disk_kind: DiskKind, calib: &Calib) -> Self {
        assert!(n_nodes >= 1, "cluster needs at least one node");
        let mut bws = vec![calib.nic_bw; n_nodes];
        bws.push(calib.nfs_nic_bw); // backend endpoint
        let fabric = Fabric::new_with_stream(&bws, calib.net_latency(), calib.tcp_stream_bw);
        let disks = (0..n_nodes)
            .map(|_| Disk::new(disk_kind, &calib.disk))
            .collect();
        let cores = (0..n_nodes)
            .map(|_| MultiResource::new(calib.cores_per_node))
            .collect();
        Cluster {
            fabric,
            disks,
            cores,
            backend: NodeId(n_nodes),
            n_nodes,
            calib: calib.clone(),
        }
    }

    /// Number of cluster nodes (excludes the backend endpoint).
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// The backend endpoint id.
    pub fn backend(&self) -> NodeId {
        self.backend
    }

    /// Calibration this cluster was built with.
    pub fn calib(&self) -> &Calib {
        &self.calib
    }

    /// All cluster node ids.
    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        (0..self.n_nodes).map(NodeId)
    }

    /// Run `cpu_secs` of compute on `node`, not before `earliest`.
    /// Applies the testbed's CPU slowdown factor (BG/P cores).
    pub fn compute(&mut self, node: NodeId, cpu_secs: f64, earliest: SimTime) -> Span {
        let dur = Dur::from_secs_f64(cpu_secs).scale(self.calib.cpu_slowdown);
        self.cores[node.0].acquire(earliest, dur)
    }

    /// Charge the client-side FUSE/VFS per-call overhead.
    pub fn fuse_op(&self, earliest: SimTime) -> SimTime {
        earliest + Dur::from_millis_f64(self.calib.fuse_op_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout() {
        let c = Cluster::new(20, DiskKind::Spinning, &Calib::default());
        assert_eq!(c.n_nodes(), 20);
        assert_eq!(c.backend(), NodeId(20));
        assert_eq!(c.fabric.len(), 21);
        assert_eq!(c.disks.len(), 20);
        assert_eq!(c.nodes().count(), 20);
    }

    #[test]
    fn compute_uses_cores() {
        let mut c = Cluster::new(2, DiskKind::RamDisk, &Calib::default());
        // 4 cores: 5 one-second jobs → two waves on one core
        let spans: Vec<_> = (0..5)
            .map(|_| c.compute(NodeId(0), 1.0, SimTime::ZERO))
            .collect();
        let max_end = spans.iter().map(|s| s.end).max().unwrap();
        assert!((max_end.as_secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn bgp_slowdown_applied() {
        let mut c = Cluster::new(2, DiskKind::RamDisk, &Calib::bgp());
        let s = c.compute(NodeId(0), 1.0, SimTime::ZERO);
        assert!((s.dur().as_secs_f64() - 2.5).abs() < 1e-6);
    }

    #[test]
    fn fuse_overhead() {
        let c = Cluster::new(1, DiskKind::RamDisk, &Calib::default());
        let t = c.fuse_op(SimTime::ZERO);
        assert!((t.as_secs_f64() - 0.15e-3).abs() < 1e-9);
    }
}
