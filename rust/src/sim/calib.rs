//! Calibration constants for the simulated testbeds.
//!
//! Defaults reproduce the paper's §4 testbeds: a 20-node cluster (Intel
//! Xeon E5345 4-core, 1 Gbps NIC, RAID-1 SATA or RAM-disk), a
//! better-provisioned NFS server (8 cores, RAID-5 ×6, big page cache),
//! and one BG/P rack (850 MHz quad-core, RAM-disk only, GPFS backend with
//! 24 I/O servers). All values are overridable through the coordinator's
//! config file (`woss --config testbed.toml`); EXPERIMENTS.md reports the
//! values each figure was generated with.

use super::disk::DiskCalib;

const MB: f64 = 1024.0 * 1024.0;
const GB: f64 = 1024.0 * MB;

/// Full calibration for one simulated deployment.
#[derive(Debug, Clone)]
pub struct Calib {
    // ---- interconnect ----
    /// Compute/storage node NIC bandwidth, bytes/s per direction (1 Gbps).
    pub nic_bw: f64,
    /// Per-message propagation latency, microseconds.
    pub net_latency_us: f64,
    /// Effective per-flow streaming rate, bytes/s: protocol + copy
    /// overhead caps what one TCP stream through the SAI achieves even
    /// on an idle 1 Gbps link (the era's measured MosaStore/NFS
    /// single-stream rates). Local (same-node) access bypasses this.
    pub tcp_stream_bw: f64,

    // ---- node hardware ----
    /// CPU cores per node usable by workflow tasks.
    pub cores_per_node: usize,
    /// Multiplier on task service times (BG/P's 850 MHz cores vs the
    /// cluster's 2.33 GHz Xeons ⇒ ~2.5).
    pub cpu_slowdown: f64,
    /// Device-level constants.
    pub disk: DiskCalib,

    // ---- client SAI ----
    /// FUSE/VFS overhead per file-system call, ms (the prototype's
    /// acknowledged per-call FUSE cost).
    pub fuse_op_ms: f64,
    /// Client OS page cache, bytes: a file a client has fully read
    /// re-reads from local memory (below-FUSE kernel caching; NFS client
    /// caching). Zero disables.
    pub os_cache_bytes: u64,
    /// Chunk (block) size in bytes; the scatter hint overrides per file.
    pub chunk_size: u64,
    /// Default data-placement stripe width: a new file's chunks stripe
    /// round-robin over this many storage nodes (MosaStore-style). Hints
    /// override per file (local = 1 node, scatter = explicit layout).
    pub default_stripe_width: usize,

    // ---- metadata manager ----
    /// Cost of one metadata operation at the manager, ms.
    pub manager_op_ms: f64,
    /// Cost of one `set-attribute` operation at the manager, ms. The
    /// prototype's implementation is notably slower here (Table 6 shows
    /// tagging as the dominant overhead) — it both serializes and does
    /// more work per call than a plain metadata op.
    pub manager_setattr_ms: f64,
    /// Manager-side parallelism for general metadata ops.
    pub manager_parallelism: usize,
    /// The prototype serializes `set-attribute` calls in a single queue —
    /// the dominant overhead in Table 6. `true` reproduces that.
    pub manager_setattr_serialized: bool,
    /// Number of metadata shards. Each shard owns a slice of the
    /// namespace (keyed by file-path hash) with its own worker pool and
    /// `set-attribute` queue, so metadata load spreads instead of
    /// funneling through one queue. `1` reproduces the paper's
    /// centralized manager (the Table 6 configuration).
    pub manager_shards: usize,
    /// Maximum attributes carried per batched `set-attribute` RPC issued
    /// by the workflow runtime. `1` reproduces the prototype's
    /// one-RPC-per-tag behaviour (Table 6); larger values amortize the
    /// fork + RPC + queue-slot cost across a file's whole tag set.
    pub setattr_batch: usize,

    // ---- workflow-runtime integration overheads (Table 6 / fig11) ----
    /// Cost of forking a helper process to run `setfattr`, ms.
    pub fork_ms: f64,
    /// Swift personality: every tag/get-location op is scheduled as a
    /// Swift task, ms per op (reproduces the BG/P fig11 regression).
    pub swift_tag_task_ms: f64,
    /// Scheduler decision cost per task, ms.
    pub sched_decision_ms: f64,

    // ---- NFS baseline server ----
    /// NFS server NIC bandwidth, bytes/s (same 1 Gbps fabric).
    pub nfs_nic_bw: f64,
    /// NFS server page-cache size, bytes (8 GB RAM machine).
    pub nfs_cache_bytes: u64,
    /// NFS per-operation server overhead, ms.
    pub nfs_op_ms: f64,

    // ---- GPFS backend (BG/P) ----
    /// Number of GPFS I/O servers.
    pub gpfs_servers: usize,
    /// Per-I/O-server sustained bandwidth, bytes/s.
    pub gpfs_server_bw: f64,
    /// GPFS per-operation overhead, ms. Small-file operations from
    /// thousands of concurrent many-task clients hit GPFS's metadata
    /// path hard (the effect §2's storage-bottleneck citations document);
    /// this per-op cost is what DSS's intermediate tier avoids.
    pub gpfs_op_ms: f64,
}

impl Default for Calib {
    fn default() -> Self {
        Calib {
            nic_bw: 117.0 * MB, // 1 Gbps payload rate
            net_latency_us: 100.0,
            tcp_stream_bw: 80.0 * MB,
            cores_per_node: 4,
            cpu_slowdown: 1.0,
            disk: DiskCalib::default(),
            fuse_op_ms: 0.15,
            os_cache_bytes: 2 << 30,
            chunk_size: 1024 * 1024,
            default_stripe_width: 4,
            manager_op_ms: 0.2,
            manager_setattr_ms: 4.0,
            manager_parallelism: 4,
            manager_setattr_serialized: true,
            manager_shards: 1,
            setattr_batch: 1,
            fork_ms: 1.0,
            swift_tag_task_ms: 0.0, // pyFlow personality by default
            sched_decision_ms: 0.1,
            nfs_nic_bw: 117.0 * MB,
            nfs_cache_bytes: 6 * GB as u64,
            nfs_op_ms: 0.3,
            gpfs_servers: 24,
            gpfs_server_bw: 400.0 * MB,
            gpfs_op_ms: 25.0,
        }
    }
}

impl Calib {
    /// The paper's 20-node lab cluster.
    pub fn cluster() -> Self {
        Calib::default()
    }

    /// One BG/P rack: slower cores, RAM-disk only nodes, GPFS backend,
    /// and the Swift integration's per-tag-op task-launch overhead.
    pub fn bgp() -> Self {
        Calib {
            cores_per_node: 4,
            cpu_slowdown: 2.5,
            // BG/P tree/torus links are fast; keep 10 Gbps-class I/O paths.
            nic_bw: 350.0 * MB,
            net_latency_us: 10.0,
            tcp_stream_bw: 250.0 * MB,
            swift_tag_task_ms: 50.0,
            // backend endpoint NIC carries the whole GPFS server pool
            nfs_nic_bw: 24.0 * 400.0 * MB,
            ..Calib::default()
        }
    }

    /// Network latency as a [`crate::sim::Dur`].
    pub fn net_latency(&self) -> super::time::Dur {
        super::time::Dur::from_micros_f64(self.net_latency_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Calib::default();
        assert!(c.nic_bw > 100.0 * MB);
        assert_eq!(c.chunk_size, 1024 * 1024);
        assert!(c.manager_setattr_serialized);
        assert_eq!(c.swift_tag_task_ms, 0.0);
        // Table 6 reproduction requires the centralized, unbatched
        // defaults; the sharded/batched path is opt-in.
        assert_eq!(c.manager_shards, 1);
        assert_eq!(c.setattr_batch, 1);
    }

    #[test]
    fn bgp_profile() {
        let c = Calib::bgp();
        assert!(c.cpu_slowdown > 1.0);
        assert!(c.swift_tag_task_ms > 0.0);
        assert!(c.nic_bw > Calib::default().nic_bw);
    }
}
