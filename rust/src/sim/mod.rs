//! Discrete-event simulation substrate.
//!
//! The paper evaluates WOSS on a 20-node cluster, Grid5000 and a BG/P
//! rack — hardware we do not have. Per the reproduction plan (DESIGN.md
//! §2) the hardware is replaced by a virtual-time resource-contention
//! simulator: every contended device (NIC direction, disk, CPU core,
//! manager queue) is a FIFO resource with *busy-until* semantics, and
//! operations compose spans greedily in virtual time. This reproduces the
//! first-order bottlenecks the paper's ratios come from — NIC
//! serialization at hot nodes, disk vs RAM-disk bandwidth, manager
//! serialization of `set-attribute`, and scheduler overheads — while
//! staying deterministic and fast enough to run every figure's full sweep
//! in milliseconds.

pub mod calib;
pub mod cluster;
pub mod disk;
pub mod metrics;
pub mod net;
pub mod resource;
pub mod time;

pub use calib::Calib;
pub use cluster::Cluster;
pub use disk::{Disk, DiskCalib, DiskKind};
pub use metrics::Metrics;
pub use net::Fabric;
pub use resource::{MultiResource, Resource};
pub use time::{Dur, SimTime, Span};
