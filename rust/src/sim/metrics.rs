//! Simulation counters.
//!
//! Every simulated run accumulates a [`Metrics`] record; the experiment
//! harness prints these alongside runtimes so the *cause* of a
//! configuration's win (local vs remote bytes, manager pressure, cache
//! hits) is visible, matching the paper's §4.4 overhead analysis.

use crate::util::json::Json;

/// Counters accumulated during one simulated run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Bytes that crossed the network fabric.
    pub net_bytes: u64,
    /// Bytes served from the same node the task ran on.
    pub local_bytes: u64,
    /// Chunk-store write operations.
    pub chunk_writes: u64,
    /// Chunk-store read operations.
    pub chunk_reads: u64,
    /// Metadata-manager operations (all kinds).
    pub manager_ops: u64,
    /// `set-attribute` (tagging) operations.
    pub setattr_ops: u64,
    /// `get-attribute` operations (includes `location` queries).
    pub getattr_ops: u64,
    /// Replica chunks created by replication policies.
    pub replicas_created: u64,
    /// Tasks scheduled onto a node that already held their main input.
    pub local_placements: u64,
    /// Tasks scheduled without locality.
    pub remote_placements: u64,
    /// NFS/backend page-cache hits (bytes).
    pub cache_hit_bytes: u64,
    /// NFS/backend page-cache misses (bytes).
    pub cache_miss_bytes: u64,
    /// Helper-process forks performed for tagging.
    pub forks: u64,
}

impl Metrics {
    /// Fresh, zeroed metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Merge another record into this one (sums all counters).
    pub fn merge(&mut self, other: &Metrics) {
        self.net_bytes += other.net_bytes;
        self.local_bytes += other.local_bytes;
        self.chunk_writes += other.chunk_writes;
        self.chunk_reads += other.chunk_reads;
        self.manager_ops += other.manager_ops;
        self.setattr_ops += other.setattr_ops;
        self.getattr_ops += other.getattr_ops;
        self.replicas_created += other.replicas_created;
        self.local_placements += other.local_placements;
        self.remote_placements += other.remote_placements;
        self.cache_hit_bytes += other.cache_hit_bytes;
        self.cache_miss_bytes += other.cache_miss_bytes;
        self.forks += other.forks;
    }

    /// Fraction of bytes served locally.
    pub fn locality(&self) -> f64 {
        let total = self.net_bytes + self.local_bytes;
        if total == 0 {
            0.0
        } else {
            self.local_bytes as f64 / total as f64
        }
    }

    /// JSON rendering for report files.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("net_bytes", self.net_bytes.into()),
            ("local_bytes", self.local_bytes.into()),
            ("chunk_writes", self.chunk_writes.into()),
            ("chunk_reads", self.chunk_reads.into()),
            ("manager_ops", self.manager_ops.into()),
            ("setattr_ops", self.setattr_ops.into()),
            ("getattr_ops", self.getattr_ops.into()),
            ("replicas_created", self.replicas_created.into()),
            ("local_placements", self.local_placements.into()),
            ("remote_placements", self.remote_placements.into()),
            ("cache_hit_bytes", self.cache_hit_bytes.into()),
            ("cache_miss_bytes", self.cache_miss_bytes.into()),
            ("forks", self.forks.into()),
            ("locality", self.locality().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums() {
        let mut a = Metrics {
            net_bytes: 10,
            manager_ops: 1,
            ..Metrics::default()
        };
        let b = Metrics {
            net_bytes: 5,
            local_bytes: 20,
            ..Metrics::default()
        };
        a.merge(&b);
        assert_eq!(a.net_bytes, 15);
        assert_eq!(a.local_bytes, 20);
        assert_eq!(a.manager_ops, 1);
    }

    #[test]
    fn locality_fraction() {
        let m = Metrics {
            net_bytes: 25,
            local_bytes: 75,
            ..Metrics::default()
        };
        assert!((m.locality() - 0.75).abs() < 1e-12);
        assert_eq!(Metrics::default().locality(), 0.0);
    }

    #[test]
    fn json_has_all_fields() {
        let j = Metrics::default().to_json();
        for key in [
            "net_bytes",
            "manager_ops",
            "locality",
            "replicas_created",
            "forks",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
