//! Contended resources with gap-filling interval reservations.
//!
//! The simulator models every contended device — a NIC direction, a disk,
//! a CPU core, the metadata manager's serialized set-attribute queue — as
//! a [`Resource`] (one server) or [`MultiResource`] (k servers).
//!
//! A reservation occupies a contiguous span of virtual time. Because the
//! workflow engine issues operations task-by-task (not globally
//! time-ordered), a naive FIFO busy-until model would queue an early
//! operation behind a reservation made *for a later time* by a
//! previously-processed task, fabricating idle gaps. Reservations here
//! are therefore **gap-filling**: `acquire(earliest, dur)` takes the
//! first idle interval of length `dur` at or after `earliest`. This
//! keeps mutual exclusion exact while approximating the work-conserving
//! behaviour of real devices and links. Adjacent intervals are merged so
//! the common append-at-end case stays O(1).

use super::time::{Dur, SimTime, Span};

/// A single-server resource with gap-filling reservations.
///
/// Intervals are a sorted `Vec<(start, end)>`. The perf pass
/// (EXPERIMENTS.md §Perf) also evaluated a `BTreeMap<start, end>`
/// variant: it won 30% on the pipeline experiment but lost 48% on
/// Montage (many ops over lightly-fragmented resources, where the Vec's
/// cache locality and O(1) tail-append dominate), so the Vec stayed.
#[derive(Debug, Clone, Default)]
pub struct Resource {
    /// Sorted, non-overlapping, non-adjacent busy intervals (ns).
    intervals: Vec<(u64, u64)>,
    busy_total: Dur,
    reservations: u64,
}

impl Resource {
    /// Fresh, idle resource.
    pub fn new() -> Self {
        Resource::default()
    }

    /// Earliest start for a hypothetical reservation (without
    /// committing).
    pub fn peek(&self, earliest: SimTime, dur: Dur) -> SimTime {
        SimTime(self.find_slot(earliest.0, dur.0))
    }

    fn find_slot(&self, earliest: u64, dur: u64) -> u64 {
        let mut candidate = earliest;
        // Binary search for the first interval that could interfere.
        let start_idx = self.intervals.partition_point(|&(_, b)| b <= earliest);
        for &(a, b) in &self.intervals[start_idx..] {
            if candidate.saturating_add(dur) <= a {
                break;
            }
            candidate = candidate.max(b);
        }
        candidate
    }

    /// Reserve `dur` of exclusive time, not starting before `earliest`;
    /// takes the first idle gap that fits.
    pub fn acquire(&mut self, earliest: SimTime, dur: Dur) -> Span {
        self.reservations += 1;
        self.busy_total += dur;
        if dur == Dur::ZERO {
            return Span::instant(SimTime(self.find_slot(earliest.0, 0)));
        }
        let start = self.find_slot(earliest.0, dur.0);
        let end = start + dur.0;
        self.insert(start, end);
        Span {
            start: SimTime(start),
            end: SimTime(end),
        }
    }

    fn insert(&mut self, start: u64, end: u64) {
        let idx = self.intervals.partition_point(|&(a, _)| a < start);
        // Merge with the previous interval when adjacent (the common
        // FIFO-append case) and/or the next one.
        let merges_prev = idx > 0 && self.intervals[idx - 1].1 == start;
        let merges_next = idx < self.intervals.len() && self.intervals[idx].0 == end;
        match (merges_prev, merges_next) {
            (true, true) => {
                self.intervals[idx - 1].1 = self.intervals[idx].1;
                self.intervals.remove(idx);
            }
            (true, false) => self.intervals[idx - 1].1 = end,
            (false, true) => self.intervals[idx].0 = start,
            (false, false) => self.intervals.insert(idx, (start, end)),
        }
        debug_assert!(self.intervals.windows(2).all(|w| w[0].1 < w[1].0));
    }

    /// End of the last reservation (conservative "fully idle after").
    pub fn free_at(&self) -> SimTime {
        SimTime(self.intervals.last().map(|&(_, b)| b).unwrap_or(0))
    }

    /// Total busy time accumulated (for utilization metrics).
    pub fn busy_total(&self) -> Dur {
        self.busy_total
    }

    /// Number of reservations served.
    pub fn reservations(&self) -> u64 {
        self.reservations
    }

    /// Utilization over a horizon (1.0 = always busy).
    pub fn utilization(&self, horizon: Dur) -> f64 {
        if horizon == Dur::ZERO {
            0.0
        } else {
            self.busy_total.as_secs_f64() / horizon.as_secs_f64()
        }
    }

    /// Number of distinct busy intervals currently tracked (perf probe).
    pub fn fragmentation(&self) -> usize {
        self.intervals.len()
    }
}

/// A k-server resource (e.g. CPU cores on a node, manager worker
/// threads). Reservations go to the server that can start earliest.
#[derive(Debug, Clone)]
pub struct MultiResource {
    servers: Vec<Resource>,
}

impl MultiResource {
    /// `k` idle servers. `k` must be ≥ 1.
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "MultiResource needs at least one server");
        MultiResource {
            servers: vec![Resource::new(); k],
        }
    }

    /// Number of servers.
    pub fn capacity(&self) -> usize {
        self.servers.len()
    }

    /// Reserve `dur` on the server that can start earliest.
    pub fn acquire(&mut self, earliest: SimTime, dur: Dur) -> Span {
        let idx = self
            .servers
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.peek(earliest, dur))
            .map(|(i, _)| i)
            .expect("non-empty");
        self.servers[idx].acquire(earliest, dur)
    }

    /// Earliest time any server could start a zero-length job now
    /// (scheduler load probe).
    pub fn free_at(&self) -> SimTime {
        self.servers
            .iter()
            .map(Resource::free_at)
            .min()
            .unwrap_or(SimTime::ZERO)
    }

    /// Aggregate busy time across servers.
    pub fn busy_total(&self) -> Dur {
        self.servers
            .iter()
            .fold(Dur::ZERO, |acc, r| acc + r.busy_total())
    }

    /// Aggregate reservations across servers.
    pub fn reservations(&self) -> u64 {
        self.servers.iter().map(Resource::reservations).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_when_contended() {
        let mut r = Resource::new();
        let a = r.acquire(SimTime(0), Dur(100));
        let b = r.acquire(SimTime(0), Dur(50));
        assert_eq!(a.start, SimTime(0));
        assert_eq!(a.end, SimTime(100));
        assert_eq!(b.start, SimTime(100), "queues behind a");
        assert_eq!(b.end, SimTime(150));
    }

    #[test]
    fn gap_filling_latecomer() {
        let mut r = Resource::new();
        // A reservation placed for a later time...
        let late = r.acquire(SimTime(1000), Dur(100));
        assert_eq!(late.start, SimTime(1000));
        // ...must not block an earlier operation that fits before it.
        let early = r.acquire(SimTime(0), Dur(500));
        assert_eq!(early.start, SimTime(0));
        assert_eq!(early.end, SimTime(500));
    }

    #[test]
    fn gap_too_small_skipped() {
        let mut r = Resource::new();
        r.acquire(SimTime(100), Dur(100)); // busy [100, 200)
        let s = r.acquire(SimTime(50), Dur(80));
        assert_eq!(s.start, SimTime(200), "50..100 gap too small for 80");
    }

    #[test]
    fn adjacent_intervals_merge() {
        let mut r = Resource::new();
        for i in 0..100 {
            r.acquire(SimTime(i * 10), Dur(10));
        }
        assert_eq!(r.fragmentation(), 1, "contiguous spans merge");
        assert_eq!(r.free_at(), SimTime(1000));
    }

    #[test]
    fn merge_bridges_two_islands() {
        let mut r = Resource::new();
        r.acquire(SimTime(0), Dur(10)); // [0,10)
        r.acquire(SimTime(20), Dur(10)); // [20,30)
        let mid = r.acquire(SimTime(10), Dur(10)); // exactly fills [10,20)
        assert_eq!(mid.start, SimTime(10));
        assert_eq!(r.fragmentation(), 1);
    }

    #[test]
    fn idle_gap_not_counted_busy() {
        let mut r = Resource::new();
        r.acquire(SimTime(0), Dur(10));
        r.acquire(SimTime(100), Dur(10));
        assert_eq!(r.busy_total(), Dur(20));
        assert_eq!(r.free_at(), SimTime(110));
    }

    #[test]
    fn multi_parallelism() {
        let mut m = MultiResource::new(2);
        let a = m.acquire(SimTime(0), Dur(100));
        let b = m.acquire(SimTime(0), Dur(100));
        let c = m.acquire(SimTime(0), Dur(100));
        assert_eq!(a.start, SimTime(0));
        assert_eq!(b.start, SimTime(0), "second core idle");
        assert_eq!(c.start, SimTime(100), "third job waits");
        assert_eq!(m.busy_total(), Dur(300));
        assert_eq!(m.reservations(), 3);
    }

    #[test]
    #[should_panic(expected = "at least one server")]
    fn zero_servers_rejected() {
        let _ = MultiResource::new(0);
    }

    #[test]
    fn utilization() {
        let mut r = Resource::new();
        r.acquire(SimTime(0), Dur::from_secs_f64(2.0));
        assert!((r.utilization(Dur::from_secs_f64(4.0)) - 0.5).abs() < 1e-9);
        assert_eq!(r.utilization(Dur::ZERO), 0.0);
    }

    #[test]
    fn zero_duration_reservation() {
        let mut r = Resource::new();
        r.acquire(SimTime(100), Dur(50));
        let z = r.acquire(SimTime(120), Dur(0));
        assert_eq!(z.start, SimTime(150), "zero-dur placed after busy span");
        assert_eq!(r.fragmentation(), 1);
    }
}
