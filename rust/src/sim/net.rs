//! Network fabric model.
//!
//! Each node has a full-duplex NIC modelled as two FIFO [`Resource`]s
//! (transmit and receive) with per-direction bandwidth, plus a per-message
//! propagation latency. A transfer serializes on the sender's TX and the
//! receiver's RX at `min(tx_bw, rx_bw)` effective bandwidth — this is the
//! first-order contention that makes a single NFS server or an
//! un-replicated broadcast source a bottleneck in the paper's experiments,
//! while node-local access (src == dst) bypasses the fabric entirely
//! (that is exactly the pipeline-pattern win).

use super::resource::Resource;
use super::time::{Dur, SimTime, Span};
use crate::storage::types::NodeId;

/// Per-node NIC state.
#[derive(Debug, Clone)]
struct Nic {
    tx: Resource,
    rx: Resource,
    bw: f64, // bytes/sec, per direction
}

/// The cluster interconnect.
#[derive(Debug, Clone)]
pub struct Fabric {
    nics: Vec<Nic>,
    latency: Dur,
    /// Per-flow effective streaming rate (protocol/copy overheads); a
    /// flow never completes faster than `bytes / stream_bw` even when
    /// both endpoints are idle. Endpoint *occupancy* is still charged at
    /// line rate, so slow flows overlap rather than hogging the NIC.
    stream_bw: f64,
}

impl Fabric {
    /// `bandwidths[n]` is node *n*'s per-direction NIC bandwidth in
    /// bytes/sec; `latency` is the per-message propagation delay;
    /// `stream_bw` caps a single flow's effective rate.
    pub fn new_with_stream(bandwidths: &[f64], latency: Dur, stream_bw: f64) -> Self {
        assert!(!bandwidths.is_empty(), "fabric needs at least one node");
        for &bw in bandwidths {
            assert!(bw > 0.0, "non-positive NIC bandwidth");
        }
        assert!(stream_bw > 0.0, "non-positive stream bandwidth");
        Fabric {
            nics: bandwidths
                .iter()
                .map(|&bw| Nic {
                    tx: Resource::new(),
                    rx: Resource::new(),
                    bw,
                })
                .collect(),
            latency,
            stream_bw,
        }
    }

    /// Fabric without a per-flow cap (tests, ideal interconnects).
    pub fn new(bandwidths: &[f64], latency: Dur) -> Self {
        Fabric::new_with_stream(bandwidths, latency, f64::INFINITY)
    }

    /// Uniform fabric: `n` nodes at `bw` bytes/sec, no per-flow cap.
    pub fn uniform(n: usize, bw: f64, latency: Dur) -> Self {
        Fabric::new(&vec![bw; n], latency)
    }

    /// Number of endpoints.
    pub fn len(&self) -> usize {
        self.nics.len()
    }

    /// True when the fabric has no endpoints (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.nics.is_empty()
    }

    /// Move `bytes` from `src` to `dst`, not starting before `earliest`.
    /// Local moves (src == dst) cost nothing: the paper's locality
    /// optimizations are precisely about converting remote transfers into
    /// these.
    pub fn transfer(
        &mut self,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        earliest: SimTime,
    ) -> Span {
        if src == dst {
            return Span::instant(earliest);
        }
        // Each endpoint is occupied for bytes at *its own* line rate, so
        // a fat endpoint (GPFS pool NIC) can overlap many slow flows
        // while a 1 Gbps NFS box serializes them. The flow completes when
        // the slower endpoint finishes.
        let tx_dur = Dur::for_bytes(bytes, self.nics[src.0].bw);
        let rx_dur = Dur::for_bytes(bytes, self.nics[dst.0].bw);
        let tx = self.nics[src.0].tx.acquire(earliest, tx_dur);
        let rx = self.nics[dst.0].rx.acquire(tx.start, rx_dur);
        let stream_floor = if self.stream_bw.is_finite() {
            tx.start + Dur::for_bytes(bytes, self.stream_bw)
        } else {
            tx.start
        };
        Span {
            start: tx.start,
            end: tx.end.max(rx.end).max(stream_floor) + self.latency,
        }
    }

    /// A small control-plane message (metadata RPC): latency-bound.
    pub fn rpc(&mut self, src: NodeId, dst: NodeId, earliest: SimTime) -> Span {
        // Control messages are tiny; model propagation latency only
        // (they do not saturate NIC bandwidth).
        if src == dst {
            return Span::instant(earliest);
        }
        Span {
            start: earliest,
            end: earliest + self.latency,
        }
    }

    /// Per-message latency.
    pub fn latency(&self) -> Dur {
        self.latency
    }

    /// Total bytes·seconds of TX busy time on a node (utilization probe).
    pub fn tx_busy(&self, node: NodeId) -> Dur {
        self.nics[node.0].tx.busy_total()
    }

    /// RX busy time on a node.
    pub fn rx_busy(&self, node: NodeId) -> Dur {
        self.nics[node.0].rx.busy_total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;
    const GBPS: f64 = 117.0 * 1024.0 * 1024.0; // ~1 Gbps in bytes/sec

    fn fabric(n: usize) -> Fabric {
        Fabric::uniform(n, GBPS, Dur::from_micros_f64(100.0))
    }

    #[test]
    fn local_transfer_is_free() {
        let mut f = fabric(2);
        let s = f.transfer(NodeId(0), NodeId(0), 100 * MB, SimTime(42));
        assert_eq!(s, Span::instant(SimTime(42)));
        assert_eq!(f.tx_busy(NodeId(0)), Dur::ZERO);
    }

    #[test]
    fn remote_transfer_takes_bandwidth_time() {
        let mut f = fabric(2);
        let s = f.transfer(NodeId(0), NodeId(1), 117 * MB, SimTime::ZERO);
        assert!((s.dur().as_secs_f64() - 1.0001).abs() < 0.01);
    }

    #[test]
    fn server_rx_serializes_many_senders() {
        // 4 clients pushing 117MB each to one server: last finishes ~4s.
        let mut f = fabric(5);
        let mut last = SimTime::ZERO;
        for c in 1..5 {
            let s = f.transfer(NodeId(c), NodeId(0), 117 * MB, SimTime::ZERO);
            last = last.max(s.end);
        }
        assert!((last.as_secs_f64() - 4.0).abs() < 0.05, "got {last}");
    }

    #[test]
    fn disjoint_pairs_run_in_parallel() {
        let mut f = fabric(4);
        let a = f.transfer(NodeId(0), NodeId(1), 117 * MB, SimTime::ZERO);
        let b = f.transfer(NodeId(2), NodeId(3), 117 * MB, SimTime::ZERO);
        assert_eq!(a.start, SimTime::ZERO);
        assert_eq!(b.start, SimTime::ZERO);
    }

    #[test]
    fn asymmetric_bandwidth_takes_min() {
        let mut f = Fabric::new(&[GBPS, GBPS / 2.0], Dur::ZERO);
        let s = f.transfer(NodeId(0), NodeId(1), 117 * MB, SimTime::ZERO);
        assert!((s.dur().as_secs_f64() - 2.0).abs() < 0.05);
    }

    #[test]
    fn rpc_is_latency_bound() {
        let mut f = fabric(2);
        let s = f.rpc(NodeId(0), NodeId(1), SimTime::ZERO);
        assert!((s.dur().as_secs_f64() - 100e-6).abs() < 1e-9);
    }
}
