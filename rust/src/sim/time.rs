//! Virtual time. The simulator works in integer nanoseconds so experiment
//! results are exactly reproducible across runs and platforms (no float
//! accumulation drift in the event order).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (nanoseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of virtual time (nanoseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Dur(pub u64);

impl SimTime {
    /// Simulation origin.
    pub const ZERO: SimTime = SimTime(0);

    /// Construct from seconds.
    pub fn from_secs_f64(s: f64) -> SimTime {
        debug_assert!(s >= 0.0 && s.is_finite());
        SimTime((s * 1e9).round() as u64)
    }

    /// As floating-point seconds (for reports).
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Later of two instants.
    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    /// Duration since `earlier` (saturating).
    pub fn since(self, earlier: SimTime) -> Dur {
        Dur(self.0.saturating_sub(earlier.0))
    }
}

impl Dur {
    /// Zero-length duration.
    pub const ZERO: Dur = Dur(0);

    /// Construct from seconds.
    pub fn from_secs_f64(s: f64) -> Dur {
        debug_assert!(s >= 0.0 && s.is_finite(), "negative duration {s}");
        Dur((s * 1e9).round() as u64)
    }

    /// Construct from milliseconds.
    pub fn from_millis_f64(ms: f64) -> Dur {
        Dur::from_secs_f64(ms / 1e3)
    }

    /// Construct from microseconds.
    pub fn from_micros_f64(us: f64) -> Dur {
        Dur::from_secs_f64(us / 1e6)
    }

    /// Time to move `bytes` at `bytes_per_sec` throughput.
    pub fn for_bytes(bytes: u64, bytes_per_sec: f64) -> Dur {
        debug_assert!(bytes_per_sec > 0.0, "non-positive bandwidth");
        Dur::from_secs_f64(bytes as f64 / bytes_per_sec)
    }

    /// As floating-point seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Saturating sum.
    pub fn saturating_add(self, other: Dur) -> Dur {
        Dur(self.0.saturating_add(other.0))
    }

    /// Scale by a factor (e.g. jitter, slow-core multipliers).
    pub fn scale(self, f: f64) -> Dur {
        debug_assert!(f >= 0.0);
        Dur((self.0 as f64 * f).round() as u64)
    }
}

impl Add<Dur> for SimTime {
    type Output = SimTime;
    fn add(self, d: Dur) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<Dur> for SimTime {
    fn add_assign(&mut self, d: Dur) {
        self.0 += d.0;
    }
}

impl Add for Dur {
    type Output = Dur;
    fn add(self, other: Dur) -> Dur {
        Dur(self.0 + other.0)
    }
}

impl AddAssign for Dur {
    fn add_assign(&mut self, other: Dur) {
        self.0 += other.0;
    }
}

impl Sub for SimTime {
    type Output = Dur;
    fn sub(self, other: SimTime) -> Dur {
        debug_assert!(self.0 >= other.0, "negative time difference");
        Dur(self.0 - other.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for Dur {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

/// A `[start, end]` interval produced by a resource reservation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// When the reservation begins.
    pub start: SimTime,
    /// When the reservation ends.
    pub end: SimTime,
}

impl Span {
    /// Zero-length span at `t`.
    pub fn instant(t: SimTime) -> Span {
        Span { start: t, end: t }
    }

    /// Length of the span.
    pub fn dur(&self) -> Dur {
        self.end - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::from_secs_f64(1.0) + Dur::from_millis_f64(500.0);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-9);
        let d = t - SimTime::from_secs_f64(1.0);
        assert!((d.as_secs_f64() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn for_bytes() {
        // 117 MB/s over 117 MB = 1s
        let d = Dur::for_bytes(117 * 1024 * 1024, 117.0 * 1024.0 * 1024.0);
        assert!((d.as_secs_f64() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn max_and_since() {
        let a = SimTime(10);
        let b = SimTime(20);
        assert_eq!(a.max(b), b);
        assert_eq!(b.since(a), Dur(10));
        assert_eq!(a.since(b), Dur(0)); // saturates
    }

    #[test]
    fn span_dur() {
        let s = Span {
            start: SimTime(5),
            end: SimTime(15),
        };
        assert_eq!(s.dur(), Dur(10));
        assert_eq!(Span::instant(SimTime(7)).dur(), Dur::ZERO);
    }

    #[test]
    fn scale() {
        assert_eq!(Dur(1000).scale(2.5), Dur(2500));
        assert_eq!(Dur(1000).scale(0.0), Dur(0));
    }
}
