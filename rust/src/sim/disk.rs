//! Storage-device models.
//!
//! The paper's testbed runs storage nodes either on spinning disks
//! (RAID-1, 7200 rpm SATA) or on RAM-disks, and the NFS baseline on a
//! RAID-5 array; figures compare `*-DISK` vs `*-RAM` configurations
//! directly. A device is a FIFO [`Resource`] with sequential bandwidth
//! plus a per-operation positioning cost (seek + rotational latency for
//! spinning media, ~zero for RAM).

use super::resource::Resource;
use super::time::{Dur, SimTime, Span};

/// Kinds of backing device for a storage node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiskKind {
    /// RAID-1 of two 7200 rpm SATA disks (the paper's cluster nodes).
    Spinning,
    /// RAM-disk (the paper's `*-RAM` configurations and BG/P nodes).
    RamDisk,
    /// RAID-5 over six SATA disks (the paper's NFS server).
    Raid5,
    /// Diskless (BG/P compute nodes mount only a RAM disk; this kind is
    /// used for nodes that contribute no storage).
    None,
}

/// A storage device with FIFO queueing.
#[derive(Debug, Clone)]
pub struct Disk {
    kind: DiskKind,
    read_bw: f64,  // bytes/sec
    write_bw: f64, // bytes/sec
    position_cost: Dur,
    resource: Resource,
}

impl Disk {
    /// Build a device of `kind` using the calibration numbers in
    /// [`DiskCalib`].
    pub fn new(kind: DiskKind, calib: &DiskCalib) -> Self {
        let (read_bw, write_bw, position_cost) = match kind {
            DiskKind::Spinning => (
                calib.spinning_read_bw,
                calib.spinning_write_bw,
                Dur::from_millis_f64(calib.spinning_position_ms),
            ),
            DiskKind::RamDisk => (calib.ramdisk_bw, calib.ramdisk_bw, Dur::ZERO),
            DiskKind::Raid5 => (
                calib.raid5_read_bw,
                calib.raid5_write_bw,
                Dur::from_millis_f64(calib.spinning_position_ms),
            ),
            DiskKind::None => (f64::INFINITY, f64::INFINITY, Dur::ZERO),
        };
        Disk {
            kind,
            read_bw,
            write_bw,
            position_cost,
            resource: Resource::new(),
        }
    }

    /// Device kind.
    pub fn kind(&self) -> DiskKind {
        self.kind
    }

    /// Read `bytes`, not before `earliest`.
    pub fn read(&mut self, bytes: u64, earliest: SimTime) -> Span {
        self.io(bytes, self.read_bw, earliest)
    }

    /// Write `bytes`, not before `earliest`.
    pub fn write(&mut self, bytes: u64, earliest: SimTime) -> Span {
        self.io(bytes, self.write_bw, earliest)
    }

    fn io(&mut self, bytes: u64, bw: f64, earliest: SimTime) -> Span {
        if self.kind == DiskKind::None || bytes == 0 {
            return Span::instant(earliest);
        }
        let dur = Dur::for_bytes(bytes, bw) + self.position_cost;
        self.resource.acquire(earliest, dur)
    }

    /// Accumulated busy time.
    pub fn busy_total(&self) -> Dur {
        self.resource.busy_total()
    }

    /// Number of I/O operations served.
    pub fn ops(&self) -> u64 {
        self.resource.reservations()
    }
}

/// Device calibration constants (overridable from config).
#[derive(Debug, Clone)]
pub struct DiskCalib {
    /// Sequential read bandwidth of the RAID-1 SATA pair, bytes/s.
    pub spinning_read_bw: f64,
    /// Sequential write bandwidth of the RAID-1 SATA pair, bytes/s.
    pub spinning_write_bw: f64,
    /// Seek + rotational cost per operation, ms.
    pub spinning_position_ms: f64,
    /// RAM-disk bandwidth, bytes/s.
    pub ramdisk_bw: f64,
    /// NFS server RAID-5 aggregate read bandwidth, bytes/s.
    pub raid5_read_bw: f64,
    /// NFS server RAID-5 aggregate write bandwidth, bytes/s (parity
    /// penalty).
    pub raid5_write_bw: f64,
}

impl Default for DiskCalib {
    fn default() -> Self {
        const MB: f64 = 1024.0 * 1024.0;
        DiskCalib {
            // RAID-1 pair: reads can be served by both spindles
            // (~2 × 60 MB/s effective), writes go to both (one-spindle
            // sequential rate with write-back absorbing latency).
            spinning_read_bw: 115.0 * MB,
            spinning_write_bw: 100.0 * MB,
            spinning_position_ms: 8.0,
            ramdisk_bw: 1600.0 * MB,
            raid5_read_bw: 260.0 * MB,
            raid5_write_bw: 140.0 * MB,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn spinning_slower_than_ram() {
        let calib = DiskCalib::default();
        let mut hdd = Disk::new(DiskKind::Spinning, &calib);
        let mut ram = Disk::new(DiskKind::RamDisk, &calib);
        let h = hdd.write(100 * MB, SimTime::ZERO);
        let r = ram.write(100 * MB, SimTime::ZERO);
        assert!(h.dur() > r.dur());
        assert!(h.dur().as_secs_f64() > 1.0);
        assert!(r.dur().as_secs_f64() < 0.1);
    }

    #[test]
    fn seek_cost_charged_per_op() {
        let calib = DiskCalib::default();
        let mut hdd = Disk::new(DiskKind::Spinning, &calib);
        let s = hdd.read(0, SimTime::ZERO);
        assert_eq!(s.dur(), Dur::ZERO, "zero-byte I/O is free");
        let s = hdd.read(1, SimTime::ZERO);
        assert!(s.dur().as_secs_f64() >= 8e-3);
    }

    #[test]
    fn fifo_queueing() {
        let calib = DiskCalib::default();
        let mut d = Disk::new(DiskKind::RamDisk, &calib);
        let a = d.write(1600 * MB, SimTime::ZERO);
        let b = d.read(1600 * MB, SimTime::ZERO);
        assert!((a.dur().as_secs_f64() - 1.0).abs() < 0.01);
        assert_eq!(b.start, a.end);
    }

    #[test]
    fn none_kind_is_free() {
        let calib = DiskCalib::default();
        let mut d = Disk::new(DiskKind::None, &calib);
        let s = d.write(u64::MAX, SimTime(5));
        assert_eq!(s, Span::instant(SimTime(5)));
        assert_eq!(d.ops(), 0);
    }

    #[test]
    fn raid5_write_penalty() {
        let calib = DiskCalib::default();
        let mut d = Disk::new(DiskKind::Raid5, &calib);
        let r = d.read(260 * MB, SimTime::ZERO);
        let w = d.write(260 * MB, r.end);
        assert!(w.dur() > r.dur());
    }
}
