//! GPFS backend model for the BG/P experiments (paper §4, fig11).
//!
//! The BG/P deployment uses GPFS with 24 I/O servers (20 Gbps each) as
//! the backend. Unlike the single NFS box, GPFS stripes files across the
//! server pool, so aggregate backend bandwidth is high — which is why
//! DSS's win over GPFS on BG/P (20–40%) is much smaller than the 10×
//! wins over NFS on the cluster, and why reproducing fig11 needs a
//! distinct model rather than "NFS but bigger".
//!
//! Model: per-file chunks stripe round-robin over `k` server devices;
//! each server has its own service resource; client traffic still
//! crosses the client's own NIC (the backend endpoint's NIC is
//! provisioned at pool aggregate bandwidth). Like NFS, GPFS accepts
//! xattrs but exposes no location and triggers no optimization.

use crate::hints::TagSet;
use crate::sim::{Calib, Cluster, Dur, Metrics, Resource, SimTime};
use crate::storage::model::StorageModel;
use crate::storage::types::{NodeId, StorageError};
use std::collections::BTreeMap;

/// The GPFS I/O-server pool.
pub struct Gpfs {
    files: BTreeMap<String, (u64, TagSet)>,
    servers: Vec<Resource>,
    server_bw: f64,
    op_cost: Dur,
    stripe: u64,
    metrics: Metrics,
    rr: usize,
    /// First stripe target per file (so reads revisit the same servers).
    file_base: BTreeMap<String, usize>,
}

impl Gpfs {
    /// Build the pool from calibration.
    pub fn new(calib: &Calib) -> Self {
        Gpfs {
            files: BTreeMap::new(),
            servers: (0..calib.gpfs_servers).map(|_| Resource::new()).collect(),
            server_bw: calib.gpfs_server_bw,
            op_cost: Dur::from_millis_f64(calib.gpfs_op_ms),
            stripe: 4 << 20, // 4 MB GPFS block size
            metrics: Metrics::new(),
            rr: 0,
            file_base: BTreeMap::new(),
        }
    }

    /// Pre-load a dataset file.
    pub fn preload(&mut self, path: &str, size: u64) {
        let base = self.rr;
        self.rr = (self.rr + 1) % self.servers.len();
        self.files.insert(path.to_string(), (size, TagSet::new()));
        self.file_base.insert(path.to_string(), base);
    }

    /// Stripe `bytes` of I/O for `path` across the pool starting at the
    /// file's base server; returns when the slowest stripe finishes.
    fn pool_io(&mut self, path: &str, bytes: u64, at: SimTime) -> SimTime {
        let base = *self.file_base.get(path).unwrap_or(&0);
        let k = self.servers.len();
        let mut done = at;
        let mut remaining = bytes;
        let mut idx = 0usize;
        while remaining > 0 {
            let this = remaining.min(self.stripe);
            let server = (base + idx) % k;
            let span = self.servers[server]
                .acquire(at, Dur::for_bytes(this, self.server_bw) + self.op_cost);
            done = done.max(span.end);
            remaining -= this;
            idx += 1;
        }
        done
    }
}

impl StorageModel for Gpfs {
    fn name(&self) -> String {
        "GPFS".to_string()
    }

    fn write_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        size: u64,
        tags: &TagSet,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let backend = cluster.backend();
        let t = cluster.fuse_op(at);
        let xfer = cluster.fabric.transfer(client, backend, size, t);
        if !self.file_base.contains_key(path) {
            let base = self.rr;
            self.rr = (self.rr + 1) % self.servers.len();
            self.file_base.insert(path.to_string(), base);
        }
        let done = self.pool_io(path, size, xfer.end);
        self.files.insert(path.to_string(), (size, tags.clone()));
        self.metrics.net_bytes += size;
        self.metrics.chunk_writes += 1;
        Ok(cluster.fuse_op(done))
    }

    fn read_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let size = self
            .files
            .get(path)
            .map(|(s, _)| *s)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        let backend = cluster.backend();
        let t = cluster.fuse_op(at);
        let served = self.pool_io(path, size, t);
        let xfer = cluster.fabric.transfer(backend, client, size, served);
        self.metrics.net_bytes += size;
        self.metrics.chunk_reads += 1;
        Ok(cluster.fuse_op(xfer.end))
    }

    fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let backend = cluster.backend();
        let t = cluster.fuse_op(at);
        let rpc = cluster.fabric.rpc(client, backend, t);
        if let Some((_, tags)) = self.files.get_mut(path) {
            tags.set(key, value);
        }
        Ok(cluster.fabric.rpc(backend, client, rpc.end + self.op_cost).end)
    }

    fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError> {
        let backend = cluster.backend();
        let t = cluster.fuse_op(at);
        let rpc = cluster.fabric.rpc(client, backend, t);
        let back = cluster.fabric.rpc(backend, client, rpc.end + self.op_cost);
        let value = self
            .files
            .get(path)
            .and_then(|(_, tags)| tags.get(key))
            .map(str::to_string);
        Ok((value, back.end))
    }

    fn locations(&self, _path: &str) -> Vec<NodeId> {
        Vec::new() // parallel FS does not expose location (§2.2)
    }

    fn file_size(&self, path: &str) -> Option<u64> {
        self.files.get(path).map(|(s, _)| *s)
    }

    fn delete(&mut self, path: &str) -> Result<(), StorageError> {
        self.file_base.remove(path);
        self.files
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| StorageError::NotFound(path.to_string()))
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DiskKind;

    const MB: u64 = 1024 * 1024;

    fn setup() -> (Cluster, Gpfs) {
        let calib = Calib::bgp();
        let cluster = Cluster::new(64, DiskKind::RamDisk, &calib);
        let gpfs = Gpfs::new(&calib);
        (cluster, gpfs)
    }

    #[test]
    fn roundtrip() {
        let (mut cl, mut g) = setup();
        let w = g
            .write_file(&mut cl, NodeId(1), "/f", 100 * MB, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        let r = g.read_file(&mut cl, NodeId(2), "/f", w).unwrap();
        assert!(r > w);
    }

    #[test]
    fn pool_outscales_single_server() {
        // Many clients reading distinct files: the pool absorbs far more
        // parallelism than one NFS box would.
        let (mut cl, mut g) = setup();
        for i in 0..32 {
            g.preload(&format!("/in{i}"), 64 * MB);
        }
        let mut max = SimTime::ZERO;
        for i in 0..32 {
            let done = g
                .read_file(&mut cl, NodeId(i + 1), &format!("/in{i}"), SimTime::ZERO)
                .unwrap();
            max = max.max(done);
        }
        // 32×64MB = 2GB; pool aggregate ~9.4GB/s ⇒ well under 2s.
        assert!(max.as_secs_f64() < 2.0, "pool should absorb parallel reads: {max}");
    }

    #[test]
    fn no_location_no_optimizations() {
        let (mut cl, mut g) = setup();
        g.preload("/f", MB);
        g.set_xattr(&mut cl, NodeId(1), "/f", "DP", "local", SimTime::ZERO)
            .unwrap();
        assert!(g.locations("/f").is_empty());
        assert!(!g.exposes_location());
    }
}
