//! Kernel runtime: execute the workload's compute kernels over data tiles.
//!
//! The original design loaded AOT JAX/Pallas artifacts (HLO text produced
//! by `python/compile/aot.py`) through a PJRT CPU client via the `xla`
//! bindings. This build is fully offline — the `xla` crate (and its
//! vendored XLA runtime) cannot be fetched — so the runtime ships an
//! **interpreted backend**: a pure-Rust implementation of each kernel with
//! semantics identical to the Python oracles in
//! `python/compile/kernels/ref.py`. The public surface (artifact names,
//! tile shapes, execute helpers, execution counters) is unchanged, so the
//! live engine, benches, and examples are backend-agnostic; re-enabling
//! PJRT is a matter of vendoring `xla` and swapping the four `exec_*`
//! bodies back to compiled executables.
//!
//! When `artifacts/*.hlo.txt` files exist (after `make artifacts`),
//! [`Runtime::load_artifact`] validates them so a stale or truncated AOT
//! build is caught even though execution is interpreted.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

/// Tile side used by every kernel (mirrors `python/compile/kernels/ref.py`).
pub const TILE: usize = 256;
/// Elements per tile.
pub const TILE_ELEMS: usize = TILE * TILE;
/// Merge fan-in of the `reduce_merge` artifact.
pub const MERGE_K: usize = 8;

/// Artifact names the runtime expects after `make artifacts`.
pub const ARTIFACTS: [&str; 4] = [
    "stage_transform",
    "stage_chain",
    "reduce_merge",
    "checksum",
];

/// A kernel pool: registered artifact names plus per-kernel execution
/// counters (perf accounting).
pub struct Runtime {
    /// Registered kernel names.
    kernels: HashSet<String>,
    /// Executions per artifact (perf accounting).
    exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Register every kernel, validating any HLO artifacts present in
    /// `dir`. Missing artifact files are fine — the interpreted backend
    /// needs no compiled code.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let mut rt = Runtime {
            kernels: HashSet::new(),
            exec_counts: HashMap::new(),
        };
        for name in ARTIFACTS {
            rt.load_artifact(name, &dir.join(format!("{name}.hlo.txt")))?;
        }
        Ok(rt)
    }

    /// Default artifact directory (`$WOSS_ARTIFACTS` or `./artifacts`).
    pub fn artifact_dir() -> PathBuf {
        std::env::var("WOSS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Register one kernel under `name`. When the HLO-text artifact at
    /// `path` exists it is sanity-checked (non-empty, `HloModule`
    /// header); when absent the interpreted implementation serves alone.
    pub fn load_artifact(&mut self, name: &str, path: &Path) -> Result<()> {
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("read artifact {path:?}: {e}"))?;
            if !text.contains("HloModule") {
                return Err(anyhow!(
                    "artifact {path:?} is not HLO text (rerun `make artifacts`)"
                ));
            }
        }
        self.kernels.insert(name.to_string());
        Ok(())
    }

    /// Names of loaded kernels, sorted.
    pub fn loaded(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.kernels.iter().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    /// How many times `name` has executed.
    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.get(name).copied().unwrap_or(0)
    }

    fn count(&mut self, name: &str) -> Result<()> {
        if !self.kernels.contains(name) {
            return Err(anyhow!("artifact '{name}' not loaded"));
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        Ok(())
    }

    /// `stage_transform(x, w, b)` over one tile: `tanh(x @ w + b)`.
    pub fn stage_transform(&mut self, x: &[f32], w: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        check_tile(x)?;
        check_tile(w)?;
        check_tile(b)?;
        self.count("stage_transform")?;
        Ok(stage_transform_ref(x, w, b))
    }

    /// `stage_chain(x, w1, b1, w2, b2)`: two fused stage transforms.
    pub fn stage_chain(
        &mut self,
        x: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
    ) -> Result<Vec<f32>> {
        for t in [x, w1, b1, w2, b2] {
            check_tile(t)?;
        }
        self.count("stage_chain")?;
        let mid = stage_transform_ref(x, w1, b1);
        Ok(stage_transform_ref(&mid, w2, b2))
    }

    /// `reduce_merge(parts, weights)` — `parts` is `MERGE_K` stacked tiles.
    pub fn reduce_merge(&mut self, parts: &[f32], weights: &[f32]) -> Result<Vec<f32>> {
        if parts.len() != MERGE_K * TILE_ELEMS {
            return Err(anyhow!(
                "reduce_merge parts: got {} elems, want {}",
                parts.len(),
                MERGE_K * TILE_ELEMS
            ));
        }
        if weights.len() != MERGE_K {
            return Err(anyhow!("reduce_merge weights: got {}", weights.len()));
        }
        self.count("reduce_merge")?;
        Ok(reduce_merge_ref(parts, weights))
    }

    /// `checksum(x)` — scalar fingerprint of one tile.
    pub fn checksum(&mut self, x: &[f32]) -> Result<f32> {
        check_tile(x)?;
        self.count("checksum")?;
        Ok(checksum_ref(x))
    }
}

fn check_tile(t: &[f32]) -> Result<()> {
    if t.len() == TILE_ELEMS {
        Ok(())
    } else {
        Err(anyhow!("tile: got {} elems, want {TILE_ELEMS}", t.len()))
    }
}

/// Pure-rust reference for `stage_transform`: `tanh(x @ w + b)` over one
/// `TILE`×`TILE` tile (row-major).
pub fn stage_transform_ref(x: &[f32], w: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), TILE_ELEMS);
    debug_assert_eq!(w.len(), TILE_ELEMS);
    debug_assert_eq!(b.len(), TILE_ELEMS);
    let mut out = b.to_vec();
    // ikj loop order: the inner loop strides contiguously through one row
    // of `w` and one row of `out`, which keeps even the debug build usable.
    for i in 0..TILE {
        let out_row = &mut out[i * TILE..(i + 1) * TILE];
        let x_row = &x[i * TILE..(i + 1) * TILE];
        for (k, &xv) in x_row.iter().enumerate() {
            let w_row = &w[k * TILE..(k + 1) * TILE];
            for (o, &wv) in out_row.iter_mut().zip(w_row) {
                *o += xv * wv;
            }
        }
    }
    for v in &mut out {
        *v = v.tanh();
    }
    out
}

/// Pure-rust reference for `checksum` (position-weighted sum).
pub fn checksum_ref(x: &[f32]) -> f32 {
    x.iter()
        .enumerate()
        .map(|(i, &v)| v * ((i % 64) as f32 + 1.0))
        .sum()
}

/// Pure-rust reference for `reduce_merge`.
pub fn reduce_merge_ref(parts: &[f32], weights: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; TILE_ELEMS];
    for (k, &w) in weights.iter().enumerate() {
        let base = k * TILE_ELEMS;
        for (o, &p) in out.iter_mut().zip(&parts[base..base + TILE_ELEMS]) {
            *o += w * p;
        }
    }
    out
}

/// Convert raw bytes into zero-padded f32 tiles (how the live engine
/// feeds storage chunks to the kernels). Values are mapped into [0, 1]
/// so transforms stay finite.
pub fn bytes_to_tiles(bytes: &[u8]) -> Vec<Vec<f32>> {
    let mut tiles = Vec::new();
    for chunk in bytes.chunks(TILE_ELEMS * 4) {
        let mut tile = vec![0.0f32; TILE_ELEMS];
        for (i, quad) in chunk.chunks(4).enumerate() {
            let mut buf = [0u8; 4];
            buf[..quad.len()].copy_from_slice(quad);
            let raw = u32::from_le_bytes(buf);
            tile[i] = (raw % 1_000_000) as f32 / 1.0e6;
        }
        tiles.push(tile);
    }
    if tiles.is_empty() {
        tiles.push(vec![0.0f32; TILE_ELEMS]);
    }
    tiles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        Runtime::load(&Runtime::artifact_dir()).expect("runtime loads")
    }

    fn tile(seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..TILE_ELEMS)
            .map(|_| (rng.gen_f64() as f32 - 0.5) * 2.0 * scale)
            .collect()
    }

    #[test]
    fn loads_all_artifacts() {
        let rt = runtime();
        assert_eq!(
            rt.loaded(),
            vec!["checksum", "reduce_merge", "stage_chain", "stage_transform"]
        );
    }

    #[test]
    fn checksum_weights_positions_independently() {
        // Independent fixture (kernel and oracle share code, so random
        // inputs would be tautological): a one-hot tile at index i must
        // produce exactly the position weight (i % 64) + 1.
        let mut rt = runtime();
        for i in [0usize, 1, 63, 64, 7_000, TILE_ELEMS - 1] {
            let mut x = vec![0.0f32; TILE_ELEMS];
            x[i] = 1.0;
            let got = rt.checksum(&x).unwrap();
            let want = (i % 64) as f32 + 1.0;
            assert_eq!(got, want, "one-hot at {i}");
        }
        assert_eq!(rt.exec_count("checksum"), 6);
    }

    #[test]
    fn reduce_merge_matches_hand_computed_fixtures() {
        let mut rt = runtime();
        // Constant parts c_k = k+1 with uniform weights 0.5: every
        // output element is 0.5 * (1 + 2 + ... + 8) = 18.
        let mut parts = Vec::with_capacity(MERGE_K * TILE_ELEMS);
        for k in 0..MERGE_K {
            parts.extend(std::iter::repeat(k as f32 + 1.0).take(TILE_ELEMS));
        }
        let out = rt.reduce_merge(&parts, &[0.5; MERGE_K]).unwrap();
        assert!(out.iter().all(|&v| (v - 18.0).abs() < 1e-4), "uniform merge");
        // One-hot weights select exactly part k.
        for k in [0usize, 3, MERGE_K - 1] {
            let mut weights = [0.0f32; MERGE_K];
            weights[k] = 1.0;
            let out = rt.reduce_merge(&parts, &weights).unwrap();
            assert!(
                out.iter().all(|&v| v == k as f32 + 1.0),
                "one-hot weight {k} must select part {k}"
            );
        }
    }

    #[test]
    fn stage_transform_routes_matmul_indices() {
        // A transposed or mis-strided matmul cannot pass this: with
        // x one-hot at (i0, k0) and w one-hot at (k0, j0), the product
        // has tanh(1) at exactly (i0, j0) and 0 elsewhere.
        let (i0, k0, j0) = (3usize, 200usize, 77usize);
        let mut x = vec![0.0f32; TILE_ELEMS];
        x[i0 * TILE + k0] = 1.0;
        let mut w = vec![0.0f32; TILE_ELEMS];
        w[k0 * TILE + j0] = 1.0;
        let b = vec![0.0f32; TILE_ELEMS];
        let mut rt = runtime();
        let y = rt.stage_transform(&x, &w, &b).unwrap();
        let expect = 1.0f32.tanh();
        for (idx, &v) in y.iter().enumerate() {
            if idx == i0 * TILE + j0 {
                assert!((v - expect).abs() < 1e-6, "product lands at (i0, j0)");
            } else {
                assert_eq!(v, 0.0, "stray value at {idx}");
            }
        }
    }

    #[test]
    fn stage_chain_equals_two_transforms() {
        let mut rt = runtime();
        let x = tile(2, 1.0);
        let w1 = tile(3, 0.05);
        let b1 = tile(4, 0.1);
        let w2 = tile(5, 0.05);
        let b2 = tile(6, 0.1);
        let y = rt.stage_transform(&x, &w1, &b1).unwrap();
        let z = rt.stage_transform(&y, &w2, &b2).unwrap();
        let chained = rt.stage_chain(&x, &w1, &b1, &w2, &b2).unwrap();
        let max_err = z
            .iter()
            .zip(&chained)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "max err {max_err}");
        assert_eq!(rt.exec_count("stage_transform"), 2);
        assert_eq!(rt.exec_count("stage_chain"), 1);
    }

    #[test]
    fn transform_output_bounded() {
        let mut rt = runtime();
        let out = rt
            .stage_transform(&tile(7, 10.0), &tile(8, 10.0), &tile(9, 10.0))
            .unwrap();
        assert!(out.iter().all(|v| v.is_finite() && v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn shape_errors_are_reported() {
        let mut rt = runtime();
        assert!(rt.stage_transform(&[1.0], &[1.0], &[1.0]).is_err());
        assert!(rt.reduce_merge(&[0.0; 8], &[0.0; 8]).is_err());
    }

    #[test]
    fn bytes_to_tiles_pads_and_bounds() {
        let tiles = bytes_to_tiles(&[0xFFu8; 100]);
        assert_eq!(tiles.len(), 1);
        assert_eq!(tiles[0].len(), TILE_ELEMS);
        assert!(tiles[0].iter().all(|v| v.is_finite() && *v >= 0.0 && *v <= 1.0));
        let empty = bytes_to_tiles(&[]);
        assert_eq!(empty.len(), 1);
        let two = bytes_to_tiles(&vec![1u8; TILE_ELEMS * 4 + 1]);
        assert_eq!(two.len(), 2);
    }
}
