//! PJRT runtime: load the AOT JAX/Pallas artifacts and execute them.
//!
//! The build path (`make artifacts`) lowers the L2 compute graphs to HLO
//! *text* (see `python/compile/aot.py` for why text, not serialized
//! proto); this module loads each `artifacts/*.hlo.txt`, compiles it once
//! on the PJRT CPU client, and exposes typed execute helpers. After
//! `make artifacts` the rust binary is self-contained — Python never
//! runs on the request path.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Tile side used by every kernel (mirrors `python/compile/kernels/ref.py`).
pub const TILE: usize = 256;
/// Elements per tile.
pub const TILE_ELEMS: usize = TILE * TILE;
/// Merge fan-in of the `reduce_merge` artifact.
pub const MERGE_K: usize = 8;

/// Artifact names the runtime expects after `make artifacts`.
pub const ARTIFACTS: [&str; 4] = [
    "stage_transform",
    "stage_chain",
    "reduce_merge",
    "checksum",
];

/// A compiled artifact pool over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per artifact (perf accounting).
    exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Create a CPU PJRT client and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut rt = Runtime {
            client,
            executables: HashMap::new(),
            exec_counts: HashMap::new(),
        };
        for name in ARTIFACTS {
            rt.load_artifact(name, &dir.join(format!("{name}.hlo.txt")))
                .with_context(|| format!("loading artifact '{name}'"))?;
        }
        Ok(rt)
    }

    /// Default artifact directory (`$WOSS_ARTIFACTS` or `./artifacts`).
    pub fn artifact_dir() -> PathBuf {
        std::env::var("WOSS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load + compile one HLO-text artifact under `name`.
    pub fn load_artifact(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Names of loaded artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.executables.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    /// How many times `name` has executed.
    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.get(name).copied().unwrap_or(0)
    }

    /// Execute artifact `name` on f32 literals shaped per `shapes`.
    fn run(&mut self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// `stage_transform(x, w, b)` over one tile.
    pub fn stage_transform(&mut self, x: &[f32], w: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        check_tile(x)?;
        check_tile(w)?;
        check_tile(b)?;
        let s: &[i64] = &[TILE as i64, TILE as i64];
        self.run("stage_transform", &[(x, s), (w, s), (b, s)])
    }

    /// `stage_chain(x, w1, b1, w2, b2)`.
    pub fn stage_chain(
        &mut self,
        x: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
    ) -> Result<Vec<f32>> {
        for t in [x, w1, b1, w2, b2] {
            check_tile(t)?;
        }
        let s: &[i64] = &[TILE as i64, TILE as i64];
        self.run("stage_chain", &[(x, s), (w1, s), (b1, s), (w2, s), (b2, s)])
    }

    /// `reduce_merge(parts, weights)` — parts is `MERGE_K` stacked tiles.
    pub fn reduce_merge(&mut self, parts: &[f32], weights: &[f32]) -> Result<Vec<f32>> {
        if parts.len() != MERGE_K * TILE_ELEMS {
            return Err(anyhow!(
                "reduce_merge parts: got {} elems, want {}",
                parts.len(),
                MERGE_K * TILE_ELEMS
            ));
        }
        if weights.len() != MERGE_K {
            return Err(anyhow!("reduce_merge weights: got {}", weights.len()));
        }
        self.run(
            "reduce_merge",
            &[
                (parts, &[MERGE_K as i64, TILE as i64, TILE as i64]),
                (weights, &[MERGE_K as i64]),
            ],
        )
    }

    /// `checksum(x)` — scalar fingerprint of one tile.
    pub fn checksum(&mut self, x: &[f32]) -> Result<f32> {
        check_tile(x)?;
        let out = self.run("checksum", &[(x, &[TILE as i64, TILE as i64])])?;
        Ok(out[0])
    }
}

fn check_tile(t: &[f32]) -> Result<()> {
    if t.len() == TILE_ELEMS {
        Ok(())
    } else {
        Err(anyhow!("tile: got {} elems, want {TILE_ELEMS}", t.len()))
    }
}

/// Pure-rust oracle for `checksum` (verifies the PJRT path end-to-end
/// without Python).
pub fn checksum_ref(x: &[f32]) -> f32 {
    x.iter()
        .enumerate()
        .map(|(i, &v)| v * ((i % 64) as f32 + 1.0))
        .sum()
}

/// Pure-rust oracle for `reduce_merge`.
pub fn reduce_merge_ref(parts: &[f32], weights: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; TILE_ELEMS];
    for (k, &w) in weights.iter().enumerate() {
        let base = k * TILE_ELEMS;
        for (o, &p) in out.iter_mut().zip(&parts[base..base + TILE_ELEMS]) {
            *o += w * p;
        }
    }
    out
}

/// Convert raw bytes into zero-padded f32 tiles (how the live engine
/// feeds storage chunks to the kernels). Values are mapped into [0, 1]
/// so transforms stay finite.
pub fn bytes_to_tiles(bytes: &[u8]) -> Vec<Vec<f32>> {
    let mut tiles = Vec::new();
    for chunk in bytes.chunks(TILE_ELEMS * 4) {
        let mut tile = vec![0.0f32; TILE_ELEMS];
        for (i, quad) in chunk.chunks(4).enumerate() {
            let mut buf = [0u8; 4];
            buf[..quad.len()].copy_from_slice(quad);
            let raw = u32::from_le_bytes(buf);
            tile[i] = (raw % 1_000_000) as f32 / 1.0e6;
        }
        tiles.push(tile);
    }
    if tiles.is_empty() {
        tiles.push(vec![0.0f32; TILE_ELEMS]);
    }
    tiles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::artifact_dir();
        if !dir.join("stage_transform.hlo.txt").exists() {
            eprintln!("artifacts missing; run `make artifacts` (skipping)");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime loads"))
    }

    fn tile(seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed);
        (0..TILE_ELEMS)
            .map(|_| (rng.gen_f64() as f32 - 0.5) * 2.0 * scale)
            .collect()
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime() else { return };
        assert_eq!(
            rt.loaded(),
            vec!["checksum", "reduce_merge", "stage_chain", "stage_transform"]
        );
    }

    #[test]
    fn checksum_matches_rust_oracle() {
        let Some(mut rt) = runtime() else { return };
        let x = tile(1, 1.0);
        let got = rt.checksum(&x).unwrap();
        let want = checksum_ref(&x);
        assert!(
            (got - want).abs() <= want.abs().max(1.0) * 1e-3,
            "pjrt {got} vs rust {want}"
        );
        assert_eq!(rt.exec_count("checksum"), 1);
    }

    #[test]
    fn reduce_merge_matches_rust_oracle() {
        let Some(mut rt) = runtime() else { return };
        let mut parts = Vec::new();
        for k in 0..MERGE_K {
            parts.extend(tile(k as u64 + 10, 1.0));
        }
        let weights: Vec<f32> = (0..MERGE_K).map(|k| 0.1 * (k as f32 + 1.0)).collect();
        let got = rt.reduce_merge(&parts, &weights).unwrap();
        let want = reduce_merge_ref(&parts, &weights);
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "max err {max_err}");
    }

    #[test]
    fn stage_chain_equals_two_transforms() {
        let Some(mut rt) = runtime() else { return };
        let x = tile(2, 1.0);
        let w1 = tile(3, 0.05);
        let b1 = tile(4, 0.1);
        let w2 = tile(5, 0.05);
        let b2 = tile(6, 0.1);
        let y = rt.stage_transform(&x, &w1, &b1).unwrap();
        let z = rt.stage_transform(&y, &w2, &b2).unwrap();
        let chained = rt.stage_chain(&x, &w1, &b1, &w2, &b2).unwrap();
        let max_err = z
            .iter()
            .zip(&chained)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "max err {max_err}");
    }

    #[test]
    fn transform_output_bounded() {
        let Some(mut rt) = runtime() else { return };
        let out = rt
            .stage_transform(&tile(7, 10.0), &tile(8, 10.0), &tile(9, 10.0))
            .unwrap();
        // XLA's CPU tanh approximation can exceed ±1 by a few ULPs.
        assert!(out.iter().all(|v| v.is_finite() && v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn shape_errors_are_reported() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.stage_transform(&[1.0], &[1.0], &[1.0]).is_err());
        assert!(rt.reduce_merge(&[0.0; 8], &[0.0; 8]).is_err());
    }

    #[test]
    fn bytes_to_tiles_pads_and_bounds() {
        let tiles = bytes_to_tiles(&[0xFFu8; 100]);
        assert_eq!(tiles.len(), 1);
        assert_eq!(tiles[0].len(), TILE_ELEMS);
        assert!(tiles[0].iter().all(|v| v.is_finite() && *v >= 0.0 && *v <= 1.0));
        let empty = bytes_to_tiles(&[]);
        assert_eq!(empty.len(), 1);
        let two = bytes_to_tiles(&vec![1u8; TILE_ELEMS * 4 + 1]);
        assert_eq!(two.len(), 2);
    }
}
