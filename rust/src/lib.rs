//! # WOSS — Workflow-Optimized Storage System
//!
//! Reproduction of *"The Case for Cross-Layer Optimizations in Storage: A
//! Workflow-Optimized Storage System"* (Al-Kiswany, Vairavanathan, Costa,
//! Yang, Ripeanu — 2013).
//!
//! The paper's thesis: POSIX **extended attributes** can act as a
//! *bidirectional* communication channel between applications and the
//! storage system, enabling per-file cross-layer optimizations without
//! abandoning the POSIX interface. Top-down, the workflow runtime tags
//! files with access-pattern hints (`DP=local`, `DP=collocation <g>`,
//! `DP=scatter <n>`, `Replication=<n>`, ...); bottom-up, the storage
//! exposes data location through the reserved `location` attribute so the
//! scheduler can collocate computation with data.
//!
//! ## Crate layout
//!
//! * [`sim`] — discrete-event simulation substrate (virtual clock, network
//!   fabric, disk models) standing in for the paper's 20-node cluster and
//!   BG/P rack.
//! * [`storage`] — the object-store substrate: sharded metadata manager,
//!   storage nodes, client SAI, chunking, replication.
//! * [`hints`] — the typed hint grammar of Table 3.
//! * [`dispatch`] — the paper's extensible dispatcher: tag-triggered
//!   optimization modules (placement, replication, location exposure).
//! * [`nfs`], [`gpfs`] — baseline storage systems used in the evaluation.
//! * [`workflow`] — pyFlow-equivalent runtime with round-robin and
//!   location-aware schedulers, plus the Swift-personality overhead model.
//! * [`workloads`] — synthetic patterns + BLAST / modFTDock / Montage.
//! * [`runtime`] — kernel runtime executing the workload's compute tiles
//!   (interpreted backend; PJRT artifacts validated when present).
//! * [`live`] — live engine: real bytes, real compute, std-thread actors.
//! * [`coordinator`] — leader: config, experiment registry, reporting.
//! * [`bench`] — experiment harness regenerating every paper figure/table.
//! * [`util`] — in-tree substrates (CLI, stats, RNG, property testing)
//!   since this build is fully offline.

#![warn(missing_docs)]

pub mod bench;
pub mod coordinator;
pub mod dispatch;
pub mod gpfs;
pub mod hints;
pub mod live;
pub mod nfs;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod storage;
pub mod util;
pub mod workflow;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
