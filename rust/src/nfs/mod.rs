//! NFS baseline: a single well-provisioned server.
//!
//! The paper's backend baseline is an NFS server on a bigger machine
//! (8 cores, 8 GB RAM, RAID-5 over six SATA disks, 1 Gbps NIC). Its
//! structural weakness in the experiments is exactly what this model
//! captures: every byte of every client's traffic serializes on one
//! server NIC and one disk array, softened only by the server's page
//! cache (which is why the paper notes "NFS only provided competitive
//! performance under cache friendly workloads").
//!
//! The model: whole files move client↔server over the shared fabric;
//! reads hit an LRU page cache (bytes-accurate) before touching RAID-5;
//! writes land in the cache and flush to disk asynchronously (blocking
//! only the server's disk resource, not the client — close-to-open NFS
//! semantics); every call pays the per-op server overhead. xattrs are
//! accepted and stored but trigger nothing, and location is never
//! exposed — NFS is the "legacy storage + hint-passing application"
//! corner of the incremental-adoption matrix.

use crate::hints::TagSet;
use crate::sim::{Calib, Cluster, Disk, DiskKind, Dur, Metrics, MultiResource, SimTime};
use crate::storage::model::StorageModel;
use crate::storage::types::{NodeId, StorageError};
use std::collections::BTreeMap;

/// Server-side page-cache entry state.
#[derive(Debug, Clone)]
struct CacheEntry {
    /// Cached bytes of the file (whole-file granularity: workflow files
    /// are written/read sequentially end-to-end).
    bytes: u64,
    /// LRU stamp.
    last_use: u64,
}

/// The NFS server model.
pub struct NfsServer {
    files: BTreeMap<String, (u64, TagSet)>,
    /// RAID-5 device (lives at the backend endpoint, outside the
    /// cluster's per-node disks).
    disk: Disk,
    /// Server CPU (request processing).
    cpu: MultiResource,
    op_cost: Dur,
    cache: BTreeMap<String, CacheEntry>,
    cache_capacity: u64,
    cache_used: u64,
    lru_clock: u64,
    /// Client-side OS cache: (client, path) fully read before, served
    /// locally when it fits `Calib::os_cache_bytes`.
    client_cache: std::collections::HashSet<(NodeId, String)>,
    metrics: Metrics,
}

impl NfsServer {
    /// Build the server from calibration.
    pub fn new(calib: &Calib) -> Self {
        NfsServer {
            files: BTreeMap::new(),
            disk: Disk::new(DiskKind::Raid5, &calib.disk),
            cpu: MultiResource::new(8),
            op_cost: Dur::from_millis_f64(calib.nfs_op_ms),
            cache: BTreeMap::new(),
            cache_capacity: calib.nfs_cache_bytes,
            cache_used: 0,
            lru_clock: 0,
            client_cache: std::collections::HashSet::new(),
            metrics: Metrics::new(),
        }
    }

    /// Pre-load a file (dataset already resident on the backend before
    /// the workflow starts — the stage-in source).
    pub fn preload(&mut self, path: &str, size: u64) {
        self.files.insert(path.to_string(), (size, TagSet::new()));
    }

    fn touch_cache(&mut self, path: &str, bytes: u64) {
        self.lru_clock += 1;
        let clock = self.lru_clock;
        let add = match self.cache.get_mut(path) {
            Some(e) => {
                e.last_use = clock;
                let grow = bytes.saturating_sub(e.bytes);
                e.bytes = e.bytes.max(bytes);
                grow
            }
            None => {
                self.cache.insert(
                    path.to_string(),
                    CacheEntry {
                        bytes,
                        last_use: clock,
                    },
                );
                bytes
            }
        };
        self.cache_used += add;
        // LRU eviction. Ties on the LRU stamp break by path so identical
        // simulations evict identically — victim choice must never depend
        // on map iteration order.
        while self.cache_used > self.cache_capacity {
            let victim = self
                .cache
                .iter()
                .min_by_key(|&(k, e)| (e.last_use, k))
                .map(|(k, _)| k.clone())
                .expect("cache non-empty while over capacity");
            let e = self.cache.remove(&victim).unwrap();
            self.cache_used -= e.bytes;
        }
    }

    fn cached_bytes(&self, path: &str) -> u64 {
        self.cache.get(path).map(|e| e.bytes).unwrap_or(0)
    }

    /// Server endpoint in the fabric.
    fn server(&self, cluster: &Cluster) -> NodeId {
        cluster.backend()
    }
}

impl StorageModel for NfsServer {
    fn name(&self) -> String {
        "NFS".to_string()
    }

    fn write_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        size: u64,
        tags: &TagSet,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let server = self.server(cluster);
        let t = cluster.fuse_op(at);
        let cpu = self.cpu.acquire(t, self.op_cost);
        let xfer = cluster.fabric.transfer(client, server, size, cpu.end);
        self.metrics.net_bytes += size;
        self.metrics.chunk_writes += 1;
        // Write-back: data lands in the page cache; flush occupies the
        // disk but does not block the client (close-to-open semantics).
        self.touch_cache(path, size);
        self.disk.write(size, xfer.end);
        self.client_cache.retain(|(_, p)| p != path);
        self.files.insert(path.to_string(), (size, tags.clone()));
        Ok(cluster.fuse_op(xfer.end))
    }

    fn read_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let size = self
            .files
            .get(path)
            .map(|(s, _)| *s)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        self.read_range(cluster, client, path, 0, size, at)
    }

    fn read_range(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        offset: u64,
        len: u64,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let size = self
            .files
            .get(path)
            .map(|(s, _)| *s)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        let server = self.server(cluster);
        let len = len.min(size.saturating_sub(offset));
        let t = cluster.fuse_op(at);
        // NFS client page cache: a full re-read by the same client is
        // served from client memory.
        if size <= cluster.calib().os_cache_bytes
            && self.client_cache.contains(&(client, path.to_string()))
        {
            self.metrics.cache_hit_bytes += len;
            self.metrics.local_bytes += len;
            return Ok(cluster.fuse_op(t));
        }
        let cpu = self.cpu.acquire(t, self.op_cost);
        // Cache split: whole-file granularity LRU.
        let cached = self.cached_bytes(path).min(size);
        let hit = ((cached.saturating_sub(offset)).min(len)) as u64;
        let miss = len - hit;
        self.metrics.cache_hit_bytes += hit;
        self.metrics.cache_miss_bytes += miss;
        let disk_done = if miss > 0 {
            let span = self.disk.read(miss, cpu.end);
            span.end
        } else {
            cpu.end
        };
        self.metrics.chunk_reads += 1;
        self.metrics.net_bytes += len;
        let xfer = cluster.fabric.transfer(server, client, len, disk_done);
        self.touch_cache(path, offset + len);
        if offset == 0 && len >= size {
            self.client_cache.insert((client, path.to_string()));
        }
        Ok(cluster.fuse_op(xfer.end))
    }

    fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        // Legacy storage: accepts the attribute, optimizes nothing.
        let server = self.server(cluster);
        let t = cluster.fuse_op(at);
        let rpc = cluster.fabric.rpc(client, server, t);
        let cpu = self.cpu.acquire(rpc.end, self.op_cost);
        if let Some((_, tags)) = self.files.get_mut(path) {
            tags.set(key, value);
        }
        let back = cluster.fabric.rpc(server, client, cpu.end);
        Ok(back.end)
    }

    fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError> {
        let server = self.server(cluster);
        let t = cluster.fuse_op(at);
        let rpc = cluster.fabric.rpc(client, server, t);
        let cpu = self.cpu.acquire(rpc.end, self.op_cost);
        let back = cluster.fabric.rpc(server, client, cpu.end);
        let value = self
            .files
            .get(path)
            .and_then(|(_, tags)| tags.get(key))
            .map(str::to_string);
        // `location` is NOT served: NFS does not expose data location.
        Ok((value, back.end))
    }

    fn locations(&self, _path: &str) -> Vec<NodeId> {
        Vec::new() // never exposed
    }

    fn file_size(&self, path: &str) -> Option<u64> {
        self.files.get(path).map(|(s, _)| *s)
    }

    fn delete(&mut self, path: &str) -> Result<(), StorageError> {
        if let Some(e) = self.cache.remove(path) {
            self.cache_used -= e.bytes;
        }
        self.client_cache.retain(|(_, p)| p != path);
        self.files
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| StorageError::NotFound(path.to_string()))
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    fn setup() -> (Cluster, NfsServer) {
        let calib = Calib::default();
        let cluster = Cluster::new(20, DiskKind::RamDisk, &calib);
        (cluster, NfsServer::new(&calib))
    }

    #[test]
    fn roundtrip() {
        let (mut cl, mut nfs) = setup();
        let w = nfs
            .write_file(&mut cl, NodeId(1), "/in", 100 * MB, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        let r = nfs.read_file(&mut cl, NodeId(2), "/in", w).unwrap();
        assert!(r > w);
        assert_eq!(nfs.file_size("/in"), Some(100 * MB));
    }

    #[test]
    fn server_nic_serializes_clients() {
        let (mut cl, mut nfs) = setup();
        nfs.preload("/db", 100 * MB);
        // warm cache so disk is not the bottleneck
        nfs.read_file(&mut cl, NodeId(1), "/db", SimTime::ZERO).unwrap();
        let mut finishes = Vec::new();
        for c in 2..12 {
            let done = nfs.read_file(&mut cl, NodeId(c), "/db", SimTime::ZERO).unwrap();
            finishes.push(done.as_secs_f64());
        }
        let max = finishes.iter().cloned().fold(0.0, f64::max);
        // 10 × 100MB over one 117MB/s NIC ≥ ~8.5s
        assert!(max > 8.0, "server NIC must serialize: {max}");
    }

    #[test]
    fn cache_hit_skips_disk() {
        let (mut cl, mut nfs) = setup();
        nfs.preload("/f", 50 * MB);
        let r1 = nfs.read_file(&mut cl, NodeId(1), "/f", SimTime::ZERO).unwrap();
        assert_eq!(nfs.metrics().cache_miss_bytes, 50 * MB);
        nfs.read_file(&mut cl, NodeId(2), "/f", r1).unwrap();
        assert_eq!(nfs.metrics().cache_miss_bytes, 50 * MB, "second read all hit");
        assert_eq!(nfs.metrics().cache_hit_bytes, 50 * MB);
    }

    #[test]
    fn lru_eviction() {
        let mut calib = Calib::default();
        calib.nfs_cache_bytes = 100 * MB;
        let mut cl = Cluster::new(4, DiskKind::RamDisk, &calib);
        let mut nfs = NfsServer::new(&calib);
        nfs.preload("/a", 60 * MB);
        nfs.preload("/b", 60 * MB);
        nfs.read_file(&mut cl, NodeId(1), "/a", SimTime::ZERO).unwrap();
        nfs.read_file(&mut cl, NodeId(1), "/b", SimTime::ZERO).unwrap(); // evicts /a
        let misses_before = nfs.metrics().cache_miss_bytes;
        // A different client (no client-cache hit) re-reads /a.
        nfs.read_file(&mut cl, NodeId(2), "/a", SimTime::ZERO).unwrap();
        assert_eq!(
            nfs.metrics().cache_miss_bytes,
            misses_before + 60 * MB,
            "/a was evicted"
        );
    }

    #[test]
    fn lru_eviction_ties_break_by_path() {
        // Regression: with two entries carrying the *same* LRU stamp the
        // victim used to be whatever the map iterator yielded first; the
        // tie must break deterministically by path ("/a" before "/z").
        let calib = Calib {
            nfs_cache_bytes: 100 * MB,
            ..Calib::default()
        };
        let mut nfs = NfsServer::new(&calib);
        nfs.cache.insert(
            "/z".to_string(),
            CacheEntry {
                bytes: 60 * MB,
                last_use: 7,
            },
        );
        nfs.cache.insert(
            "/a".to_string(),
            CacheEntry {
                bytes: 60 * MB,
                last_use: 7,
            },
        );
        nfs.cache_used = 120 * MB;
        nfs.lru_clock = 7;
        // Next touch pushes the cache over capacity and evicts one entry.
        nfs.touch_cache("/c", 10 * MB);
        assert!(
            !nfs.cache.contains_key("/a"),
            "/a is the deterministic victim on an LRU-stamp tie"
        );
        assert!(nfs.cache.contains_key("/z"));
        assert!(nfs.cache.contains_key("/c"));
        assert_eq!(nfs.cache_used, 70 * MB);
    }

    #[test]
    fn xattrs_accepted_but_inert() {
        let (mut cl, mut nfs) = setup();
        nfs.write_file(&mut cl, NodeId(1), "/f", MB, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        nfs.set_xattr(&mut cl, NodeId(1), "/f", "DP", "local", SimTime::ZERO)
            .unwrap();
        let (v, _) = nfs
            .get_xattr(&mut cl, NodeId(1), "/f", "DP", SimTime::ZERO)
            .unwrap();
        assert_eq!(v.as_deref(), Some("local"), "stored verbatim");
        let (loc, _) = nfs
            .get_xattr(&mut cl, NodeId(1), "/f", "location", SimTime::ZERO)
            .unwrap();
        assert_eq!(loc, None, "location never exposed");
        assert!(nfs.locations("/f").is_empty());
        assert_eq!(nfs.metrics().replicas_created, 0);
    }
}
