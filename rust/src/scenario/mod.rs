//! Hostile-scenario harness: named adversarial workloads against the
//! live store, replayable from one seed, each ending in a full
//! bottom-up audit.
//!
//! Benches (`crate::bench::experiments`) measure the live store on
//! *friendly* workloads; this module is the other half of the story —
//! what the numbers look like when the environment misbehaves. Each
//! scenario drives [`crate::live::LiveStore`] through one hostile shape
//! the paper's deployment model has to survive:
//!
//! * [`metadata_storm`](self) — thousands of tiny-file creates (and a
//!   third of them deleted again) while the injector fires put errors
//!   and latency spikes; every failed create retries.
//! * [`small_file_flood`](self) — the metadata storm's storage-layer
//!   sequel: a tiny-file workload through the full store, plus a raw
//!   ≥100k-tiny-chunk ingest race between the file-per-chunk `disk`
//!   backend and the packed segment-log `seg` backend — the
//!   file-count and wall-clock gap the tracked trajectory pins.
//! * [`hot_skew`](self) — a 10%-hot/90%-of-traffic read skew over
//!   replicated files under torn replica publishes and transient read
//!   errors; reads fail over and retry.
//! * [`tenant_pressure`](self) — three tenants with different placement
//!   hints interleave writes against deliberately scarce node capacity,
//!   deleting their own oldest files to make room when `NoSpace` hits.
//! * [`kill_recover`](self) — a storage node dies mid-workflow
//!   ([`crate::live::LiveStore::fail_node`]); the workload keeps
//!   writing and reading while churn re-replication drains, every byte
//!   is verified **without a reopen**, and the node rejoins
//!   ([`crate::live::LiveStore::join_node`]).
//!
//! Every scenario also runs over the process split
//! ([`Transport::Socket`], `--transport socket`): the node tier
//! becomes real `woss noded` daemon processes behind the wire
//! protocol, `kill_recover`'s node death a real SIGKILL, and its
//! rejoin a `noded --reopen` salvage restart — with the identical
//! workload, audit, and byte verification on top.
//!
//! Hostility comes from [`crate::live::FaultBackend`] (seed-driven,
//! interleaving-independent fault schedules) and the store's live-churn
//! API — so a run is replayable: the same seed yields the same fault
//! schedule and the same workload shape. Every scenario closes the same
//! way: injection is disabled (torn chunks were stored intact, so the
//! store heals), background replication drains, every surviving file's
//! fingerprint is re-verified, and [`crate::live::LiveStore::audit`]
//! must come back clean — namespace claims, usage accounting, and
//! physical backend contents in exact agreement, zero stray chunks.
//!
//! Results are machine-readable ([`ScenarioReport::to_json`], schema
//! [`SCENARIO_SCHEMA`]): `woss scenario all --json BENCH_scenarios.json`
//! is the tracked perf trajectory, and [`check_scenarios_json`] /
//! [`check_live_json`] are the schema gates `woss bench-check` (and
//! `scripts/verify.sh`) enforce on the emitted files.

use crate::dispatch::Registry;
use crate::hints::TagSet;
use crate::live::{
    chunk_crc, chunk_files_under, segment_files_under, store_over_cluster, BackendKind,
    ChunkBackend, Cluster, FaultSpec, FileBackend, LiveStore, LiveTuning, SegBackend, StoreAudit,
};
use crate::storage::{FileId, NodeId};
use crate::util::json::Json;
use crate::util::{Rng, Summary};
use std::path::PathBuf;
use std::time::Instant;

/// Schema tag stamped into (and required of) `BENCH_scenarios.json`.
/// v2 added the adaptive-placement columns: `adaptive` on every row,
/// `read_p99_ms_static` / `read_p99_ms_adaptive` on the skew
/// scenarios that dual-run both modes. v3 added the process-split
/// columns: `transport` on every row, `read_p99_ms_wire` on
/// `kill_recover` (the socket-transport leg's read p99 — the tracked
/// wire-overhead artifact).
pub const SCENARIO_SCHEMA: &str = "woss-scenarios-v3";

/// Which transport sits under the store a scenario drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Transport {
    /// Plain method calls on an in-process [`LiveStore`] — the default,
    /// trace-equivalent to the pre-split monolith.
    #[default]
    InProc,
    /// Real `woss noded` daemon processes per storage node, reached
    /// over the length-prefixed wire protocol (Unix sockets); node
    /// churn is a real SIGKILL + restart through the salvage path.
    Socket,
}

impl Transport {
    /// Stable label for reports (`inproc` | `socket`).
    pub fn label(&self) -> &'static str {
        match self {
            Transport::InProc => "inproc",
            Transport::Socket => "socket",
        }
    }
}

impl std::str::FromStr for Transport {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "inproc" | "local" => Ok(Transport::InProc),
            "socket" | "wire" => Ok(Transport::Socket),
            other => Err(format!("unknown transport '{other}' (inproc|socket)")),
        }
    }
}

/// How a scenario run is wired: replay seed, chunk backend, disk root,
/// and whether sizes are scaled down for the CI smoke leg.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Deterministic schedule seed — workload shape and fault schedule.
    pub seed: u64,
    /// Chunk backend under the store.
    pub backend: BackendKind,
    /// Persistent-backend root (`disk` | `seg`); each scenario uses
    /// its own subdirectory. `None` on a persistent backend
    /// auto-creates (and removes) a tempdir.
    pub data_dir: Option<PathBuf>,
    /// Scaled-down workload sizes for fast smoke runs.
    pub quick: bool,
    /// Disk I/O pool threads for the store under test
    /// ([`LiveTuning::io_workers`]); 1 = the serial data path.
    pub io_workers: usize,
    /// Adaptive load-aware placement/read decisions
    /// ([`LiveTuning::adaptive`]) for the primary run. The skew
    /// scenarios additionally dual-run both modes to record the
    /// static-vs-adaptive p99 columns regardless of this flag.
    pub adaptive: bool,
    /// Transport under the store: in-process method calls (default) or
    /// real `woss noded` daemons over the wire protocol.
    pub transport: Transport,
    /// Force the `kill_recover` socket leg that records
    /// `read_p99_ms_wire` even at `--quick` sizes (full-size in-process
    /// runs record it unconditionally).
    pub wire_bench: bool,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            seed: 7,
            backend: BackendKind::Memory,
            data_dir: None,
            quick: false,
            io_workers: 1,
            adaptive: false,
            transport: Transport::InProc,
            wire_bench: false,
        }
    }
}

/// Machine-readable outcome of one scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// Scenario name.
    pub name: &'static str,
    /// Backend label (`mem` | `disk` | `seg`).
    pub backend: &'static str,
    /// The replay seed the run used.
    pub seed: u64,
    /// Whether smoke sizes were used.
    pub quick: bool,
    /// Whether the primary run used adaptive load-aware decisions.
    pub adaptive: bool,
    /// Transport label of the primary run (`inproc` | `socket`).
    pub transport: &'static str,
    /// Skew scenarios only: p99 read latency (ms) of the static-mode
    /// leg of the dual run. `None` on scenarios that run once.
    pub read_p99_ms_static: Option<f64>,
    /// Skew scenarios only: p99 read latency (ms) of the
    /// adaptive-mode leg of the dual run.
    pub read_p99_ms_adaptive: Option<f64>,
    /// `kill_recover` only: p99 read latency (ms) of the
    /// socket-transport leg — the tracked wire-overhead column.
    /// `None` when the wire leg did not run (quick in-process runs
    /// without `--wire-bench`) or on other scenarios.
    pub read_p99_ms_wire: Option<f64>,
    /// Files alive at the final audit.
    pub files: usize,
    /// Workload operations issued (writes + reads + deletes, retries
    /// included).
    pub ops: usize,
    /// Payload bytes successfully written.
    pub bytes_written: u64,
    /// Payload bytes read back.
    pub bytes_read: u64,
    /// Wall-clock workload time, excluding the closing audit.
    pub elapsed_secs: f64,
    /// Median successful-write latency, milliseconds.
    pub write_p50_ms: f64,
    /// 99th-percentile successful-write latency, milliseconds.
    pub write_p99_ms: f64,
    /// Median successful-read latency, milliseconds.
    pub read_p50_ms: f64,
    /// 99th-percentile successful-read latency, milliseconds.
    pub read_p99_ms: f64,
    /// Faults the injector actually fired (all classes).
    pub faults_injected: u64,
    /// Operation-level errors the workload observed and retried.
    pub faults_surfaced: u64,
    /// `NoSpace` rejections absorbed (capacity-pressure scenarios).
    pub nospace_errors: u64,
    /// `fail_node` → re-replication drained, seconds (churn scenarios).
    pub recovery_secs: Option<f64>,
    /// Bytes landed on replacement holders by churn re-replication.
    pub bytes_rereplicated: u64,
    /// Chunks landed on replacement holders.
    pub chunks_rereplicated: u64,
    /// Chunks still below replica count at the end — must be zero.
    pub under_replicated_after: u64,
    /// The closing bottom-up audit.
    pub audit: StoreAudit,
    /// Physical `*.chunk` files left on disk (disk backend only) —
    /// must equal the audit's claimed replica count.
    pub chunk_files: Option<usize>,
    /// Physical `seg-*.log` files left on disk (`seg` backend only).
    /// Informational: the packed layout means this is O(segments), so
    /// it never equals the replica count the way `chunk_files` does.
    pub segment_files: Option<usize>,
    /// `small_file_flood` only: tiny chunks ingested per backend in
    /// the raw disk-vs-seg comparison (`None` on other scenarios).
    pub flood_chunks: Option<u64>,
    /// `small_file_flood` only: file-per-chunk ingest wall clock,
    /// seconds.
    pub flood_disk_secs: Option<f64>,
    /// `small_file_flood` only: packed segment-log ingest wall clock,
    /// seconds.
    pub flood_seg_secs: Option<f64>,
    /// `small_file_flood` only: files the `disk` backend left on disk
    /// after ingest — O(chunks), the layout this scenario indicts.
    pub flood_disk_files: Option<usize>,
    /// `small_file_flood` only: files the `seg` backend left on disk
    /// after the same ingest — O(segments).
    pub flood_seg_files: Option<usize>,
}

impl ScenarioReport {
    /// Aggregate payload throughput over the workload window, MB/s.
    pub fn throughput_mbps(&self) -> f64 {
        if self.elapsed_secs <= 0.0 {
            return 0.0;
        }
        (self.bytes_written + self.bytes_read) as f64 / 1048576.0 / self.elapsed_secs
    }

    /// Did the run close fully consistent? Clean audit, nothing left
    /// under-replicated, and (on disk) physical chunk files exactly
    /// matching the namespace's replica claims.
    pub fn clean(&self) -> bool {
        self.audit.clean()
            && self.under_replicated_after == 0
            && self
                .chunk_files
                .map(|n| n == self.audit.replicas_claimed)
                .unwrap_or(true)
    }

    /// One human-readable result line.
    pub fn summary_line(&self) -> String {
        let recovery = match self.recovery_secs {
            Some(s) => format!(
                ", recovered in {s:.3}s ({} B re-replicated)",
                self.bytes_rereplicated
            ),
            None => String::new(),
        };
        let backend_tag = if self.transport == "socket" {
            format!("{}/socket", self.backend)
        } else {
            self.backend.to_string()
        };
        format!(
            "{} [{}] seed={}: {} files, {} ops, {:.1} MB/s, write p50/p99 {:.2}/{:.2} ms, \
             read p50/p99 {:.2}/{:.2} ms, {} faults injected ({} surfaced){}, audit {}",
            self.name,
            backend_tag,
            self.seed,
            self.files,
            self.ops,
            self.throughput_mbps(),
            self.write_p50_ms,
            self.write_p99_ms,
            self.read_p50_ms,
            self.read_p99_ms,
            self.faults_injected,
            self.faults_surfaced,
            recovery,
            if self.clean() { "clean" } else { "DIRTY" },
        )
    }

    /// The [`SCENARIO_SCHEMA`] record for this run.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", self.name.into()),
            ("backend", self.backend.into()),
            ("seed", self.seed.into()),
            ("quick", self.quick.into()),
            ("adaptive", self.adaptive.into()),
            ("transport", self.transport.into()),
            (
                "read_p99_ms_static",
                self.read_p99_ms_static.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "read_p99_ms_adaptive",
                self.read_p99_ms_adaptive
                    .map(Json::Num)
                    .unwrap_or(Json::Null),
            ),
            (
                "read_p99_ms_wire",
                self.read_p99_ms_wire.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("files", self.files.into()),
            ("ops", self.ops.into()),
            ("bytes_written", self.bytes_written.into()),
            ("bytes_read", self.bytes_read.into()),
            ("elapsed_secs", self.elapsed_secs.into()),
            ("throughput_mbps", self.throughput_mbps().into()),
            ("write_p50_ms", self.write_p50_ms.into()),
            ("write_p99_ms", self.write_p99_ms.into()),
            ("read_p50_ms", self.read_p50_ms.into()),
            ("read_p99_ms", self.read_p99_ms.into()),
            ("faults_injected", self.faults_injected.into()),
            ("faults_surfaced", self.faults_surfaced.into()),
            ("nospace_errors", self.nospace_errors.into()),
            (
                "recovery_secs",
                self.recovery_secs.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("bytes_rereplicated", self.bytes_rereplicated.into()),
            ("chunks_rereplicated", self.chunks_rereplicated.into()),
            ("under_replicated_after", self.under_replicated_after.into()),
            ("replicas_claimed", self.audit.replicas_claimed.into()),
            ("stray_chunks", self.audit.stray_chunks.into()),
            ("missing_chunks", self.audit.missing_chunks.into()),
            ("usage_exact", self.audit.usage_exact().into()),
            ("audit_clean", self.clean().into()),
            (
                "segment_files",
                self.segment_files
                    .map(|v| Json::Num(v as f64))
                    .unwrap_or(Json::Null),
            ),
            (
                "flood_chunks",
                self.flood_chunks
                    .map(|v| Json::Num(v as f64))
                    .unwrap_or(Json::Null),
            ),
            (
                "flood_disk_secs",
                self.flood_disk_secs.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "flood_seg_secs",
                self.flood_seg_secs.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "flood_disk_files",
                self.flood_disk_files
                    .map(|v| Json::Num(v as f64))
                    .unwrap_or(Json::Null),
            ),
            (
                "flood_seg_files",
                self.flood_seg_files
                    .map(|v| Json::Num(v as f64))
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

/// All scenario names, in documentation order.
pub fn names() -> Vec<&'static str> {
    vec![
        "metadata_storm",
        "small_file_flood",
        "hot_skew",
        "tenant_pressure",
        "kill_recover",
    ]
}

/// Run one scenario by name.
pub fn run(name: &str, cfg: &ScenarioConfig) -> Result<ScenarioReport, String> {
    match name {
        "metadata_storm" => metadata_storm(cfg),
        "small_file_flood" => small_file_flood(cfg),
        "hot_skew" => hot_skew(cfg),
        "tenant_pressure" => tenant_pressure(cfg),
        "kill_recover" => kill_recover(cfg),
        other => Err(format!(
            "unknown scenario '{other}' (see `woss scenario --list`)"
        )),
    }
}

/// Run every scenario under one config, in [`names`] order.
pub fn run_all(cfg: &ScenarioConfig) -> Result<Vec<ScenarioReport>, String> {
    names().into_iter().map(|n| run(n, cfg)).collect()
}

/// Serialize scenario reports as the tracked `BENCH_scenarios.json`
/// document ([`SCENARIO_SCHEMA`]).
pub fn results_json(reports: &[ScenarioReport], seed: u64) -> Json {
    Json::obj([
        ("schema", SCENARIO_SCHEMA.into()),
        ("seed", seed.into()),
        (
            "scenarios",
            Json::Arr(reports.iter().map(ScenarioReport::to_json).collect()),
        ),
    ])
}

/// Validate a `BENCH_scenarios.json` document: schema tag, non-empty
/// scenario list, the numeric fields the perf trajectory tracks, a
/// clean closing audit on every entry, and a measured recovery time on
/// the churn scenario. This is what `woss bench-check` runs.
pub fn check_scenarios_json(text: &str) -> Result<(), String> {
    let doc = Json::parse(text).map_err(|e| format!("scenarios file: {e}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some(SCENARIO_SCHEMA) {
        return Err(format!(
            "scenarios file: missing or drifted schema tag (want \"{SCENARIO_SCHEMA}\")"
        ));
    }
    let Some(Json::Arr(scenarios)) = doc.get("scenarios") else {
        return Err("scenarios file: missing 'scenarios' array".into());
    };
    if scenarios.is_empty() {
        return Err("scenarios file: empty 'scenarios' array".into());
    }
    for s in scenarios {
        let name = s
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| "scenarios file: entry without 'name'".to_string())?;
        for field in [
            "elapsed_secs",
            "throughput_mbps",
            "write_p50_ms",
            "write_p99_ms",
            "read_p50_ms",
            "read_p99_ms",
            "bytes_written",
            "faults_injected",
            "under_replicated_after",
            "stray_chunks",
            "missing_chunks",
        ] {
            if s.get(field).and_then(Json::as_f64).is_none() {
                return Err(format!("scenario '{name}': missing numeric '{field}'"));
            }
        }
        if s.get("backend").and_then(Json::as_str).is_none() {
            return Err(format!("scenario '{name}': missing 'backend'"));
        }
        match s.get("transport").and_then(Json::as_str) {
            Some("inproc") | Some("socket") => {}
            _ => {
                return Err(format!(
                    "scenario '{name}': missing 'transport' (inproc|socket)"
                ))
            }
        }
        if !matches!(s.get("adaptive"), Some(Json::Bool(_))) {
            return Err(format!("scenario '{name}': missing boolean 'adaptive'"));
        }
        if s.get("audit_clean") != Some(&Json::Bool(true)) {
            return Err(format!("scenario '{name}' did not close with a clean audit"));
        }
        if name == "hot_skew" || name == "tenant_pressure" {
            // The skew scenarios dual-run static vs adaptive; both
            // p99 columns must be present, and on a full-size
            // `hot_skew` row the adaptive leg must not lose — the
            // tracked artifact of the cross-layer feedback loop. The
            // gate is skipped at smoke sizes, where a handful of
            // reads makes p99 noise.
            let p99_static = s
                .get("read_p99_ms_static")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("scenario '{name}': missing numeric 'read_p99_ms_static'"))?;
            let p99_adaptive = s
                .get("read_p99_ms_adaptive")
                .and_then(Json::as_f64)
                .ok_or_else(|| {
                    format!("scenario '{name}': missing numeric 'read_p99_ms_adaptive'")
                })?;
            if name == "hot_skew"
                && s.get("quick") != Some(&Json::Bool(true))
                && p99_adaptive > p99_static
            {
                return Err(format!(
                    "hot_skew: adaptive p99 read latency ({p99_adaptive:.3} ms) did not \
                     beat static ({p99_static:.3} ms)"
                ));
            }
        }
        if name == "kill_recover" {
            if s.get("recovery_secs").and_then(Json::as_f64).is_none() {
                return Err("kill_recover: missing numeric 'recovery_secs'".into());
            }
            if s.get("bytes_rereplicated").and_then(Json::as_f64).unwrap_or(0.0) <= 0.0 {
                return Err("kill_recover: no bytes were re-replicated".into());
            }
            // A full-size row must carry the socket-transport leg's
            // read p99 — the tracked wire-overhead column of the
            // process split. Quick rows may skip the leg (it spawns
            // real daemons) unless `--wire-bench` forced it.
            if s.get("quick") != Some(&Json::Bool(true)) {
                let wire = s
                    .get("read_p99_ms_wire")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| {
                        "kill_recover: missing numeric 'read_p99_ms_wire'".to_string()
                    })?;
                if wire <= 0.0 {
                    return Err(format!(
                        "kill_recover: wire-leg read p99 must be positive (got {wire})"
                    ));
                }
            }
        }
        if name == "small_file_flood" {
            // The tracked file-per-chunk vs packed-log gap: every
            // flood field present, the packed log's file count at
            // least two orders of magnitude below file-per-chunk's,
            // and — on a full-size (non-quick) row — ≥100k chunks
            // with `seg` winning the ingest race outright. Timing is
            // only gated at full size: at smoke sizes the gap can
            // drown in noise.
            let num = |field: &str| -> Result<f64, String> {
                s.get(field)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("small_file_flood: missing numeric '{field}'"))
            };
            let chunks = num("flood_chunks")?;
            let disk_secs = num("flood_disk_secs")?;
            let seg_secs = num("flood_seg_secs")?;
            let disk_files = num("flood_disk_files")?;
            let seg_files = num("flood_seg_files")?;
            if seg_files * 100.0 > disk_files {
                return Err(format!(
                    "small_file_flood: seg left {seg_files} files vs disk's \
                     {disk_files} — not O(segments)"
                ));
            }
            if s.get("quick") != Some(&Json::Bool(true)) {
                if chunks < 100_000.0 {
                    return Err(format!(
                        "small_file_flood: full-size row must ingest ≥100k chunks \
                         (got {chunks})"
                    ));
                }
                if seg_secs >= disk_secs {
                    return Err(format!(
                        "small_file_flood: seg ingest ({seg_secs:.3}s) did not beat \
                         file-per-chunk ({disk_secs:.3}s)"
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Validate a `BENCH_live.json` document (`woss experiment live
/// --json`): the three live experiments present, throughput rows on
/// `live_throughput`, reopen/recovery timings on `live_recovery`.
pub fn check_live_json(text: &str) -> Result<(), String> {
    let doc = Json::parse(text).map_err(|e| format!("live file: {e}"))?;
    let Some(Json::Arr(exps)) = doc.get("experiments") else {
        return Err("live file: missing 'experiments' array".into());
    };
    let mut seen: Vec<String> = Vec::new();
    for e in exps {
        let id = e
            .get("id")
            .and_then(Json::as_str)
            .ok_or_else(|| "live file: experiment without 'id'".to_string())?
            .to_string();
        let row_fields: &[&str] = match id.as_str() {
            // Percentile fields landed with the pipelined data path:
            // every throughput row must carry the per-op
            // latency distribution alongside the aggregate rates.
            "live_throughput" => &[
                "write_mbps",
                "read_mbps",
                "put_p50_us",
                "put_p95_us",
                "put_p99_us",
                "get_p50_us",
                "get_p95_us",
                "get_p99_us",
                "spill_p50_us",
                "spill_p95_us",
                "spill_p99_us",
            ],
            "live_recovery" => &["reopen_ms"],
            _ => &[],
        };
        if !row_fields.is_empty() {
            let Some(Json::Arr(rows)) = e.get("rows") else {
                return Err(format!("live file: '{id}' has no 'rows' array"));
            };
            if rows.is_empty() {
                return Err(format!("live file: '{id}' has empty 'rows'"));
            }
            for row in rows {
                for field in row_fields {
                    if row.get(field).and_then(Json::as_f64).is_none() {
                        return Err(format!("live file: '{id}' row missing numeric '{field}'"));
                    }
                }
            }
        }
        seen.push(id);
    }
    for required in ["live_throughput", "live_cache", "live_recovery"] {
        if !seen.iter().any(|id| id == required) {
            return Err(format!("live file: missing experiment '{required}'"));
        }
    }
    Ok(())
}

/// `(path, byte length, payload crc)` recorded at write time and
/// re-verified bottom-up before the closing audit.
type Fingerprint = (String, usize, u64);

/// Per-run operation tallies the scenarios accumulate.
#[derive(Default)]
struct Tally {
    ops: usize,
    bytes_written: u64,
    bytes_read: u64,
    write_lat_ms: Vec<f64>,
    read_lat_ms: Vec<f64>,
    surfaced: u64,
    nospace: u64,
}

/// Snapshot taken by [`close_out`] after the workload window.
struct Closing {
    injected: u64,
    audit: StoreAudit,
    under: u64,
    chunk_files: Option<usize>,
    segment_files: Option<usize>,
}

/// Per-scenario store: on the disk backend each scenario runs in its
/// own subdirectory of the configured root (or an owned tempdir). On
/// [`Transport::Socket`] the node tier is a [`Cluster`] of real `woss
/// noded` daemon processes; the cluster is kept alive by the store's
/// supervisor handle (fault injection still works — the
/// [`crate::live::FaultBackend`] decorator wraps the remote client
/// backends), and churn becomes a real SIGKILL + salvage restart.
fn store_for(
    cfg: &ScenarioConfig,
    name: &str,
    nodes: usize,
    capacity: u64,
    fault: Option<FaultSpec>,
) -> Result<LiveStore, String> {
    let scenario_dir = match (cfg.backend, &cfg.data_dir) {
        (kind, Some(root)) if kind.is_persistent() => Some(root.join(name)),
        _ => None,
    };
    let tuning = LiveTuning {
        backend: cfg.backend,
        data_dir: match cfg.transport {
            Transport::InProc => scenario_dir.clone(),
            Transport::Socket => None,
        },
        fault,
        io_workers: cfg.io_workers,
        adaptive: cfg.adaptive,
        ..LiveTuning::default()
    };
    match cfg.transport {
        Transport::InProc => LiveStore::try_with_tuning(Registry::woss(), nodes, capacity, tuning)
            .map_err(|e| format!("bring up store: {e}")),
        Transport::Socket => {
            let cluster = Cluster::spawn(nodes, cfg.backend, scenario_dir.as_deref())
                .map_err(|e| format!("spawn node daemons: {e}"))?;
            Ok(store_over_cluster(
                Registry::woss(),
                &cluster,
                capacity,
                tuning,
            ))
        }
    }
}

/// Deterministic payload: one fresh odd multiplier per file so every
/// file's bytes are distinct and every position varies.
fn payload(rng: &mut Rng, len: usize) -> Vec<u8> {
    let mult = rng.next_u64() | 1;
    (0..len)
        .map(|i| ((i as u64).wrapping_mul(mult) >> 3) as u8)
        .collect()
}

/// Disable injection (the injector never altered stored bytes, so
/// flagged chunks heal), drain background replication, and take the
/// closing audit. Injected-fault counters are read first — disabling
/// stops new faults, not the tally.
fn close_out(store: &LiveStore) -> Closing {
    let injected = store.fault_control().map(|c| c.total()).unwrap_or(0);
    if let Some(ctl) = store.fault_control() {
        ctl.set_enabled(false);
    }
    store.flush_replication();
    Closing {
        injected,
        audit: store.audit(),
        under: store.under_replicated(),
        // Per-chunk file accounting only applies to the file-per-chunk
        // layout; on `seg` the replica claims live packed inside a few
        // segment logs, reported separately (and informationally).
        chunk_files: match store.backend_kind() {
            BackendKind::Disk => store.data_dir().map(chunk_files_under),
            _ => None,
        },
        segment_files: match store.backend_kind() {
            BackendKind::Seg => store.data_dir().map(segment_files_under),
            _ => None,
        },
    }
}

/// Re-read every surviving file and compare length + crc against the
/// fingerprint recorded at write time. Runs with injection disabled:
/// any mismatch here is real corruption, not an injected fault.
fn verify_fingerprints(
    store: &LiveStore,
    expected: &[Fingerprint],
    seed: u64,
) -> Result<(), String> {
    let nodes = store.n_nodes();
    for (i, (path, len, crc)) in expected.iter().enumerate() {
        let reader = (0..nodes)
            .map(|n| NodeId((i + n) % nodes))
            .find(|&n| store.is_alive(n))
            .ok_or_else(|| "no live node to read from".to_string())?;
        let bytes = store
            .read_file(reader, path)
            .map_err(|e| format!("final read of {path} failed (seed={seed}): {e}"))?;
        if bytes.len() != *len || chunk_crc(&bytes) != *crc {
            return Err(format!(
                "fingerprint mismatch on {path}: got {} bytes (seed={seed})",
                bytes.len()
            ));
        }
    }
    Ok(())
}

/// Assemble the report from a finished workload window.
#[allow(clippy::too_many_arguments)]
fn report(
    name: &'static str,
    cfg: &ScenarioConfig,
    store: &LiveStore,
    tally: Tally,
    files: usize,
    elapsed_secs: f64,
    recovery_secs: Option<f64>,
    closing: Closing,
) -> ScenarioReport {
    let pct = |samples: &[f64], p: f64| {
        if samples.is_empty() {
            0.0
        } else {
            Summary::from_iter(samples.iter().copied()).percentile(p)
        }
    };
    ScenarioReport {
        name,
        backend: cfg.backend.label(),
        seed: cfg.seed,
        quick: cfg.quick,
        adaptive: cfg.adaptive,
        transport: cfg.transport.label(),
        read_p99_ms_static: None,
        read_p99_ms_adaptive: None,
        read_p99_ms_wire: None,
        files,
        ops: tally.ops,
        bytes_written: tally.bytes_written,
        bytes_read: tally.bytes_read,
        elapsed_secs,
        write_p50_ms: pct(&tally.write_lat_ms, 50.0),
        write_p99_ms: pct(&tally.write_lat_ms, 99.0),
        read_p50_ms: pct(&tally.read_lat_ms, 50.0),
        read_p99_ms: pct(&tally.read_lat_ms, 99.0),
        faults_injected: closing.injected,
        faults_surfaced: tally.surfaced,
        nospace_errors: tally.nospace,
        recovery_secs,
        bytes_rereplicated: store.bytes_rereplicated(),
        chunks_rereplicated: store.chunks_rereplicated(),
        under_replicated_after: closing.under,
        audit: closing.audit,
        chunk_files: closing.chunk_files,
        segment_files: closing.segment_files,
        flood_chunks: None,
        flood_disk_secs: None,
        flood_seg_secs: None,
        flood_disk_files: None,
        flood_seg_files: None,
    }
}

/// Write one file, retrying injected failures; records latency of the
/// successful attempt only (failed attempts are surfaced faults, not
/// service time).
fn write_with_retry(
    store: &LiveStore,
    client: NodeId,
    path: &str,
    data: &[u8],
    tags: &TagSet,
    tally: &mut Tally,
    seed: u64,
) -> Result<(), String> {
    let mut tries = 0u32;
    loop {
        tally.ops += 1;
        let t = Instant::now();
        match store.write_file(client, path, data, tags) {
            Ok(()) => {
                tally.write_lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
                tally.bytes_written += data.len() as u64;
                return Ok(());
            }
            Err(e) if tries < 8 => {
                tries += 1;
                tally.surfaced += 1;
                if matches!(e, crate::storage::StorageError::NoSpace(_)) {
                    tally.nospace += 1;
                    return Err(format!("nospace:{path}"));
                }
            }
            Err(e) => return Err(format!("write {path} kept failing (seed={seed}): {e}")),
        }
    }
}

/// Many-small-files metadata storm. Four writers' worth of tiny files
/// (one chunk each) land under injected put errors and latency spikes;
/// a third of the namespace is deleted again, and the survivors are
/// read back byte-verified before the audit.
fn metadata_storm(cfg: &ScenarioConfig) -> Result<ScenarioReport, String> {
    const NODES: usize = 4;
    let files = if cfg.quick { 240 } else { 1000 };
    let spec = FaultSpec {
        seed: cfg.seed,
        put_error_permille: 25,
        delay_permille: 40,
        delay_us: 200,
        ..FaultSpec::default()
    };
    let store = store_for(cfg, "metadata_storm", NODES, u64::MAX / 2, Some(spec))?;
    let mut rng = Rng::new(cfg.seed ^ 0x5708_6d00);
    let mut tally = Tally::default();
    let mut expected: Vec<Fingerprint> = Vec::new();
    let t0 = Instant::now();

    for f in 0..files {
        let len = 512 + rng.gen_range(7 * 1024) as usize;
        let data = payload(&mut rng, len);
        let path = format!("/storm/w{}/f{f}", f % 4);
        let tags = match f % 3 {
            0 => TagSet::from_pairs([("DP", "local")]),
            1 => TagSet::from_pairs([("DP", "scatter 2")]),
            _ => TagSet::new(),
        };
        write_with_retry(&store, NodeId(f % NODES), &path, &data, &tags, &mut tally, cfg.seed)?;
        expected.push((path, len, chunk_crc(&data)));
    }

    // Churn the namespace: every third file dies again. Deletes under
    // the storm are the metadata ops the audit must reconcile exactly.
    let mut kept: Vec<Fingerprint> = Vec::new();
    for (i, fp) in expected.into_iter().enumerate() {
        if i % 3 == 0 {
            store
                .delete(&fp.0)
                .map_err(|e| format!("storm delete {}: {e}", fp.0))?;
            tally.ops += 1;
        } else {
            kept.push(fp);
        }
    }

    // Read-back pass (no read faults in this scenario's spec): every
    // survivor byte-verified while injection is still firing on puts.
    for (i, (path, len, crc)) in kept.iter().enumerate() {
        let t = Instant::now();
        let bytes = store
            .read_file(NodeId(i % NODES), path)
            .map_err(|e| format!("storm read {path}: {e}"))?;
        tally.read_lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        tally.ops += 1;
        tally.bytes_read += bytes.len() as u64;
        if bytes.len() != *len || chunk_crc(&bytes) != *crc {
            return Err(format!("storm corruption on {path} (seed={})", cfg.seed));
        }
    }

    let elapsed = t0.elapsed().as_secs_f64();
    let closing = close_out(&store);
    verify_fingerprints(&store, &kept, cfg.seed)?;
    let files_alive = kept.len();
    Ok(report(
        "metadata_storm",
        cfg,
        &store,
        tally,
        files_alive,
        elapsed,
        None,
        closing,
    ))
}

/// Outcome of the raw disk-vs-seg tiny-chunk ingest race.
struct FloodOutcome {
    chunks: u64,
    disk_secs: f64,
    seg_secs: f64,
    disk_files: usize,
    seg_files: usize,
}

/// Ingest the same flood of tiny chunks into a bare [`FileBackend`]
/// and a bare [`SegBackend`], then delete everything and require both
/// to return every byte. This is the layer the paper's
/// "millions of small files" argument is about: file-per-chunk pays
/// one file + one fsync per tiny chunk, the packed log pays one
/// append (fsynced on the group-commit boundary) and keeps the file
/// count O(segments).
fn flood_backends(cfg: &ScenarioConfig) -> Result<FloodOutcome, String> {
    let chunks: u64 = if cfg.quick { 800 } else { 100_000 };
    let root = match &cfg.data_dir {
        Some(dir) => dir.join("small_file_flood").join("raw"),
        None => std::env::temp_dir().join(format!("woss-flood-{}", std::process::id())),
    };
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).map_err(|e| format!("flood dir {}: {e}", root.display()))?;
    let body = [0x5au8; 64];

    let disk_dir = root.join("disk");
    let disk = FileBackend::new(&disk_dir).map_err(|e| format!("flood disk backend: {e}"))?;
    let t = Instant::now();
    for c in 0..chunks {
        disk.put((FileId(1), c), &body)
            .map_err(|e| format!("flood disk put {c}: {e}"))?;
    }
    let disk_secs = t.elapsed().as_secs_f64();
    let disk_files = chunk_files_under(&disk_dir);

    let seg_dir = root.join("seg");
    let seg = SegBackend::new(&seg_dir).map_err(|e| format!("flood seg backend: {e}"))?;
    let t = Instant::now();
    for c in 0..chunks {
        seg.put((FileId(1), c), &body)
            .map_err(|e| format!("flood seg put {c}: {e}"))?;
    }
    let seg_secs = t.elapsed().as_secs_f64();
    let seg_files = segment_files_under(&seg_dir);

    // Spot-verify both layouts actually hold the bytes before the
    // teardown (ends, middle, and a seed-driven sample).
    let mut rng = Rng::new(cfg.seed ^ 0xf100_d00d);
    for probe in [0, chunks / 2, chunks - 1]
        .into_iter()
        .chain((0..8).map(|_| rng.next_u64() % chunks))
    {
        for (label, b) in [("disk", &disk as &dyn ChunkBackend), ("seg", &seg)] {
            let got = b
                .get((FileId(1), probe))
                .map_err(|e| format!("flood {label} read {probe}: {e}"))?;
            if got.as_deref() != Some(&body[..]) {
                return Err(format!("flood {label} chunk {probe} corrupt or missing"));
            }
        }
    }

    // The space must come back: file-per-chunk by unlinking, the
    // packed log by compaction.
    for c in 0..chunks {
        disk.delete((FileId(1), c));
        seg.delete((FileId(1), c));
    }
    seg.maintain();
    if disk.used_bytes() != 0 || seg.used_bytes() != 0 {
        return Err(format!(
            "flood deletes left bytes behind: disk={} seg={}",
            disk.used_bytes(),
            seg.used_bytes()
        ));
    }
    if chunk_files_under(&disk_dir) != 0 {
        return Err("flood: stray chunk files after delete".into());
    }
    drop(disk);
    drop(seg);
    let _ = std::fs::remove_dir_all(&root);
    Ok(FloodOutcome {
        chunks,
        disk_secs,
        seg_secs,
        disk_files,
        seg_files,
    })
}

/// The metadata storm's storage-layer sequel: a tiny-file workload
/// through the full store on the configured backend (every file one
/// small chunk, a read-back pass, a clean audit), then the raw
/// [`flood_backends`] ingest race — ≥100k tiny chunks per backend at
/// full size — whose numbers land in the `flood_*` report fields that
/// `bench-check` gates. The scenario fails unless the packed log's
/// file count is at least two orders of magnitude below
/// file-per-chunk's.
fn small_file_flood(cfg: &ScenarioConfig) -> Result<ScenarioReport, String> {
    const NODES: usize = 2;
    let files = if cfg.quick { 160 } else { 600 };
    let store = store_for(cfg, "small_file_flood", NODES, u64::MAX / 2, None)?;
    let mut rng = Rng::new(cfg.seed ^ 0x5f10_0d00);
    let mut tally = Tally::default();
    let mut expected: Vec<Fingerprint> = Vec::new();
    let t0 = Instant::now();

    for f in 0..files {
        let len = 64 + rng.gen_range(448) as usize;
        let data = payload(&mut rng, len);
        let path = format!("/flood/f{f}");
        let tags = TagSet::from_pairs([("DP", "local")]);
        write_with_retry(&store, NodeId(f % NODES), &path, &data, &tags, &mut tally, cfg.seed)?;
        expected.push((path, len, chunk_crc(&data)));
    }
    for (i, (path, len, crc)) in expected.iter().enumerate() {
        let t = Instant::now();
        let bytes = store
            .read_file(NodeId(i % NODES), path)
            .map_err(|e| format!("flood read {path}: {e}"))?;
        tally.read_lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        tally.ops += 1;
        tally.bytes_read += bytes.len() as u64;
        if bytes.len() != *len || chunk_crc(&bytes) != *crc {
            return Err(format!("flood corruption on {path} (seed={})", cfg.seed));
        }
    }

    let elapsed = t0.elapsed().as_secs_f64();
    let closing = close_out(&store);
    verify_fingerprints(&store, &expected, cfg.seed)?;

    let flood = flood_backends(cfg)?;
    if flood.seg_files * 100 > flood.disk_files {
        return Err(format!(
            "flood: seg left {} files vs disk's {} — the packed layout \
             must stay O(segments), not O(chunks)",
            flood.seg_files, flood.disk_files
        ));
    }

    let files_alive = expected.len();
    let mut rep = report(
        "small_file_flood",
        cfg,
        &store,
        tally,
        files_alive,
        elapsed,
        None,
        closing,
    );
    rep.flood_chunks = Some(flood.chunks);
    rep.flood_disk_secs = Some(flood.disk_secs);
    rep.flood_seg_secs = Some(flood.seg_secs);
    rep.flood_disk_files = Some(flood.disk_files);
    rep.flood_seg_files = Some(flood.seg_files);
    Ok(rep)
}

/// Skewed hot-file traffic: 10% of the files take ~90% of the reads,
/// under torn replica publishes and transient read errors. Hot files
/// carry `Replication=3`, so failover almost always hides the faults;
/// reads retry when an attempt exhausts every holder.
///
/// Dual-runs the identical seeded workload with adaptive decisions
/// off and on — the proving ground for the load-feedback plane. The
/// primary report reflects `cfg.adaptive`; both legs' p99 read
/// latencies are recorded so `bench-check` gates the win as a tracked
/// artifact.
fn hot_skew(cfg: &ScenarioConfig) -> Result<ScenarioReport, String> {
    dual_run(cfg, hot_skew_once)
}

/// Static-vs-adaptive harness for the skew scenarios: run `once` with
/// adaptive forced off then on (distinct store names keep persistent
/// backends' on-disk subtrees apart), pick the primary leg by
/// `cfg.adaptive`, and stamp both legs' p99 read latencies on it.
fn dual_run(
    cfg: &ScenarioConfig,
    once: fn(&ScenarioConfig, &str) -> Result<ScenarioReport, String>,
) -> Result<ScenarioReport, String> {
    let leg = |adaptive: bool, suffix: &str| -> Result<ScenarioReport, String> {
        let leg_cfg = ScenarioConfig {
            adaptive,
            ..cfg.clone()
        };
        once(&leg_cfg, suffix)
    };
    let static_rep = leg(false, "static")?;
    let adaptive_rep = leg(true, "adaptive")?;
    let (p99_static, p99_adaptive) = (static_rep.read_p99_ms, adaptive_rep.read_p99_ms);
    let mut rep = if cfg.adaptive { adaptive_rep } else { static_rep };
    rep.adaptive = cfg.adaptive;
    rep.read_p99_ms_static = Some(p99_static);
    rep.read_p99_ms_adaptive = Some(p99_adaptive);
    Ok(rep)
}

fn hot_skew_once(cfg: &ScenarioConfig, leg: &str) -> Result<ScenarioReport, String> {
    const NODES: usize = 4;
    const READERS: usize = 4;
    let files = if cfg.quick { 30 } else { 120 };
    let reads = if cfg.quick { 400 } else { 4000 };
    let hot_count = (files / 10).max(1);
    let spec = FaultSpec {
        seed: cfg.seed,
        torn_put_permille: 8,
        read_error_permille: 12,
        delay_permille: 30,
        delay_us: 100,
        ..FaultSpec::default()
    };
    let store = store_for(cfg, &format!("hot_skew_{leg}"), NODES, u64::MAX / 2, Some(spec))?;
    let mut rng = Rng::new(cfg.seed ^ 0x4075_6b00);
    let mut tally = Tally::default();
    let mut expected: Vec<Fingerprint> = Vec::new();
    let t0 = Instant::now();

    for f in 0..files {
        let len = 64 * 1024 + rng.gen_range(192 * 1024) as usize;
        let data = payload(&mut rng, len);
        let path = format!("/skew/f{f}");
        // The hot prefix of the namespace replicates wider.
        let tags = if f < hot_count {
            TagSet::from_pairs([("Replication", "3"), ("RepSmntc", "optimistic")])
        } else {
            TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")])
        };
        write_with_retry(&store, NodeId(f % NODES), &path, &data, &tags, &mut tally, cfg.seed)?;
        expected.push((path, len, chunk_crc(&data)));
    }
    // Replicas on their holders before the read storm begins.
    store.flush_replication();

    // Concurrent skewed readers. The fault schedule is a pure function
    // of (key, attempt), so the aggregate outcome is seed-deterministic
    // even though threads interleave.
    let reader_results = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..READERS)
            .map(|r| {
                let store = &store;
                let expected = &expected;
                let mut rng = Rng::new(cfg.seed ^ 0xbeef ^ ((r as u64) << 24));
                let seed = cfg.seed;
                scope.spawn(move || -> Result<(Vec<f64>, u64, u64, usize), String> {
                    let mut lat = Vec::new();
                    let mut surfaced = 0u64;
                    let mut bytes_read = 0u64;
                    let mut ops = 0usize;
                    for _ in 0..reads / READERS {
                        let (path, len, crc) = if rng.gen_range(10) < 9 {
                            &expected[rng.range_usize(0, hot_count)]
                        } else {
                            &expected[rng.range_usize(hot_count, expected.len())]
                        };
                        let mut tries = 0u32;
                        let mut got = None;
                        while got.is_none() {
                            ops += 1;
                            let t = Instant::now();
                            match store.read_file(NodeId(rng.range_usize(0, NODES)), path) {
                                Ok(bytes) => {
                                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                                    got = Some(bytes);
                                }
                                Err(_) => {
                                    tries += 1;
                                    surfaced += 1;
                                    if tries >= 8 {
                                        // Every holder's copy can be torn
                                        // at once — an outage until the
                                        // storm passes, not corruption.
                                        // The closing fingerprint pass
                                        // (injection off) still proves
                                        // the bytes survived.
                                        break;
                                    }
                                }
                            }
                        }
                        let Some(bytes) = got else { continue };
                        bytes_read += bytes.len() as u64;
                        // A read that succeeds must be exact: injected
                        // faults surface as errors, never as bytes.
                        if bytes.len() != *len || chunk_crc(&bytes) != *crc {
                            return Err(format!("skew corruption on {path} (seed={seed})"));
                        }
                    }
                    Ok((lat, surfaced, bytes_read, ops))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("skew reader panicked"))
            .collect::<Vec<_>>()
    });
    for r in reader_results {
        let (lat, surfaced, bytes_read, ops) = r?;
        tally.read_lat_ms.extend(lat);
        tally.surfaced += surfaced;
        tally.bytes_read += bytes_read;
        tally.ops += ops;
    }

    let elapsed = t0.elapsed().as_secs_f64();
    let closing = close_out(&store);
    verify_fingerprints(&store, &expected, cfg.seed)?;
    let files_alive = expected.len();
    Ok(report(
        "hot_skew",
        cfg,
        &store,
        tally,
        files_alive,
        elapsed,
        None,
        closing,
    ))
}

/// Multi-tenant capacity pressure: three tenants with different
/// placement hints interleave writes against scarce node capacity.
/// When `NoSpace` hits, the tenant deletes its own oldest files and
/// retries — the scenario proves reclaimed capacity is accounted
/// exactly (the closing audit's `usage_exact`).
///
/// Dual-runs static vs adaptive like [`hot_skew`]; here the columns
/// are informational (capacity pressure, not read skew, dominates),
/// so `bench-check` requires them present but does not gate a win.
fn tenant_pressure(cfg: &ScenarioConfig) -> Result<ScenarioReport, String> {
    dual_run(cfg, tenant_pressure_once)
}

fn tenant_pressure_once(cfg: &ScenarioConfig, leg: &str) -> Result<ScenarioReport, String> {
    const NODES: usize = 4;
    const TENANTS: usize = 3;
    let writes_per_tenant = if cfg.quick { 40 } else { 120 };
    let node_capacity: u64 = if cfg.quick { 3 << 20 } else { 6 << 20 };
    let store = store_for(cfg, &format!("tenant_pressure_{leg}"), NODES, node_capacity, None)?;
    let mut rng = Rng::new(cfg.seed ^ 0x7e4a_4700);
    let mut tally = Tally::default();
    // Per-tenant surviving files, oldest first.
    let mut live: Vec<Vec<Fingerprint>> = vec![Vec::new(); TENANTS];
    let t0 = Instant::now();

    let tenant_tags = |tenant: usize| match tenant {
        0 => TagSet::from_pairs([("DP", "local")]),
        1 => TagSet::from_pairs([("DP", "scatter 2")]),
        _ => TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")]),
    };

    for round in 0..writes_per_tenant {
        for tenant in 0..TENANTS {
            let len = 96 * 1024 + rng.gen_range(160 * 1024) as usize;
            let data = payload(&mut rng, len);
            let path = format!("/tenant{tenant}/f{round}");
            let tags = tenant_tags(tenant);
            // Write; on NoSpace, evict own oldest files and retry.
            let mut evictions = 0u32;
            loop {
                match write_with_retry(
                    &store,
                    NodeId(tenant % NODES),
                    &path,
                    &data,
                    &tags,
                    &mut tally,
                    cfg.seed,
                ) {
                    Ok(()) => {
                        live[tenant].push((path, len, chunk_crc(&data)));
                        break;
                    }
                    Err(e) if e.starts_with("nospace:") && evictions < 12 => {
                        evictions += 1;
                        // Reclaim: drop this tenant's two oldest files
                        // (if any survive) and try again. Another
                        // tenant may still own the full node — then the
                        // write is legitimately rejected and skipped.
                        if live[tenant].is_empty() {
                            break;
                        }
                        let evict = 2.min(live[tenant].len());
                        for fp in live[tenant].drain(..evict) {
                            store
                                .delete(&fp.0)
                                .map_err(|e| format!("tenant delete {}: {e}", fp.0))?;
                            tally.ops += 1;
                        }
                        store.flush_replication();
                    }
                    Err(e) if e.starts_with("nospace:") => break,
                    Err(e) => return Err(e),
                }
            }
        }
    }

    // Every tenant's survivors read back exactly.
    let survivors: Vec<Fingerprint> = live.into_iter().flatten().collect();
    for (i, (path, len, crc)) in survivors.iter().enumerate() {
        let t = Instant::now();
        let bytes = store
            .read_file(NodeId(i % NODES), path)
            .map_err(|e| format!("tenant read {path}: {e}"))?;
        tally.read_lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        tally.ops += 1;
        tally.bytes_read += bytes.len() as u64;
        if bytes.len() != *len || chunk_crc(&bytes) != *crc {
            return Err(format!("tenant corruption on {path} (seed={})", cfg.seed));
        }
    }

    let elapsed = t0.elapsed().as_secs_f64();
    let closing = close_out(&store);
    verify_fingerprints(&store, &survivors, cfg.seed)?;
    let files_alive = survivors.len();
    Ok(report(
        "tenant_pressure",
        cfg,
        &store,
        tally,
        files_alive,
        elapsed,
        None,
        closing,
    ))
}

/// Kill-and-recover mid-workflow: half the dataset lands, a holder
/// node dies ([`LiveStore::fail_node`]), the workload keeps writing
/// and reading while churn re-replication drains in the background,
/// and every byte — including chunks the dead node held — verifies
/// **without any reopen**. The node then rejoins and the audit closes
/// clean. `recovery_secs` measures fail → re-replication drained.
///
/// On [`Transport::Socket`] every step crosses the process boundary:
/// the victim daemon is SIGKILLed for real, mid-churn reads fail over
/// to surviving daemons, and the rejoin is a fresh `noded --reopen`
/// through the salvage path. In-process runs additionally re-run the
/// whole scenario over sockets (at full size, or with
/// [`ScenarioConfig::wire_bench`]) and record that leg's read p99 as
/// `read_p99_ms_wire` — the tracked wire-overhead column.
fn kill_recover(cfg: &ScenarioConfig) -> Result<ScenarioReport, String> {
    let mut rep = kill_recover_once(cfg, "kill_recover")?;
    rep.read_p99_ms_wire = match cfg.transport {
        // The primary run already crossed the wire.
        Transport::Socket => Some(rep.read_p99_ms),
        Transport::InProc if cfg.wire_bench || !cfg.quick => {
            let wire_cfg = ScenarioConfig {
                transport: Transport::Socket,
                ..cfg.clone()
            };
            let wire = kill_recover_once(&wire_cfg, "kill_recover_wire")?;
            if !wire.clean() {
                return Err("kill_recover: socket leg closed with a dirty audit".into());
            }
            Some(wire.read_p99_ms)
        }
        Transport::InProc => None,
    };
    Ok(rep)
}

fn kill_recover_once(cfg: &ScenarioConfig, name: &str) -> Result<ScenarioReport, String> {
    const NODES: usize = 5;
    let files = if cfg.quick { 16 } else { 60 };
    let store = store_for(cfg, name, NODES, u64::MAX / 2, None)?;
    let mut rng = Rng::new(cfg.seed ^ 0x6b17_7200);
    let mut tally = Tally::default();
    let mut expected: Vec<Fingerprint> = Vec::new();
    let tags = TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")]);
    let t0 = Instant::now();

    let write_one = |store: &LiveStore,
                         f: usize,
                         client: NodeId,
                         rng: &mut Rng,
                         tally: &mut Tally,
                         expected: &mut Vec<Fingerprint>|
     -> Result<(), String> {
        let len = 256 * 1024 + rng.gen_range(512 * 1024) as usize;
        let data = payload(rng, len);
        let path = format!("/kr/f{f}");
        write_with_retry(store, client, &path, &data, &tags, tally, cfg.seed)?;
        expected.push((path, len, chunk_crc(&data)));
        Ok(())
    };

    // Phase 1: half the workflow's dataset lands and replicates.
    for f in 0..files / 2 {
        write_one(&store, f, NodeId(f % NODES), &mut rng, &mut tally, &mut expected)?;
    }
    store.flush_replication();

    // The primary holder of the first file dies mid-workflow.
    let victim = store.locations(&expected[0].0)[0];
    let t_fail = Instant::now();
    let queued = store.fail_node(victim);
    if queued == 0 {
        return Err(format!(
            "kill_recover: victim {victim:?} held nothing to restore (seed={})",
            cfg.seed
        ));
    }

    // Phase 2: the workflow keeps going — new writes placed on the
    // survivors, reads failing over — while restores drain behind it.
    let live_clients: Vec<NodeId> = (0..NODES)
        .map(NodeId)
        .filter(|&n| n != victim)
        .collect();
    for f in files / 2..files {
        let client = live_clients[f % live_clients.len()];
        write_one(&store, f, client, &mut rng, &mut tally, &mut expected)?;
        // Interleave reads of phase-1 files (some were held by the
        // victim; failover serves them from surviving holders).
        let (path, len, crc) = &expected[rng.range_usize(0, files / 2)];
        let t = Instant::now();
        let bytes = store
            .read_file(client, path)
            .map_err(|e| format!("mid-churn read {path} (seed={}): {e}", cfg.seed))?;
        tally.read_lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        tally.ops += 1;
        tally.bytes_read += bytes.len() as u64;
        if bytes.len() != *len || chunk_crc(&bytes) != *crc {
            return Err(format!("mid-churn corruption on {path} (seed={})", cfg.seed));
        }
    }

    // Recovery barrier: every queued restore has landed.
    store.flush_replication();
    let recovery_secs = t_fail.elapsed().as_secs_f64();
    if store.under_replicated() != 0 {
        return Err(format!(
            "kill_recover: {} chunks still under-replicated after flush (seed={})",
            store.under_replicated(),
            cfg.seed
        ));
    }

    // The acceptance check: every byte verifies with the node still
    // dead and no reopen anywhere in sight.
    verify_fingerprints(&store, &expected, cfg.seed)?;

    // The node comes back; its stale copies are swept before service.
    store.join_node(victim);
    let elapsed = t0.elapsed().as_secs_f64();
    let closing = close_out(&store);
    verify_fingerprints(&store, &expected, cfg.seed)?;
    let files_alive = expected.len();
    Ok(report(
        "kill_recover",
        cfg,
        &store,
        tally,
        files_alive,
        elapsed,
        Some(recovery_secs),
        closing,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(seed: u64) -> ScenarioConfig {
        ScenarioConfig {
            seed,
            quick: true,
            ..ScenarioConfig::default()
        }
    }

    #[test]
    fn all_scenarios_close_clean_on_mem() {
        let cfg = quick_cfg(7);
        let reports = run_all(&cfg).expect("scenarios complete");
        assert_eq!(reports.len(), names().len());
        for r in &reports {
            assert!(r.clean(), "{} closed dirty: {:?}", r.name, r.audit);
            assert!(r.files > 0, "{} kept no files", r.name);
            assert!(r.bytes_written > 0);
            assert_eq!(r.transport, "inproc", "default transport is in-process");
        }
        let kr = reports.iter().find(|r| r.name == "kill_recover").unwrap();
        assert!(kr.recovery_secs.is_some());
        assert!(kr.bytes_rereplicated > 0, "churn re-replicated data");
        let flood = reports
            .iter()
            .find(|r| r.name == "small_file_flood")
            .unwrap();
        let (disk_files, seg_files) = (
            flood.flood_disk_files.expect("flood ran the disk leg"),
            flood.flood_seg_files.expect("flood ran the seg leg"),
        );
        assert_eq!(
            disk_files,
            flood.flood_chunks.unwrap() as usize,
            "file-per-chunk leaves one file per tiny chunk"
        );
        assert!(
            seg_files * 100 <= disk_files,
            "packed log stays O(segments): {seg_files} vs {disk_files}"
        );
        // The emitted document round-trips through its own gate.
        let doc = results_json(&reports, cfg.seed).to_string_pretty();
        check_scenarios_json(&doc).expect("self-emitted document passes the schema gate");
    }

    #[test]
    fn storm_outcome_is_a_pure_function_of_the_seed() {
        let a = metadata_storm(&quick_cfg(1234)).unwrap();
        let b = metadata_storm(&quick_cfg(1234)).unwrap();
        // Timing fields differ run to run; schedule-derived outcomes
        // must not.
        assert_eq!(a.files, b.files);
        assert_eq!(a.bytes_written, b.bytes_written);
        assert_eq!(a.faults_injected, b.faults_injected);
        assert_eq!(a.faults_surfaced, b.faults_surfaced);
        assert_eq!(a.audit, b.audit);
        let c = metadata_storm(&quick_cfg(99)).unwrap();
        assert_ne!(
            (a.faults_injected, a.bytes_written),
            (c.faults_injected, c.bytes_written),
            "a different seed draws a different schedule"
        );
    }

    #[test]
    fn schema_gate_rejects_drift() {
        let cfg = quick_cfg(7);
        let rep = metadata_storm(&cfg).unwrap();
        let good = results_json(std::slice::from_ref(&rep), cfg.seed);
        check_scenarios_json(&good.to_string_pretty()).unwrap();

        let mut drifted = good.clone();
        drifted.set("schema", "woss-scenarios-v0".into());
        assert!(check_scenarios_json(&drifted.to_string_pretty()).is_err());

        assert!(check_scenarios_json("{}").is_err());
        assert!(check_scenarios_json("not json").is_err());

        // A dirty audit is a hard failure, not a schema detail.
        let mut dirty_scenario = rep.to_json();
        dirty_scenario.set("audit_clean", false.into());
        let dirty = Json::obj([
            ("schema", SCENARIO_SCHEMA.into()),
            ("seed", 7u64.into()),
            ("scenarios", Json::Arr(vec![dirty_scenario])),
        ]);
        assert!(check_scenarios_json(&dirty.to_string_pretty()).is_err());
    }

    #[test]
    fn transport_parses_and_labels() {
        assert_eq!("inproc".parse::<Transport>().unwrap(), Transport::InProc);
        assert_eq!("socket".parse::<Transport>().unwrap(), Transport::Socket);
        assert_eq!("wire".parse::<Transport>().unwrap(), Transport::Socket);
        assert!("carrier-pigeon".parse::<Transport>().is_err());
        assert_eq!(Transport::default().label(), "inproc");
        assert_eq!(Transport::Socket.label(), "socket");
    }

    #[test]
    fn v3_gate_checks_transport_and_wire_columns() {
        let cfg = quick_cfg(7);
        let rep = metadata_storm(&cfg).unwrap();
        let wrap = |row: Json| {
            Json::obj([
                ("schema", SCENARIO_SCHEMA.into()),
                ("seed", 7u64.into()),
                ("scenarios", Json::Arr(vec![row])),
            ])
        };

        // A row without the transport label is schema drift.
        let mut row = rep.to_json();
        row.set("transport", Json::Null);
        assert!(check_scenarios_json(&wrap(row).to_string_pretty()).is_err());
        let mut row = rep.to_json();
        row.set("transport", "telepathy".into());
        assert!(check_scenarios_json(&wrap(row).to_string_pretty()).is_err());

        // A full-size kill_recover row must carry a positive wire-leg
        // p99; a quick row may skip the leg.
        let mut row = rep.to_json();
        row.set("name", "kill_recover".into());
        row.set("recovery_secs", 0.5.into());
        row.set("bytes_rereplicated", 4096u64.into());
        row.set("quick", false.into());
        row.set("read_p99_ms_wire", Json::Null);
        assert!(check_scenarios_json(&wrap(row.clone()).to_string_pretty()).is_err());
        row.set("read_p99_ms_wire", 0.0.into());
        assert!(check_scenarios_json(&wrap(row.clone()).to_string_pretty()).is_err());
        row.set("read_p99_ms_wire", 1.25.into());
        check_scenarios_json(&wrap(row.clone()).to_string_pretty()).unwrap();
        row.set("quick", true.into());
        row.set("read_p99_ms_wire", Json::Null);
        check_scenarios_json(&wrap(row).to_string_pretty()).unwrap();
    }

    #[test]
    fn live_gate_checks_ids_and_rows() {
        let row = r#"{"write_mbps":100,"read_mbps":200,
            "put_p50_us":10,"put_p95_us":20,"put_p99_us":30,
            "get_p50_us":1,"get_p95_us":2,"get_p99_us":3,
            "spill_p50_us":0,"spill_p95_us":0,"spill_p99_us":0}"#;
        let good = format!(
            r#"{{"experiments":[
            {{"id":"live_throughput","rows":[{row}]}},
            {{"id":"live_cache","rows":[]}},
            {{"id":"live_recovery","rows":[{{"reopen_ms":12.5}}]}}
        ]}}"#
        );
        check_live_json(&good).unwrap();

        let missing = format!(r#"{{"experiments":[{{"id":"live_throughput","rows":[{row}]}}]}}"#);
        assert!(check_live_json(&missing).is_err());

        // A throughput row without the percentile fields is schema
        // drift, not a tolerated legacy shape.
        let legacy = r#"{"experiments":[
            {"id":"live_throughput","rows":[{"write_mbps":100,"read_mbps":200}]},
            {"id":"live_cache","rows":[]},
            {"id":"live_recovery","rows":[{"reopen_ms":12.5}]}
        ]}"#;
        assert!(check_live_json(legacy).is_err());

        let no_rows = r#"{"experiments":[
            {"id":"live_throughput","rows":[]},
            {"id":"live_cache"},
            {"id":"live_recovery","rows":[{"reopen_ms":1}]}
        ]}"#;
        assert!(check_live_json(no_rows).is_err());
        assert!(check_live_json("[]").is_err());
    }
}
