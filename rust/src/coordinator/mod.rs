//! The leader process: configuration, experiment registry, reporting.
//!
//! `woss` (rust/src/main.rs) parses the CLI through [`crate::util::cli`],
//! loads calibration overrides from a config file ([`config`]), runs
//! experiments from [`crate::bench::experiments`] or the live engine,
//! and renders reports ([`report`]).

pub mod config;
pub mod report;

pub use config::load_calib;
pub use report::write_reports;
