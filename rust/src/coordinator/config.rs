//! Calibration config files (offline TOML subset).
//!
//! A deployment file overrides [`Calib`] fields:
//!
//! ```toml
//! # testbed.toml
//! [network]
//! nic_bw_mbps = 117.0
//! tcp_stream_mbps = 80.0
//! net_latency_us = 100.0
//!
//! [node]
//! cores = 4
//! cpu_slowdown = 1.0
//!
//! [manager]
//! op_ms = 0.2
//! setattr_ms = 4.0
//! setattr_serialized = true
//! ```
//!
//! Only `key = value` pairs and `[section]` headers are supported
//! (comments with `#`); unknown keys are reported as errors so typos
//! cannot silently skew an experiment.

use crate::sim::Calib;
use anyhow::{anyhow, Result};

const MB: f64 = 1024.0 * 1024.0;

/// Parse `source` and apply overrides onto `base`.
pub fn apply(base: &mut Calib, source: &str) -> Result<()> {
    let mut section = String::new();
    for (lineno, raw) in source.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = format!("{section}.{}", key.trim());
        let value = value.trim();
        set(base, &key, value).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
    }
    Ok(())
}

fn set(c: &mut Calib, key: &str, value: &str) -> Result<()> {
    let f = || -> Result<f64> {
        value
            .parse::<f64>()
            .map_err(|e| anyhow!("{key}: bad number '{value}': {e}"))
    };
    let b = || -> Result<bool> {
        value
            .parse::<bool>()
            .map_err(|e| anyhow!("{key}: bad bool '{value}': {e}"))
    };
    match key {
        "network.nic_bw_mbps" => c.nic_bw = f()? * MB,
        "network.tcp_stream_mbps" => c.tcp_stream_bw = f()? * MB,
        "network.net_latency_us" => c.net_latency_us = f()?,
        "node.cores" => c.cores_per_node = f()? as usize,
        "node.cpu_slowdown" => c.cpu_slowdown = f()?,
        "node.os_cache_mb" => c.os_cache_bytes = (f()? * MB) as u64,
        "disk.spinning_read_mbps" => c.disk.spinning_read_bw = f()? * MB,
        "disk.spinning_write_mbps" => c.disk.spinning_write_bw = f()? * MB,
        "disk.position_ms" => c.disk.spinning_position_ms = f()?,
        "disk.ramdisk_mbps" => c.disk.ramdisk_bw = f()? * MB,
        "sai.fuse_op_ms" => c.fuse_op_ms = f()?,
        "sai.chunk_kb" => c.chunk_size = (f()? * 1024.0) as u64,
        "sai.stripe_width" => c.default_stripe_width = f()? as usize,
        "manager.op_ms" => c.manager_op_ms = f()?,
        "manager.setattr_ms" => c.manager_setattr_ms = f()?,
        "manager.parallelism" => c.manager_parallelism = f()? as usize,
        "manager.setattr_serialized" => c.manager_setattr_serialized = b()?,
        "manager.shards" => c.manager_shards = (f()? as usize).max(1),
        "manager.setattr_batch" => c.setattr_batch = (f()? as usize).max(1),
        "runtime.fork_ms" => c.fork_ms = f()?,
        "runtime.swift_tag_task_ms" => c.swift_tag_task_ms = f()?,
        "runtime.sched_decision_ms" => c.sched_decision_ms = f()?,
        "nfs.nic_bw_mbps" => c.nfs_nic_bw = f()? * MB,
        "nfs.cache_gb" => c.nfs_cache_bytes = (f()? * 1024.0 * MB) as u64,
        "nfs.op_ms" => c.nfs_op_ms = f()?,
        "gpfs.servers" => c.gpfs_servers = f()? as usize,
        "gpfs.server_bw_mbps" => c.gpfs_server_bw = f()? * MB,
        "gpfs.op_ms" => c.gpfs_op_ms = f()?,
        _ => return Err(anyhow!("unknown config key '{key}'")),
    }
    Ok(())
}

/// Load a calibration: defaults (or the BG/P profile) + optional file.
pub fn load_calib(profile: &str, path: Option<&str>) -> Result<Calib> {
    let mut calib = match profile {
        "cluster" => Calib::cluster(),
        "bgp" => Calib::bgp(),
        other => return Err(anyhow!("unknown profile '{other}' (cluster|bgp)")),
    };
    if let Some(p) = path {
        let text = std::fs::read_to_string(p).map_err(|e| anyhow!("read {p}: {e}"))?;
        apply(&mut calib, &text)?;
    }
    Ok(calib)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn applies_overrides() {
        let mut c = Calib::default();
        apply(
            &mut c,
            "# comment\n[network]\nnic_bw_mbps = 234\n\n[manager]\nsetattr_serialized = false\nop_ms = 1.5\n",
        )
        .unwrap();
        assert!((c.nic_bw - 234.0 * MB).abs() < 1.0);
        assert!(!c.manager_setattr_serialized);
        assert!((c.manager_op_ms - 1.5).abs() < 1e-9);
    }

    #[test]
    fn shard_and_batch_overrides() {
        let mut c = Calib::default();
        apply(&mut c, "[manager]\nshards = 8\nsetattr_batch = 16\n").unwrap();
        assert_eq!(c.manager_shards, 8);
        assert_eq!(c.setattr_batch, 16);
        // Zero is clamped to 1: a manager always has at least one shard.
        apply(&mut c, "[manager]\nshards = 0\n").unwrap();
        assert_eq!(c.manager_shards, 1);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Calib::default();
        let err = apply(&mut c, "[network]\nwarp_speed = 9\n").unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn malformed_line_rejected() {
        let mut c = Calib::default();
        assert!(apply(&mut c, "[node]\ncores\n").is_err());
        assert!(apply(&mut c, "[node]\ncores = banana\n").is_err());
    }

    #[test]
    fn profiles() {
        assert!(load_calib("cluster", None).is_ok());
        let bgp = load_calib("bgp", None).unwrap();
        assert!(bgp.cpu_slowdown > 1.0);
        assert!(load_calib("laptop", None).is_err());
    }
}
