//! Report rendering and persistence.

use crate::bench::experiments::Report;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Print each report's table and expectation line.
pub fn print_reports(reports: &[Report]) {
    for r in reports {
        println!("{}", r.table.render());
        println!("(expectation: {})\n", r.expectation);
    }
}

/// Write all reports as one JSON document.
pub fn write_reports(reports: &[Report], path: &Path) -> Result<()> {
    let doc = Json::obj([(
        "experiments",
        Json::Arr(reports.iter().map(|r| r.json.clone()).collect()),
    )]);
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::experiments;

    #[test]
    fn json_roundtrip_on_disk() {
        let reports = vec![experiments::run("fig8", 1, 5).unwrap()];
        let dir = std::env::temp_dir().join(format!("woss-report-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        write_reports(&reports, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed.get("experiments").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
