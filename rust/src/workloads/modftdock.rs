//! modFTDock workload (paper §4.2, Figures 9–11).
//!
//! Protein-docking workflow combining three patterns per stream:
//! *dock* verifies molecules against a database (the database is
//! broadcast to all dock tasks), *merge* summarizes each stream's dock
//! outputs (reduce — outputs collocated), *score* ranks the merge result
//! (pipeline — local placement). The paper runs 9 streams over 18 nodes
//! on the cluster and scales streams with nodes on BG/P.

use crate::hints::TagSet;
use crate::workflow::dag::{TaskSpec, Tier, Workflow};

const KB: u64 = 1024;

/// modFTDock configuration.
#[derive(Debug, Clone)]
pub struct ModFtDock {
    /// Parallel dock streams (paper: 9 on the cluster).
    pub streams: usize,
    /// Dock tasks per stream.
    pub docks_per_stream: usize,
    /// Replication factor for the broadcast database.
    pub db_replication: u32,
    /// Attach WOSS hints?
    pub hints: bool,
    /// Database size in bytes.
    pub db_bytes: u64,
    /// Per-molecule input size in bytes.
    pub mol_bytes: u64,
    /// Dock compute seconds (reference CPU).
    pub dock_cpu: f64,
}

impl Default for ModFtDock {
    fn default() -> Self {
        ModFtDock {
            streams: 9,
            docks_per_stream: 6,
            db_replication: 8,
            hints: true,
            db_bytes: 200 * KB,
            mol_bytes: 150 * KB,
            dock_cpu: 12.0,
        }
    }
}

impl ModFtDock {
    /// BG/P scaling point: streams proportional to node count
    /// (fig11 sweeps the allocation; the workload grows with it). Files
    /// stay small (the paper's modFTDock inputs are 100–200 KB); what
    /// degrades GPFS at scale is its per-operation metadata cost under
    /// many-task storms, not bandwidth.
    pub fn bgp(nodes: usize, hints: bool) -> Self {
        ModFtDock {
            streams: nodes / 2,
            docks_per_stream: 6,
            db_replication: (nodes / 4).clamp(2, 32) as u32,
            hints,
            ..ModFtDock::default()
        }
    }

    /// Build the workflow.
    pub fn build(&self) -> Workflow {
        let mut w = Workflow::new();
        let db_size = self.db_bytes;
        w.preload("/backend/db", db_size);

        // Stage in + (optionally) replicate the shared database.
        let mut db_tags = TagSet::new();
        if self.hints && self.db_replication > 1 {
            db_tags.set("Replication", &self.db_replication.to_string());
            db_tags.set("RepSmntc", "optimistic");
        }
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/backend/db", Tier::Backend)
                .write("/w/db", Tier::Intermediate, db_size, db_tags),
        );

        for s in 0..self.streams {
            let input = format!("/backend/mol{s}");
            w.preload(&input, self.mol_bytes);
            w.push(
                TaskSpec::new(0, "stageIn")
                    .read(&input, Tier::Backend)
                    .write(&format!("/w/mol{s}"), Tier::Intermediate, self.mol_bytes, TagSet::new()),
            );

            let colloc = if self.hints {
                TagSet::from_pairs([("DP", format!("collocation merge{s}").as_str())])
            } else {
                TagSet::new()
            };
            let mut merge = TaskSpec::new(0, "merge").compute(2.0);
            for d in 0..self.docks_per_stream {
                let out = format!("/w/dock{s}_{d}");
                w.push(
                    TaskSpec::new(0, "dock")
                        .read(&format!("/w/mol{s}"), Tier::Intermediate)
                        .read("/w/db", Tier::Intermediate)
                        .write(&out, Tier::Intermediate, 120 * KB, colloc.clone())
                        .compute(self.dock_cpu),
                );
                merge = merge.read(&out, Tier::Intermediate);
            }
            let local = if self.hints {
                TagSet::from_pairs([("DP", "local")])
            } else {
                TagSet::new()
            };
            merge = merge.write(&format!("/w/merged{s}"), Tier::Intermediate, 150 * KB, local);
            w.push(merge);
            w.push(
                TaskSpec::new(0, "score")
                    .read(&format!("/w/merged{s}"), Tier::Intermediate)
                    .write(&format!("/w/rank{s}"), Tier::Intermediate, 50 * KB, TagSet::new())
                    .compute(1.5),
            );
            w.push(
                TaskSpec::new(0, "stageOut")
                    .read(&format!("/w/rank{s}"), Tier::Intermediate)
                    .write(&format!("/backend/rank{s}"), Tier::Backend, 50 * KB, TagSet::new()),
            );
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        ModFtDock::default().build().validate().unwrap();
        ModFtDock {
            hints: false,
            ..Default::default()
        }
        .build()
        .validate()
        .unwrap();
    }

    #[test]
    fn shape() {
        let w = ModFtDock::default().build();
        let docks = w.tasks.iter().filter(|t| t.stage == "dock").count();
        let merges = w.tasks.iter().filter(|t| t.stage == "merge").count();
        let scores = w.tasks.iter().filter(|t| t.stage == "score").count();
        assert_eq!(docks, 9 * 6);
        assert_eq!(merges, 9);
        assert_eq!(scores, 9);
    }

    #[test]
    fn patterns_tagged() {
        let w = ModFtDock::default().build();
        let db = w
            .tasks
            .iter()
            .flat_map(|t| t.writes.iter())
            .find(|wr| wr.path == "/w/db")
            .unwrap();
        assert_eq!(db.tags.replication(), Some(8), "broadcast db replicated");
        let dock_out = w
            .tasks
            .iter()
            .flat_map(|t| t.writes.iter())
            .find(|wr| wr.path.starts_with("/w/dock"))
            .unwrap();
        assert!(dock_out.tags.get("DP").unwrap().starts_with("collocation"));
    }

    #[test]
    fn bgp_scales_with_nodes() {
        let small = ModFtDock::bgp(64, true).build();
        let large = ModFtDock::bgp(256, true).build();
        assert!(large.tasks.len() > 3 * small.tasks.len());
    }
}
