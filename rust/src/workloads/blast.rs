//! BLAST workload (paper §4.2, Figure 12, Table 4).
//!
//! DNA search: a 1.8 GB database is broadcast to all nodes; 19 worker
//! processes each run two queries against it, writing small result
//! files straight to the backend. The cross-layer hint is the database's
//! replication factor — Table 4 sweeps it over {2, 4, 8, 16} and shows
//! the stage-in cost growing with replicas while task time shrinks,
//! with the sweet spot before 16.

use crate::hints::TagSet;
use crate::workflow::dag::{TaskSpec, Tier, Workflow};

const KB: u64 = 1024;
const MB: u64 = 1024 * KB;

/// BLAST configuration.
#[derive(Debug, Clone)]
pub struct Blast {
    /// Worker processes (one per machine; paper: 19).
    pub workers: usize,
    /// Queries per worker (paper: 2 → 38 total).
    pub queries_per_worker: usize,
    /// Database size (paper: 1.7–1.8 GB).
    pub db_bytes: u64,
    /// Database replication factor (`None` = untagged: DSS/NFS runs).
    pub db_replication: Option<u32>,
    /// Per-query compute seconds (search is CPU-heavy; calibrated so
    /// the DSS total lands near Table 4's scale).
    pub query_cpu_secs: f64,
}

impl Default for Blast {
    fn default() -> Self {
        Blast {
            workers: 19,
            queries_per_worker: 2,
            db_bytes: 1800 * MB,
            db_replication: Some(4),
            query_cpu_secs: 70.0,
        }
    }
}

impl Blast {
    /// Build the workflow.
    pub fn build(&self) -> Workflow {
        let mut w = Workflow::new();
        w.preload("/backend/db", self.db_bytes);
        for q in 0..(self.workers * self.queries_per_worker) {
            w.preload(&format!("/backend/query{q}"), 8 * KB);
        }

        let mut db_tags = TagSet::new();
        if let Some(r) = self.db_replication {
            db_tags.set("Replication", &r.to_string());
            db_tags.set("RepSmntc", "optimistic");
        }
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/backend/db", Tier::Backend)
                .write("/w/db", Tier::Intermediate, self.db_bytes, db_tags),
        );

        // Each worker runs its queries sequentially: query k depends on
        // query k-1 of the same worker through a small chain file,
        // mirroring one BLAST process handling two queries.
        for worker in 0..self.workers {
            let mut prev: Option<String> = None;
            for q in 0..self.queries_per_worker {
                let qid = worker * self.queries_per_worker + q;
                let mut task = TaskSpec::new(0, "blast")
                    .read(&format!("/backend/query{qid}"), Tier::Backend)
                    .read("/w/db", Tier::Intermediate)
                    .compute(self.query_cpu_secs)
                    .write(
                        &format!("/w/result{qid}"),
                        Tier::Intermediate,
                        300 * KB,
                        TagSet::new(),
                    );
                if let Some(p) = &prev {
                    task = task.read(p, Tier::Intermediate);
                }
                let chain = format!("/w/chain{worker}_{q}");
                task = task.write(&chain, Tier::Intermediate, 1 * KB, TagSet::new());
                prev = Some(chain);
                w.push(task);
                w.push(
                    TaskSpec::new(0, "stageOut")
                        .read(&format!("/w/result{qid}"), Tier::Intermediate)
                        .write(
                            &format!("/backend/result{qid}"),
                            Tier::Backend,
                            300 * KB,
                            TagSet::new(),
                        ),
                );
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        Blast::default().build().validate().unwrap();
        Blast {
            db_replication: None,
            ..Default::default()
        }
        .build()
        .validate()
        .unwrap();
    }

    #[test]
    fn shape() {
        let w = Blast::default().build();
        assert_eq!(w.tasks.iter().filter(|t| t.stage == "blast").count(), 38);
        assert_eq!(w.tasks.iter().filter(|t| t.stage == "stageIn").count(), 1);
    }

    #[test]
    fn replication_tag_present_only_when_set() {
        let tagged = Blast::default().build();
        let db = tagged
            .tasks
            .iter()
            .flat_map(|t| t.writes.iter())
            .find(|wr| wr.path == "/w/db")
            .unwrap();
        assert_eq!(db.tags.replication(), Some(4));

        let plain = Blast {
            db_replication: None,
            ..Default::default()
        }
        .build();
        let db = plain
            .tasks
            .iter()
            .flat_map(|t| t.writes.iter())
            .find(|wr| wr.path == "/w/db")
            .unwrap();
        assert_eq!(db.tags.replication(), None);
    }

    #[test]
    fn queries_chain_per_worker() {
        let w = Blast::default().build();
        let deps = w.dependencies();
        // The second query of worker 0 depends on the first (chain file)
        // and on the stage-in (db).
        let blast_ids: Vec<usize> = w
            .tasks
            .iter()
            .filter(|t| t.stage == "blast")
            .map(|t| t.id)
            .collect();
        let second = blast_ids[1];
        assert!(deps[second].contains(&blast_ids[0]));
    }
}
