//! Montage workload (paper §4.3, Figure 13, Table 5).
//!
//! Astronomy mosaic pipeline: 10 processing stages with highly variable
//! I/O intensity — ~650 files, 1 KB…165 MB, ~2 GB moved. Stage shapes,
//! file counts and sizes follow Table 5; the hints follow Figure 13's
//! arrow labels (pipeline stages tag `DP=local`, the two reduce stages
//! tag `DP=collocation`).

use crate::hints::TagSet;
use crate::workflow::dag::{TaskSpec, Tier, Workflow};

const KB: u64 = 1024;
const MB: u64 = 1024 * KB;

/// Montage configuration (defaults = the paper's workload).
#[derive(Debug, Clone)]
pub struct Montage {
    /// Input images (Table 5: 57 files, 1.7–2.1 MB).
    pub inputs: usize,
    /// Attach WOSS hints?
    pub hints: bool,
    /// Scale factor on file sizes.
    pub scale: f64,
}

impl Default for Montage {
    fn default() -> Self {
        Montage {
            inputs: 57,
            hints: true,
            scale: 1.0,
        }
    }
}

impl Montage {
    fn sz(&self, bytes: u64) -> u64 {
        ((bytes as f64) * self.scale).round().max(1.0) as u64
    }

    fn local(&self) -> TagSet {
        if self.hints {
            TagSet::from_pairs([("DP", "local")])
        } else {
            TagSet::new()
        }
    }

    fn colloc(&self, group: &str) -> TagSet {
        if self.hints {
            TagSet::from_pairs([("DP", format!("collocation {group}").as_str())])
        } else {
            TagSet::new()
        }
    }

    /// Build the workflow.
    pub fn build(&self) -> Workflow {
        let n = self.inputs;
        let mut w = Workflow::new();

        // --- stageIn: 57 files, 1.7–2.1 MB (109 MB total) ---
        for i in 0..n {
            let src = format!("/backend/raw{i}");
            w.preload(&src, self.sz(1900 * KB));
            w.push(
                TaskSpec::new(0, "stageIn")
                    .read(&src, Tier::Backend)
                    .write(&format!("/w/raw{i}.fits"), Tier::Intermediate, self.sz(1900 * KB), TagSet::new()),
            );
        }

        // --- mProject: one task per image, 2 outputs each (113 files,
        //     3.3–4.2 MB; 438 MB) — pipeline pattern ---
        for i in 0..n {
            w.push(
                TaskSpec::new(0, "mProject")
                    .read(&format!("/w/raw{i}.fits"), Tier::Intermediate)
                    .write(&format!("/w/proj{i}.fits"), Tier::Intermediate, self.sz(3800 * KB), self.local())
                    .write(&format!("/w/proj{i}.area"), Tier::Intermediate, self.sz(3800 * KB), self.local())
                    .compute(0.6),
            );
        }

        // --- mImgTbl: one task reads all projected images, 17 KB out ---
        let mut imgtbl = TaskSpec::new(0, "mImgTbl").compute(0.3);
        for i in 0..n {
            imgtbl = imgtbl.read(&format!("/w/proj{i}.fits"), Tier::Intermediate);
        }
        imgtbl = imgtbl.write("/w/images.tbl", Tier::Intermediate, self.sz(17 * KB), TagSet::new());
        w.push(imgtbl);

        // --- mOverlaps: reads the table, 17 KB out ---
        w.push(
            TaskSpec::new(0, "mOverlaps")
                .read("/w/images.tbl", Tier::Intermediate)
                .write("/w/diffs.tbl", Tier::Intermediate, self.sz(17 * KB), TagSet::new())
                .compute(0.2),
        );

        // --- mDiff: one task per overlapping pair (~142 tasks, 285
        //     files, 100 KB–3 MB; 148 MB) — pipeline pattern ---
        let n_diff = (n as f64 * 2.5) as usize; // ~142 for 57 inputs
        for d in 0..n_diff {
            let a = d % n;
            let b = (d + 1) % n;
            w.push(
                TaskSpec::new(0, "mDiff")
                    .read("/w/diffs.tbl", Tier::Intermediate)
                    .read(&format!("/w/proj{a}.fits"), Tier::Intermediate)
                    .read(&format!("/w/proj{b}.fits"), Tier::Intermediate)
                    .write(&format!("/w/diff{d}.fits"), Tier::Intermediate, self.sz(1000 * KB), self.local())
                    .write(&format!("/w/diff{d}.area"), Tier::Intermediate, self.sz(40 * KB), self.local())
                    .compute(0.15),
            );
        }

        // --- mFitPlane: one per diff (142 files, 4 KB; 576 KB) ---
        for d in 0..n_diff {
            w.push(
                TaskSpec::new(0, "mFitPlane")
                    .read(&format!("/w/diff{d}.fits"), Tier::Intermediate)
                    .write(&format!("/w/fit{d}.txt"), Tier::Intermediate, self.sz(4 * KB), self.colloc("fits"))
                    .compute(0.1),
            );
        }

        // --- mConcatFit: reduce over all fit files (16 KB out) ---
        let mut concat = TaskSpec::new(0, "mConcatFit").compute(0.2);
        for d in 0..n_diff {
            concat = concat.read(&format!("/w/fit{d}.txt"), Tier::Intermediate);
        }
        concat = concat.write("/w/fits.tbl", Tier::Intermediate, self.sz(16 * KB), self.local());
        w.push(concat);

        // --- mBgModel: 2 KB out ---
        w.push(
            TaskSpec::new(0, "mBgModel")
                .read("/w/fits.tbl", Tier::Intermediate)
                .write("/w/corrections.tbl", Tier::Intermediate, self.sz(2 * KB), TagSet::new())
                .compute(0.4),
        );

        // --- mBackground: one per projected image (113 files; 438 MB)
        //     — pipeline pattern ---
        for i in 0..n {
            w.push(
                TaskSpec::new(0, "mBackground")
                    .read(&format!("/w/proj{i}.fits"), Tier::Intermediate)
                    .read("/w/corrections.tbl", Tier::Intermediate)
                    .write(&format!("/w/bg{i}.fits"), Tier::Intermediate, self.sz(3800 * KB), self.local())
                    .write(&format!("/w/bg{i}.area"), Tier::Intermediate, self.sz(3800 * KB), self.local())
                    .compute(0.3),
            );
        }

        // --- mAdd: reduce over all background files (2 files, 165 MB) ---
        let mut madd = TaskSpec::new(0, "mAdd").compute(1.5);
        for i in 0..n {
            madd = madd.read(&format!("/w/bg{i}.fits"), Tier::Intermediate);
        }
        madd = madd
            .write("/w/mosaic.fits", Tier::Intermediate, self.sz(165 * MB), self.local())
            .write("/w/mosaic.area", Tier::Intermediate, self.sz(165 * MB), self.local());
        w.push(madd);

        // --- mJPEG: pipeline from the mosaic (4.7 MB) ---
        w.push(
            TaskSpec::new(0, "mJPEG")
                .read("/w/mosaic.fits", Tier::Intermediate)
                .write("/w/mosaic.jpg", Tier::Intermediate, self.sz(4700 * KB), self.local())
                .compute(0.5),
        );

        // --- stageOut: mosaic + jpeg (170 MB) ---
        w.push(
            TaskSpec::new(0, "stageOut")
                .read("/w/mosaic.fits", Tier::Intermediate)
                .read("/w/mosaic.jpg", Tier::Intermediate)
                .write("/backend/mosaic.fits", Tier::Backend, self.sz(165 * MB), TagSet::new())
                .write("/backend/mosaic.jpg", Tier::Backend, self.sz(4700 * KB), TagSet::new()),
        );
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        Montage::default().build().validate().unwrap();
        Montage {
            hints: false,
            ..Default::default()
        }
        .build()
        .validate()
        .unwrap();
    }

    #[test]
    fn table5_shape() {
        let w = Montage::default().build();
        let count = |s: &str| w.tasks.iter().filter(|t| t.stage == s).count();
        assert_eq!(count("stageIn"), 57);
        assert_eq!(count("mProject"), 57);
        assert_eq!(count("mImgTbl"), 1);
        assert_eq!(count("mDiff"), 142);
        assert_eq!(count("mFitPlane"), 142);
        assert_eq!(count("mConcatFit"), 1);
        assert_eq!(count("mBgModel"), 1);
        assert_eq!(count("mBackground"), 57);
        assert_eq!(count("mAdd"), 1);
        assert_eq!(count("mJPEG"), 1);
        assert_eq!(count("stageOut"), 1);
        // ~650 files overall
        let files: usize = w.tasks.iter().map(|t| t.writes.len()).sum();
        assert!((600..750).contains(&files), "file count {files}");
        // ~2 GB written
        let gb = w.bytes_written() as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!((1.2..2.5).contains(&gb), "bytes written {gb:.2} GB");
    }

    #[test]
    fn hints_follow_figure13() {
        let w = Montage::default().build();
        let tag_of = |path: &str| -> Option<String> {
            w.tasks
                .iter()
                .flat_map(|t| t.writes.iter())
                .find(|wr| wr.path == path)
                .and_then(|wr| wr.tags.get("DP").map(str::to_string))
        };
        assert_eq!(tag_of("/w/proj0.fits").as_deref(), Some("local"));
        assert!(tag_of("/w/fit0.txt").unwrap().starts_with("collocation"));
        assert_eq!(tag_of("/w/bg0.fits").as_deref(), Some("local"));
        assert_eq!(tag_of("/w/mosaic.fits").as_deref(), Some("local"));
        assert_eq!(tag_of("/w/images.tbl"), None, "untagged stage");
    }
}
