//! Synthetic pattern benchmarks (paper §4.1, Figure 4).
//!
//! Four workloads, one per pattern: pipeline, broadcast, reduce,
//! scatter. Data sizes follow Figure 4's labels; `scale` multiplies all
//! file sizes (the paper also runs 10× up and 1000× down). Each builder
//! takes `hints`: when true the runtime attaches the WOSS tags from
//! Table 1/3; when false the same workflow runs hint-free (DSS/NFS
//! baselines — identical I/O, no cross-layer information).

use crate::hints::TagSet;
use crate::workflow::dag::{TaskSpec, Tier, Workflow};

const MB: u64 = 1024 * 1024;

/// Number of worker machines in the paper's cluster benchmarks
/// (20 nodes minus the manager/coordination node).
pub const WORKERS: usize = 19;

fn scaled(bytes: u64, scale: f64) -> u64 {
    ((bytes as f64) * scale).round().max(1.0) as u64
}

/// Pipeline benchmark: `width` independent 3-stage pipelines. Per
/// pipeline: stage-in 100 MB → s1 (200 MB) → s2 (10 MB) → s3 (1 MB) →
/// stage-out. Hints: every intermediate output `DP=local`; the script
/// then launches the next stage on the node holding the file.
pub fn pipeline(width: usize, scale: f64, hints: bool) -> Workflow {
    let mut w = Workflow::new();
    w.preload("/backend/input", scaled(100 * MB, scale));
    let local = || {
        if hints {
            TagSet::from_pairs([("DP", "local")])
        } else {
            TagSet::new()
        }
    };
    for p in 0..width {
        w.push(
            TaskSpec::new(0, "stageIn")
                .read("/backend/input", Tier::Backend)
                .write(&format!("/w/p{p}.in"), Tier::Intermediate, scaled(100 * MB, scale), local()),
        );
        w.push(
            TaskSpec::new(0, "stage1")
                .read(&format!("/w/p{p}.in"), Tier::Intermediate)
                .write(&format!("/w/p{p}.s1"), Tier::Intermediate, scaled(200 * MB, scale), local())
                .compute(1.0),
        );
        w.push(
            TaskSpec::new(0, "stage2")
                .read(&format!("/w/p{p}.s1"), Tier::Intermediate)
                .write(&format!("/w/p{p}.s2"), Tier::Intermediate, scaled(10 * MB, scale), local())
                .compute(1.0),
        );
        w.push(
            TaskSpec::new(0, "stage3")
                .read(&format!("/w/p{p}.s2"), Tier::Intermediate)
                .write(&format!("/w/p{p}.out"), Tier::Intermediate, scaled(1 * MB, scale), local())
                .compute(0.5),
        );
        w.push(
            TaskSpec::new(0, "stageOut")
                .read(&format!("/w/p{p}.out"), Tier::Intermediate)
                .write(&format!("/backend/p{p}.result"), Tier::Backend, scaled(1 * MB, scale), TagSet::new()),
        );
    }
    w
}

/// Broadcast benchmark: one staged-in file, a producer stage emits a
/// 100 MB file consumed by `consumers` parallel tasks (one per machine),
/// each writing an independent output staged out. Hint:
/// `Replication=<factor>` on the hot file (plus optimistic semantics).
pub fn broadcast(consumers: usize, replication: u32, scale: f64, hints: bool) -> Workflow {
    let mut w = Workflow::new();
    w.preload("/backend/input", scaled(100 * MB, scale));
    let mut tags = TagSet::new();
    if hints && replication > 1 {
        tags.set("Replication", &replication.to_string());
        tags.set("RepSmntc", "optimistic");
    }
    w.push(
        TaskSpec::new(0, "stageIn")
            .read("/backend/input", Tier::Backend)
            .write("/w/staged", Tier::Intermediate, scaled(100 * MB, scale), TagSet::new()),
    );
    w.push(
        TaskSpec::new(0, "produce")
            .read("/w/staged", Tier::Intermediate)
            .write("/w/hot", Tier::Intermediate, scaled(100 * MB, scale), tags)
            .compute(1.0),
    );
    for c in 0..consumers {
        w.push(
            TaskSpec::new(0, "consume")
                .read("/w/hot", Tier::Intermediate)
                .write(&format!("/w/out{c}"), Tier::Intermediate, scaled(10 * MB, scale), TagSet::new())
                .compute(1.0),
        );
        w.push(
            TaskSpec::new(0, "stageOut")
                .read(&format!("/w/out{c}"), Tier::Intermediate)
                .write(&format!("/backend/out{c}"), Tier::Backend, scaled(10 * MB, scale), TagSet::new()),
        );
    }
    w
}

/// Reduce benchmark: `producers` staged-in files, one parallel task per
/// file producing a `DP=collocation` output, then a single reduce task
/// consumes them all and its 1 MB result is staged out. With hints, the
/// staged inputs are tagged `DP=local` ("the storage system stored
/// staged-in files locally") so producers read locally, and the produce
/// outputs collocate on one anchor where the reduce task is scheduled.
/// Producer service times are heterogeneous (±30%), as in any real batch,
/// which lets the collocated writes overlap the compute stagger.
pub fn reduce(producers: usize, scale: f64, hints: bool) -> Workflow {
    let mut w = Workflow::new();
    let colloc = || {
        if hints {
            TagSet::from_pairs([("DP", "collocation reduce_g1")])
        } else {
            TagSet::new()
        }
    };
    let local = || {
        if hints {
            TagSet::from_pairs([("DP", "local")])
        } else {
            TagSet::new()
        }
    };
    let mut reduce_task = TaskSpec::new(0, "reduce").compute(2.0);
    for p in 0..producers {
        w.preload(&format!("/backend/in{p}"), scaled(50 * MB, scale));
        w.push(
            TaskSpec::new(0, "stageIn")
                .read(&format!("/backend/in{p}"), Tier::Backend)
                .write(&format!("/w/in{p}"), Tier::Intermediate, scaled(50 * MB, scale), local()),
        );
        let cpu = 8.0 * (0.7 + 0.6 * (p % 7) as f64 / 6.0);
        w.push(
            TaskSpec::new(0, "produce")
                .read(&format!("/w/in{p}"), Tier::Intermediate)
                .write(&format!("/w/part{p}"), Tier::Intermediate, scaled(50 * MB, scale), colloc())
                .compute(cpu),
        );
        reduce_task = reduce_task.read(&format!("/w/part{p}"), Tier::Intermediate);
    }
    reduce_task = reduce_task.write("/w/result", Tier::Intermediate, scaled(1 * MB, scale), TagSet::new());
    w.push(reduce_task);
    w.push(
        TaskSpec::new(0, "stageOut")
            .read("/w/result", Tier::Intermediate)
            .write("/backend/result", Tier::Backend, scaled(1 * MB, scale), TagSet::new()),
    );
    w
}

/// Scatter benchmark: stage-in, one task writes a scatter-file whose
/// block size matches the readers' region size (`BlockSize` +
/// `DP=scatter 1` hints), then `readers` tasks read disjoint regions and
/// write independent outputs, staged out. Figure 8 reports only stage 2
/// (the region reads), which [`crate::bench`] extracts by stage label.
pub fn scatter(readers: usize, scale: f64, hints: bool) -> Workflow {
    let region = scaled(30 * MB, scale);
    let total = region * readers as u64;
    let mut w = Workflow::new();
    w.preload("/backend/input", scaled(100 * MB, scale));
    let mut tags = TagSet::new();
    if hints {
        tags.set("DP", "scatter 1");
        tags.set("BlockSize", &region.to_string());
    }
    w.push(
        TaskSpec::new(0, "stageIn")
            .read("/backend/input", Tier::Backend)
            .write("/w/staged", Tier::Intermediate, scaled(100 * MB, scale), TagSet::new()),
    );
    w.push(
        TaskSpec::new(0, "produce")
            .read("/w/staged", Tier::Intermediate)
            .write("/w/scatter", Tier::Intermediate, total, tags)
            .compute(1.0),
    );
    for r in 0..readers {
        let local = if hints {
            TagSet::from_pairs([("DP", "local")])
        } else {
            TagSet::new()
        };
        w.push(
            TaskSpec::new(0, "readRegion")
                .read_range("/w/scatter", Tier::Intermediate, r as u64 * region, region)
                .write(&format!("/w/out{r}"), Tier::Intermediate, scaled(1 * MB, scale), local)
                .compute(0.25),
        );
        w.push(
            TaskSpec::new(0, "stageOut")
                .read(&format!("/w/out{r}"), Tier::Intermediate)
                .write(&format!("/backend/out{r}"), Tier::Backend, scaled(1 * MB, scale), TagSet::new()),
        );
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_validate() {
        for wf in [
            pipeline(WORKERS, 1.0, true),
            pipeline(WORKERS, 1.0, false),
            broadcast(WORKERS, 8, 1.0, true),
            reduce(WORKERS, 1.0, true),
            scatter(WORKERS, 1.0, true),
        ] {
            wf.validate().expect("workflow valid");
        }
    }

    #[test]
    fn pipeline_shape() {
        let w = pipeline(19, 1.0, true);
        assert_eq!(w.tasks.len(), 19 * 5);
        assert_eq!(
            w.stages(),
            vec!["stageIn", "stage1", "stage2", "stage3", "stageOut"]
        );
    }

    #[test]
    fn hints_toggle() {
        let tagged = pipeline(2, 1.0, true);
        let plain = pipeline(2, 1.0, false);
        let n_tags = |w: &Workflow| -> usize {
            w.tasks
                .iter()
                .flat_map(|t| t.writes.iter())
                .map(|wr| wr.tags.len())
                .sum()
        };
        assert!(n_tags(&tagged) > 0);
        assert_eq!(n_tags(&plain), 0);
        // Same I/O volume either way.
        assert_eq!(tagged.bytes_written(), plain.bytes_written());
    }

    #[test]
    fn broadcast_replication_tag() {
        let w = broadcast(19, 8, 1.0, true);
        let hot = w
            .tasks
            .iter()
            .flat_map(|t| t.writes.iter())
            .find(|wr| wr.path == "/w/hot")
            .unwrap();
        assert_eq!(hot.tags.replication(), Some(8));
    }

    #[test]
    fn scatter_ranges_disjoint() {
        let w = scatter(4, 1.0, true);
        let mut ranges: Vec<(u64, u64)> = w
            .tasks
            .iter()
            .flat_map(|t| t.reads.iter())
            .filter_map(|r| r.range)
            .collect();
        ranges.sort();
        assert_eq!(ranges.len(), 4);
        for pair in ranges.windows(2) {
            assert!(pair[0].0 + pair[0].1 <= pair[1].0, "regions overlap");
        }
    }

    #[test]
    fn scale_multiplies_sizes() {
        let big = pipeline(1, 10.0, true);
        let small = pipeline(1, 1.0, true);
        assert_eq!(big.bytes_written(), small.bytes_written() * 10);
    }
}
