//! Workload generators: the paper's synthetic patterns (§4.1) and the
//! three real applications (§4.2–4.3), expressed as [`crate::workflow`]
//! DAGs with the Table 1/3 hints attached exactly where the paper's
//! figures put them.

pub mod blast;
pub mod modftdock;
pub mod montage;
pub mod synthetic;

pub use blast::Blast;
pub use modftdock::ModFtDock;
pub use montage::Montage;
pub use synthetic::{broadcast, pipeline, reduce, scatter, WORKERS};
