//! The cross-layer hint grammar (paper Table 3).
//!
//! Hints are plain `<key, value>` pairs carried in POSIX extended
//! attributes — the paper's entire cross-layer mechanism. This module is
//! the *mechanism* half of the mechanism/policy split (§5 design
//! guidelines): it only parses and carries tags; the policies that react
//! to them live in [`crate::dispatch`].
//!
//! Implemented hints:
//!
//! | Tag | Optimization |
//! |-----|--------------|
//! | `DP=local` | pipeline pattern: place blocks on the writer's node |
//! | `DP=collocation <group>` | reduce pattern: co-place all files of a group |
//! | `DP=scatter <n>` | scatter pattern: stripe every `n` contiguous chunks round-robin |
//! | `Replication=<n>` | broadcast pattern: replicate blocks `n`× |
//! | `RepSmntc=optimistic\|pessimistic` | return after first replica vs after full replication |
//! | `CacheSize=<bytes>` | per-file client cache sizing |
//! | `BlockSize=<bytes>` | application-informed chunk size (scatter/gather) |
//! | `location` *(reserved, read-only)* | bottom-up: storage exposes replica locations |

pub mod tagset;

pub use tagset::TagSet;

/// Reserved attribute through which the storage system exposes data
/// location to the workflow runtime (bottom-up channel).
pub const LOCATION_ATTR: &str = "location";

/// A parsed, typed hint. Unknown keys are preserved in the [`TagSet`] but
/// parse to [`Hint::Unknown`] — a legacy storage system would simply
/// ignore them (the paper's incremental-adoption argument).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Hint {
    /// `DP=local` — prefer the writer's own storage node.
    PlacementLocal,
    /// `DP=collocation <group>` — co-place all files tagged with the same
    /// group on a single storage node.
    PlacementCollocate(String),
    /// `DP=scatter <n>` — place every `n` contiguous chunks on one node,
    /// round-robin across nodes.
    PlacementScatter(u64),
    /// `Replication=<n>` — keep `n` replicas of every block.
    Replication(u32),
    /// `RepSmntc=...` — replication completion semantics.
    ReplicationSemantics(RepSemantics),
    /// `CacheSize=<bytes>` — per-file client cache budget.
    CacheSize(u64),
    /// `BlockSize=<bytes>` — application-informed chunk size.
    BlockSize(u64),
    /// Recognized key, malformed value (reported, then ignored — hints
    /// are hints, not directives).
    Malformed { key: String, value: String },
    /// Unrecognized key (application-private metadata; ignored).
    Unknown { key: String, value: String },
}

/// Replication completion semantics (Table 3 `RepSmntc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RepSemantics {
    /// Return to the application after the first replica exists; the
    /// remaining replicas are created in the background.
    #[default]
    Optimistic,
    /// Return only after every replica is durable.
    Pessimistic,
}

/// Canonical tag keys.
pub mod keys {
    /// Data-placement policy selector.
    pub const DP: &str = "DP";
    /// Replication factor.
    pub const REPLICATION: &str = "Replication";
    /// Replication semantics.
    pub const REP_SEMANTICS: &str = "RepSmntc";
    /// Per-file cache budget.
    pub const CACHE_SIZE: &str = "CacheSize";
    /// Application-informed chunk size.
    pub const BLOCK_SIZE: &str = "BlockSize";
}

/// Parse one `<key, value>` pair into a typed hint.
pub fn parse(key: &str, value: &str) -> Hint {
    match key {
        keys::DP => parse_dp(value),
        keys::REPLICATION => match value.trim().parse::<u32>() {
            Ok(n) if n >= 1 => Hint::Replication(n),
            _ => malformed(key, value),
        },
        keys::REP_SEMANTICS => match value.trim().to_ascii_lowercase().as_str() {
            // the paper's Table 3 itself spells these loosely
            // ("Optimisite/Pessimestic"); accept prefixes.
            v if v.starts_with("optim") => {
                Hint::ReplicationSemantics(RepSemantics::Optimistic)
            }
            v if v.starts_with("pessim") => {
                Hint::ReplicationSemantics(RepSemantics::Pessimistic)
            }
            _ => malformed(key, value),
        },
        keys::CACHE_SIZE => match parse_size(value) {
            Some(n) if n >= 1 => Hint::CacheSize(n),
            _ => malformed(key, value),
        },
        keys::BLOCK_SIZE => match parse_size(value) {
            Some(n) if n >= 1 => Hint::BlockSize(n),
            _ => malformed(key, value),
        },
        _ => Hint::Unknown {
            key: key.to_string(),
            value: value.to_string(),
        },
    }
}

fn parse_dp(value: &str) -> Hint {
    let v = value.trim();
    if v.eq_ignore_ascii_case("local") {
        return Hint::PlacementLocal;
    }
    if let Some(rest) = strip_word(v, "collocation") {
        if rest.is_empty() {
            return malformed(keys::DP, value);
        }
        return Hint::PlacementCollocate(rest.to_string());
    }
    if let Some(rest) = strip_word(v, "scatter") {
        if let Ok(n) = rest.parse::<u64>() {
            if n >= 1 {
                return Hint::PlacementScatter(n);
            }
        }
        return malformed(keys::DP, value);
    }
    malformed(keys::DP, value)
}

/// Case-insensitive `word` prefix followed by whitespace; returns the
/// trimmed remainder.
fn strip_word<'a>(v: &'a str, word: &str) -> Option<&'a str> {
    if v.len() >= word.len() && v[..word.len()].eq_ignore_ascii_case(word) {
        let rest = &v[word.len()..];
        if rest.is_empty() || rest.starts_with(char::is_whitespace) {
            return Some(rest.trim());
        }
    }
    None
}

/// Parse sizes like `4096`, `64K`, `1M`, `2G`. Values whose scaled size
/// does not fit in `u64` are rejected (a malformed hint must degrade to
/// [`Hint::Malformed`], never panic the manager).
fn parse_size(v: &str) -> Option<u64> {
    let v = v.trim();
    if v.is_empty() {
        return None;
    }
    let (digits, mult) = match v.as_bytes()[v.len() - 1].to_ascii_uppercase() {
        b'K' => (&v[..v.len() - 1], 1024u64),
        b'M' => (&v[..v.len() - 1], 1024 * 1024),
        b'G' => (&v[..v.len() - 1], 1024 * 1024 * 1024),
        _ => (v, 1),
    };
    digits
        .trim()
        .parse::<u64>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
}

fn malformed(key: &str, value: &str) -> Hint {
    Hint::Malformed {
        key: key.to_string(),
        value: value.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_local() {
        assert_eq!(parse("DP", "local"), Hint::PlacementLocal);
        assert_eq!(parse("DP", " LOCAL "), Hint::PlacementLocal);
    }

    #[test]
    fn dp_collocation() {
        assert_eq!(
            parse("DP", "collocation merge_group_3"),
            Hint::PlacementCollocate("merge_group_3".into())
        );
        assert!(matches!(
            parse("DP", "collocation"),
            Hint::Malformed { .. }
        ));
    }

    #[test]
    fn dp_scatter() {
        assert_eq!(parse("DP", "scatter 16"), Hint::PlacementScatter(16));
        assert!(matches!(parse("DP", "scatter 0"), Hint::Malformed { .. }));
        assert!(matches!(parse("DP", "scatter x"), Hint::Malformed { .. }));
        // "scattergun" must not match the scatter word-prefix
        assert!(matches!(parse("DP", "scattergun 4"), Hint::Malformed { .. }));
    }

    #[test]
    fn replication() {
        assert_eq!(parse("Replication", "8"), Hint::Replication(8));
        assert!(matches!(parse("Replication", "0"), Hint::Malformed { .. }));
        assert!(matches!(
            parse("Replication", "many"),
            Hint::Malformed { .. }
        ));
    }

    #[test]
    fn rep_semantics_accepts_papers_spelling() {
        assert_eq!(
            parse("RepSmntc", "Optimisite"),
            Hint::ReplicationSemantics(RepSemantics::Optimistic)
        );
        assert_eq!(
            parse("RepSmntc", "Pessimestic"),
            Hint::ReplicationSemantics(RepSemantics::Pessimistic)
        );
        assert_eq!(
            parse("RepSmntc", "pessimistic"),
            Hint::ReplicationSemantics(RepSemantics::Pessimistic)
        );
    }

    #[test]
    fn sizes() {
        assert_eq!(parse("CacheSize", "4096"), Hint::CacheSize(4096));
        assert_eq!(parse("BlockSize", "64K"), Hint::BlockSize(65536));
        assert_eq!(parse("BlockSize", "1M"), Hint::BlockSize(1 << 20));
        assert!(matches!(parse("BlockSize", "0"), Hint::Malformed { .. }));
    }

    /// Zero-valued hints are nonsense the data path must never see: a
    /// zero scatter stride would feed a modulo, a zero replication
    /// factor would mean "store nothing", a zero block size would make
    /// chunking diverge. Each parses to `Malformed` (hints, not
    /// directives) so the dispatcher falls back to defaults.
    #[test]
    fn zero_values_malformed_for_every_key() {
        assert!(matches!(parse("DP", "scatter 0"), Hint::Malformed { .. }));
        assert!(matches!(parse("Replication", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("BlockSize", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("CacheSize", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("CacheSize", "0K"), Hint::Malformed { .. }));
    }

    /// A size whose scaled value overflows `u64` is malformed, not a
    /// panic: hostile or buggy tag values must never crash the manager.
    #[test]
    fn size_overflow_is_malformed_not_panic() {
        assert!(matches!(
            parse("BlockSize", "18446744073709551615K"),
            Hint::Malformed { .. }
        ));
        assert!(matches!(
            parse("CacheSize", "99999999999999999G"),
            Hint::Malformed { .. }
        ));
        // The largest representable size still parses.
        assert_eq!(
            parse("BlockSize", "18446744073709551615"),
            Hint::BlockSize(u64::MAX)
        );
    }

    #[test]
    fn unknown_keys_preserved() {
        assert_eq!(
            parse("provenance", "stage3"),
            Hint::Unknown {
                key: "provenance".into(),
                value: "stage3".into()
            }
        );
    }
}
