//! The cross-layer hint grammar (paper Table 3).
//!
//! Hints are plain `<key, value>` pairs carried in POSIX extended
//! attributes — the paper's entire cross-layer mechanism. This module is
//! the *mechanism* half of the mechanism/policy split (§5 design
//! guidelines): it only parses and carries tags; the policies that react
//! to them live in [`crate::dispatch`].
//!
//! Implemented hints:
//!
//! | Tag | Optimization |
//! |-----|--------------|
//! | `DP=local` | pipeline pattern: place blocks on the writer's node |
//! | `DP=collocation <group>` | reduce pattern: co-place all files of a group |
//! | `DP=scatter <n>` | scatter pattern: stripe every `n` contiguous chunks round-robin |
//! | `Replication=<n>` | broadcast pattern: replicate blocks `n`× |
//! | `RepSmntc=optimistic\|pessimistic` | return after first replica vs after full replication |
//! | `CacheSize=<bytes>` | per-file client cache sizing |
//! | `BlockSize=<bytes>` | application-informed chunk size (scatter/gather) |
//! | `Lifetime=scratch\|durable` | cache eviction class + auto-reclamation eligibility |
//! | `Consumers=<n>` | declared consumer reads before a scratch file is dead |
//! | `Pattern=pipeline\|broadcast\|reduce\|scatter` | access-pattern class driving prefetch / cache pinning |
//! | `location` *(reserved, read-only)* | bottom-up: storage exposes replica locations |
//!
//! The complete grammar — wire form, consuming layer, and triggered
//! optimization per tag — is documented in `docs/HINTS.md`.

pub mod tagset;

pub use tagset::TagSet;

/// Reserved attribute through which the storage system exposes data
/// location to the workflow runtime (bottom-up channel).
pub const LOCATION_ATTR: &str = "location";

/// Reserved attribute exposing where a file's bytes actually live:
/// `tier=<mem|disk|seg>;chunks=<n>;bytes=<n>;pinned=<n>;recovered=<0|1>` —
/// the chunk backend uncached bytes sit on, the file's cache-tier
/// residency summed over node caches, and whether the file survived a
/// store restart (`recovered=1` after `LiveStore::reopen` brought it
/// back). Bottom-up, served by the live store.
pub const CACHE_STATE_ATTR: &str = "cache_state";

/// Reserved attribute summarizing pool state (`nodes=<n> used=<b>
/// capacity=<b>`), served by the dispatcher's `SystemStatusProvider`;
/// the live store appends a ` recovered=<count>` field — how many
/// files its last re-open salvaged — so a scheduler can judge restart
/// fallout from one getxattr. Bottom-up.
pub const SYSTEM_STATUS_ATTR: &str = "system_status";

/// Reserved attribute exposing how many declared consumer reads remain
/// before a scratch file is reclaimed (`<n>`, or `untracked` when the
/// file declared no consumer count) — bottom-up.
pub const CONSUMERS_LEFT_ATTR: &str = "consumers_left";

/// Reserved attribute exposing a file's current read heat (`%.2f`):
/// the decayed per-file read counter the adaptive plane uses to decide
/// when a hot file earns extra replicas (and when they are trimmed).
/// Bottom-up, served by the live store.
pub const HEAT_ATTR: &str = "heat";

/// A parsed, typed hint. Unknown keys are preserved in the [`TagSet`] but
/// parse to [`Hint::Unknown`] — a legacy storage system would simply
/// ignore them (the paper's incremental-adoption argument).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Hint {
    /// `DP=local` — prefer the writer's own storage node.
    PlacementLocal,
    /// `DP=collocation <group>` — co-place all files tagged with the same
    /// group on a single storage node.
    PlacementCollocate(String),
    /// `DP=scatter <n>` — place every `n` contiguous chunks on one node,
    /// round-robin across nodes.
    PlacementScatter(u64),
    /// `Replication=<n>` — keep `n` replicas of every block.
    Replication(u32),
    /// `RepSmntc=...` — replication completion semantics.
    ReplicationSemantics(RepSemantics),
    /// `CacheSize=<bytes>` — per-file client cache budget.
    CacheSize(u64),
    /// `BlockSize=<bytes>` — application-informed chunk size.
    BlockSize(u64),
    /// `Lifetime=...` — how long the file's bytes matter.
    Lifetime(Lifetime),
    /// `Consumers=<n>` — declared number of whole-file consumer reads;
    /// a scratch file is dead (and reclaimable) after the last one.
    Consumers(u32),
    /// `Pattern=...` — workflow-level access pattern of the file.
    Pattern(AccessPattern),
    /// Recognized key, malformed value (reported, then ignored — hints
    /// are hints, not directives).
    Malformed { key: String, value: String },
    /// Unrecognized key (application-private metadata; ignored).
    Unknown { key: String, value: String },
}

/// Replication completion semantics (Table 3 `RepSmntc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RepSemantics {
    /// Return to the application after the first replica exists; the
    /// remaining replicas are created in the background.
    #[default]
    Optimistic,
    /// Return only after every replica is durable.
    Pessimistic,
}

/// File lifetime class (`Lifetime` tag): which data is worth keeping.
///
/// Workflow intermediates are typically written once, read by a known
/// set of consumers, then never touched again; tagging them `scratch`
/// lets the cache evict them first and — when a consumer count is
/// declared — lets the store reclaim them automatically after the last
/// read.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Lifetime {
    /// Keep until explicitly deleted (the default for untagged files).
    #[default]
    Durable,
    /// Workflow scratch: evict from caches first; auto-reclaim after
    /// the last declared consumer read (`Consumers=<n>`).
    Scratch,
}

/// Workflow access pattern (`Pattern` tag): how the file will be
/// consumed, independent of where it is placed (`DP`/`Replication`
/// decide placement; `Pattern` drives the cache tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    /// One producer, next-stage consumer: eligible for cache prefetch
    /// into the consumer's node.
    Pipeline,
    /// One producer, many consumers: cached copies stay pinned until
    /// the fan-out completes (all declared consumers have read).
    Broadcast,
    /// Many producers, one consumer.
    Reduce,
    /// One producer, disjoint-range consumers.
    Scatter,
}

/// Canonical tag keys.
pub mod keys {
    /// Data-placement policy selector.
    pub const DP: &str = "DP";
    /// Replication factor.
    pub const REPLICATION: &str = "Replication";
    /// Replication semantics.
    pub const REP_SEMANTICS: &str = "RepSmntc";
    /// Per-file cache budget.
    pub const CACHE_SIZE: &str = "CacheSize";
    /// Application-informed chunk size.
    pub const BLOCK_SIZE: &str = "BlockSize";
    /// File lifetime class (scratch/durable).
    pub const LIFETIME: &str = "Lifetime";
    /// Declared consumer-read count.
    pub const CONSUMERS: &str = "Consumers";
    /// Workflow access pattern.
    pub const PATTERN: &str = "Pattern";
}

/// Parse one `<key, value>` pair into a typed hint.
///
/// ```
/// use woss::hints::{parse, AccessPattern, Hint, Lifetime};
///
/// assert_eq!(parse("Lifetime", "scratch"), Hint::Lifetime(Lifetime::Scratch));
/// assert_eq!(parse("Consumers", "3"), Hint::Consumers(3));
/// assert_eq!(
///     parse("Pattern", "pipeline"),
///     Hint::Pattern(AccessPattern::Pipeline)
/// );
/// // Zero-valued hints are nonsense the data path must never see.
/// assert!(matches!(parse("Consumers", "0"), Hint::Malformed { .. }));
/// ```
pub fn parse(key: &str, value: &str) -> Hint {
    match key {
        keys::DP => parse_dp(value),
        keys::REPLICATION => match value.trim().parse::<u32>() {
            Ok(n) if n >= 1 => Hint::Replication(n),
            _ => malformed(key, value),
        },
        keys::REP_SEMANTICS => match value.trim().to_ascii_lowercase().as_str() {
            // the paper's Table 3 itself spells these loosely
            // ("Optimisite/Pessimestic"); accept prefixes.
            v if v.starts_with("optim") => {
                Hint::ReplicationSemantics(RepSemantics::Optimistic)
            }
            v if v.starts_with("pessim") => {
                Hint::ReplicationSemantics(RepSemantics::Pessimistic)
            }
            _ => malformed(key, value),
        },
        keys::CACHE_SIZE => match parse_size(value) {
            Some(n) if n >= 1 => Hint::CacheSize(n),
            _ => malformed(key, value),
        },
        keys::BLOCK_SIZE => match parse_size(value) {
            Some(n) if n >= 1 => Hint::BlockSize(n),
            _ => malformed(key, value),
        },
        keys::LIFETIME => match value.trim().to_ascii_lowercase().as_str() {
            "scratch" => Hint::Lifetime(Lifetime::Scratch),
            "durable" => Hint::Lifetime(Lifetime::Durable),
            _ => malformed(key, value),
        },
        keys::CONSUMERS => match value.trim().parse::<u32>() {
            // Zero declared consumers would mean "dead on arrival";
            // like every other zero-valued hint it is malformed.
            Ok(n) if n >= 1 => Hint::Consumers(n),
            _ => malformed(key, value),
        },
        keys::PATTERN => match value.trim().to_ascii_lowercase().as_str() {
            "pipeline" => Hint::Pattern(AccessPattern::Pipeline),
            "broadcast" => Hint::Pattern(AccessPattern::Broadcast),
            "reduce" => Hint::Pattern(AccessPattern::Reduce),
            "scatter" => Hint::Pattern(AccessPattern::Scatter),
            _ => malformed(key, value),
        },
        _ => Hint::Unknown {
            key: key.to_string(),
            value: value.to_string(),
        },
    }
}

fn parse_dp(value: &str) -> Hint {
    let v = value.trim();
    if v.eq_ignore_ascii_case("local") {
        return Hint::PlacementLocal;
    }
    if let Some(rest) = strip_word(v, "collocation") {
        if rest.is_empty() {
            return malformed(keys::DP, value);
        }
        return Hint::PlacementCollocate(rest.to_string());
    }
    if let Some(rest) = strip_word(v, "scatter") {
        if let Ok(n) = rest.parse::<u64>() {
            if n >= 1 {
                return Hint::PlacementScatter(n);
            }
        }
        return malformed(keys::DP, value);
    }
    malformed(keys::DP, value)
}

/// Case-insensitive `word` prefix followed by whitespace; returns the
/// trimmed remainder.
fn strip_word<'a>(v: &'a str, word: &str) -> Option<&'a str> {
    if v.len() >= word.len() && v[..word.len()].eq_ignore_ascii_case(word) {
        let rest = &v[word.len()..];
        if rest.is_empty() || rest.starts_with(char::is_whitespace) {
            return Some(rest.trim());
        }
    }
    None
}

/// Parse sizes like `4096`, `64K`, `1M`, `2G`. Values whose scaled size
/// does not fit in `u64` are rejected (a malformed hint must degrade to
/// [`Hint::Malformed`], never panic the manager).
fn parse_size(v: &str) -> Option<u64> {
    let v = v.trim();
    if v.is_empty() {
        return None;
    }
    let (digits, mult) = match v.as_bytes()[v.len() - 1].to_ascii_uppercase() {
        b'K' => (&v[..v.len() - 1], 1024u64),
        b'M' => (&v[..v.len() - 1], 1024 * 1024),
        b'G' => (&v[..v.len() - 1], 1024 * 1024 * 1024),
        _ => (v, 1),
    };
    digits
        .trim()
        .parse::<u64>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
}

fn malformed(key: &str, value: &str) -> Hint {
    Hint::Malformed {
        key: key.to_string(),
        value: value.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_local() {
        assert_eq!(parse("DP", "local"), Hint::PlacementLocal);
        assert_eq!(parse("DP", " LOCAL "), Hint::PlacementLocal);
    }

    #[test]
    fn dp_collocation() {
        assert_eq!(
            parse("DP", "collocation merge_group_3"),
            Hint::PlacementCollocate("merge_group_3".into())
        );
        assert!(matches!(
            parse("DP", "collocation"),
            Hint::Malformed { .. }
        ));
    }

    #[test]
    fn dp_scatter() {
        assert_eq!(parse("DP", "scatter 16"), Hint::PlacementScatter(16));
        assert!(matches!(parse("DP", "scatter 0"), Hint::Malformed { .. }));
        assert!(matches!(parse("DP", "scatter x"), Hint::Malformed { .. }));
        // "scattergun" must not match the scatter word-prefix
        assert!(matches!(parse("DP", "scattergun 4"), Hint::Malformed { .. }));
    }

    #[test]
    fn replication() {
        assert_eq!(parse("Replication", "8"), Hint::Replication(8));
        assert!(matches!(parse("Replication", "0"), Hint::Malformed { .. }));
        assert!(matches!(
            parse("Replication", "many"),
            Hint::Malformed { .. }
        ));
    }

    #[test]
    fn rep_semantics_accepts_papers_spelling() {
        assert_eq!(
            parse("RepSmntc", "Optimisite"),
            Hint::ReplicationSemantics(RepSemantics::Optimistic)
        );
        assert_eq!(
            parse("RepSmntc", "Pessimestic"),
            Hint::ReplicationSemantics(RepSemantics::Pessimistic)
        );
        assert_eq!(
            parse("RepSmntc", "pessimistic"),
            Hint::ReplicationSemantics(RepSemantics::Pessimistic)
        );
    }

    #[test]
    fn sizes() {
        assert_eq!(parse("CacheSize", "4096"), Hint::CacheSize(4096));
        assert_eq!(parse("BlockSize", "64K"), Hint::BlockSize(65536));
        assert_eq!(parse("BlockSize", "1M"), Hint::BlockSize(1 << 20));
        assert!(matches!(parse("BlockSize", "0"), Hint::Malformed { .. }));
    }

    /// Zero-valued hints are nonsense the data path must never see: a
    /// zero scatter stride would feed a modulo, a zero replication
    /// factor would mean "store nothing", a zero block size would make
    /// chunking diverge. Each parses to `Malformed` (hints, not
    /// directives) so the dispatcher falls back to defaults.
    #[test]
    fn zero_values_malformed_for_every_key() {
        assert!(matches!(parse("DP", "scatter 0"), Hint::Malformed { .. }));
        assert!(matches!(parse("Replication", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("BlockSize", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("CacheSize", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("CacheSize", "0K"), Hint::Malformed { .. }));
    }

    /// A size whose scaled value overflows `u64` is malformed, not a
    /// panic: hostile or buggy tag values must never crash the manager.
    #[test]
    fn size_overflow_is_malformed_not_panic() {
        assert!(matches!(
            parse("BlockSize", "18446744073709551615K"),
            Hint::Malformed { .. }
        ));
        assert!(matches!(
            parse("CacheSize", "99999999999999999G"),
            Hint::Malformed { .. }
        ));
        // The largest representable size still parses.
        assert_eq!(
            parse("BlockSize", "18446744073709551615"),
            Hint::BlockSize(u64::MAX)
        );
    }

    #[test]
    fn lifetime_consumers_pattern() {
        assert_eq!(parse("Lifetime", "scratch"), Hint::Lifetime(Lifetime::Scratch));
        assert_eq!(parse("Lifetime", " Durable "), Hint::Lifetime(Lifetime::Durable));
        assert!(matches!(parse("Lifetime", "eternal"), Hint::Malformed { .. }));
        assert_eq!(parse("Consumers", "3"), Hint::Consumers(3));
        assert!(matches!(parse("Consumers", "0"), Hint::Malformed { .. }));
        assert!(matches!(parse("Consumers", "-1"), Hint::Malformed { .. }));
        assert_eq!(
            parse("Pattern", "pipeline"),
            Hint::Pattern(AccessPattern::Pipeline)
        );
        assert_eq!(
            parse("Pattern", "BROADCAST"),
            Hint::Pattern(AccessPattern::Broadcast)
        );
        assert_eq!(parse("Pattern", "reduce"), Hint::Pattern(AccessPattern::Reduce));
        assert_eq!(parse("Pattern", "scatter"), Hint::Pattern(AccessPattern::Scatter));
        assert!(matches!(parse("Pattern", "zigzag"), Hint::Malformed { .. }));
    }

    #[test]
    fn unknown_keys_preserved() {
        assert_eq!(
            parse("provenance", "stage3"),
            Hint::Unknown {
                key: "provenance".into(),
                value: "stage3".into()
            }
        );
    }
}
