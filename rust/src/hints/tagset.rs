//! Raw extended-attribute sets attached to files.
//!
//! A [`TagSet`] is the wire-level form of the cross-layer channel: an
//! ordered map of `<key, value>` string pairs, exactly what POSIX
//! `setxattr`/`getxattr` carries. In the prototype's design every
//! inter-component message related to a file is stamped with the file's
//! `TagSet` ("tagged communication messages") so each component's
//! dispatcher can trigger the matching optimization without extra
//! manager round-trips.

use super::{parse, AccessPattern, Hint, Lifetime, RepSemantics};
use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

/// An ordered set of extended attributes.
///
/// A tag set renders to a `key=value;key=value` wire form
/// ([`fmt::Display`]) and parses back losslessly ([`FromStr`]) — the
/// round-trip the hint grammar (paper Table 3) rides on. Delimiter
/// characters inside keys/values (`;`, `\`, and `=` in keys) are
/// backslash-escaped on render and unescaped on parse:
///
/// ```
/// use woss::hints::{Hint, TagSet};
///
/// let tags = TagSet::from_pairs([("DP", "collocation merge_g3")]);
/// let wire = tags.to_string();
/// assert_eq!(wire, "DP=collocation merge_g3");
///
/// let back: TagSet = wire.parse().unwrap();
/// assert_eq!(back, tags);
/// assert_eq!(
///     back.placement(),
///     Some(Hint::PlacementCollocate("merge_g3".into()))
/// );
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TagSet {
    tags: BTreeMap<String, String>,
}

impl TagSet {
    /// Empty set (a legacy, hint-free file).
    pub fn new() -> Self {
        TagSet::default()
    }

    /// Build from `(key, value)` pairs.
    pub fn from_pairs<K: Into<String>, V: Into<String>, I: IntoIterator<Item = (K, V)>>(
        pairs: I,
    ) -> Self {
        TagSet {
            tags: pairs
                .into_iter()
                .map(|(k, v)| (k.into(), v.into()))
                .collect(),
        }
    }

    /// Set (or replace) an attribute.
    pub fn set(&mut self, key: &str, value: &str) {
        self.tags.insert(key.to_string(), value.to_string());
    }

    /// Get an attribute's raw value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.tags.get(key).map(String::as_str)
    }

    /// Remove an attribute; returns the previous value.
    pub fn remove(&mut self, key: &str) -> Option<String> {
        self.tags.remove(key)
    }

    /// Number of attributes.
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    /// True when no attributes are set.
    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    /// Iterate raw pairs in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.tags.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Parse every pair into a typed [`Hint`].
    pub fn hints(&self) -> Vec<Hint> {
        self.iter().map(|(k, v)| parse(k, v)).collect()
    }

    /// The placement-relevant hint, if any (`DP=...` parses cleanly).
    pub fn placement(&self) -> Option<Hint> {
        self.get(super::keys::DP).map(|v| parse(super::keys::DP, v)).filter(|h| {
            matches!(
                h,
                Hint::PlacementLocal | Hint::PlacementCollocate(_) | Hint::PlacementScatter(_)
            )
        })
    }

    /// The requested replication factor, if tagged and well-formed.
    pub fn replication(&self) -> Option<u32> {
        match self
            .get(super::keys::REPLICATION)
            .map(|v| parse(super::keys::REPLICATION, v))
        {
            Some(Hint::Replication(n)) => Some(n),
            _ => None,
        }
    }

    /// Replication semantics (defaults to optimistic, per Table 3).
    pub fn replication_semantics(&self) -> RepSemantics {
        match self
            .get(super::keys::REP_SEMANTICS)
            .map(|v| parse(super::keys::REP_SEMANTICS, v))
        {
            Some(Hint::ReplicationSemantics(s)) => s,
            _ => RepSemantics::default(),
        }
    }

    /// Application-informed chunk size, if tagged.
    pub fn block_size(&self) -> Option<u64> {
        match self
            .get(super::keys::BLOCK_SIZE)
            .map(|v| parse(super::keys::BLOCK_SIZE, v))
        {
            Some(Hint::BlockSize(n)) => Some(n),
            _ => None,
        }
    }

    /// Per-file cache budget, if tagged.
    pub fn cache_size(&self) -> Option<u64> {
        match self
            .get(super::keys::CACHE_SIZE)
            .map(|v| parse(super::keys::CACHE_SIZE, v))
        {
            Some(Hint::CacheSize(n)) => Some(n),
            _ => None,
        }
    }

    /// Lifetime class (defaults to durable — untagged and malformed
    /// files are never auto-reclaimed):
    ///
    /// ```
    /// use woss::hints::{Lifetime, TagSet};
    ///
    /// let t = TagSet::from_pairs([("Lifetime", "scratch"), ("Consumers", "2")]);
    /// assert_eq!(t.lifetime(), Lifetime::Scratch);
    /// assert_eq!(t.consumers(), Some(2));
    /// assert_eq!(TagSet::new().lifetime(), Lifetime::Durable);
    /// ```
    pub fn lifetime(&self) -> Lifetime {
        match self
            .get(super::keys::LIFETIME)
            .map(|v| parse(super::keys::LIFETIME, v))
        {
            Some(Hint::Lifetime(l)) => l,
            _ => Lifetime::default(),
        }
    }

    /// Declared consumer-read count, if tagged and well-formed.
    pub fn consumers(&self) -> Option<u32> {
        match self
            .get(super::keys::CONSUMERS)
            .map(|v| parse(super::keys::CONSUMERS, v))
        {
            Some(Hint::Consumers(n)) => Some(n),
            _ => None,
        }
    }

    /// Workflow access pattern, if tagged and well-formed:
    ///
    /// ```
    /// use woss::hints::{AccessPattern, TagSet};
    ///
    /// let t = TagSet::from_pairs([("Pattern", "pipeline")]);
    /// assert_eq!(t.pattern(), Some(AccessPattern::Pipeline));
    /// assert_eq!(TagSet::new().pattern(), None);
    /// ```
    pub fn pattern(&self) -> Option<AccessPattern> {
        match self
            .get(super::keys::PATTERN)
            .map(|v| parse(super::keys::PATTERN, v))
        {
            Some(Hint::Pattern(p)) => Some(p),
            _ => None,
        }
    }
}

/// Append `s` to `out`, backslash-escaping `\`, `;`, and (for keys)
/// `=`, so the wire form survives delimiter characters in tag content.
fn escape_into(out: &mut String, s: &str, escape_eq: bool) {
    for c in s.chars() {
        if c == '\\' || c == ';' || (escape_eq && c == '=') {
            out.push('\\');
        }
        out.push(c);
    }
}

impl fmt::Display for TagSet {
    /// Render as `key=value` pairs joined by `;`, in key order, with
    /// delimiter characters backslash-escaped.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        for (i, (k, v)) in self.tags.iter().enumerate() {
            if i > 0 {
                out.push(';');
            }
            escape_into(&mut out, k, true);
            out.push('=');
            escape_into(&mut out, v, false);
        }
        f.write_str(&out)
    }
}

impl FromStr for TagSet {
    type Err = String;

    /// Parse the `key=value;key=value` wire form produced by
    /// [`TagSet`]'s `Display`, honoring backslash escapes. The empty
    /// string parses to an empty set.
    ///
    /// A key appearing twice is a malformed tag set, not a last-wins
    /// merge: on the wire there is no way to tell a retagged file from
    /// a corrupted one, so the parser refuses rather than silently
    /// dropping a pair (`docs/HINTS.md` documents the rule):
    ///
    /// ```
    /// use woss::hints::TagSet;
    ///
    /// assert!("DP=local;DP=scatter 4".parse::<TagSet>().is_err());
    /// ```
    fn from_str(s: &str) -> Result<TagSet, String> {
        let mut tags = TagSet::new();
        let mut key = String::new();
        let mut value = String::new();
        let mut in_value = false;
        let mut escaped = false;
        let commit = |tags: &mut TagSet, key: &str, value: &str| {
            if tags.get(key).is_some() {
                return Err(format!("duplicate tag key '{key}'"));
            }
            tags.set(key, value);
            Ok(())
        };
        for c in s.chars() {
            if escaped {
                (if in_value { &mut value } else { &mut key }).push(c);
                escaped = false;
                continue;
            }
            match c {
                '\\' => escaped = true,
                '=' if !in_value => in_value = true,
                ';' => {
                    if !in_value {
                        if !key.is_empty() {
                            return Err(format!("tag pair '{key}' is missing '='"));
                        }
                    } else {
                        commit(&mut tags, &key, &value)?;
                        key.clear();
                        value.clear();
                        in_value = false;
                    }
                }
                _ => (if in_value { &mut value } else { &mut key }).push(c),
            }
        }
        if escaped {
            return Err("dangling '\\' escape at end of tag set".to_string());
        }
        if in_value {
            commit(&mut tags, &key, &value)?;
        } else if !key.is_empty() {
            return Err(format!("tag pair '{key}' is missing '='"));
        }
        Ok(tags)
    }
}

impl<'a> IntoIterator for &'a TagSet {
    type Item = (&'a String, &'a String);
    type IntoIter = std::collections::btree_map::Iter<'a, String, String>;
    fn into_iter(self) -> Self::IntoIter {
        self.tags.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hints::Hint;

    #[test]
    fn set_get_remove() {
        let mut t = TagSet::new();
        assert!(t.is_empty());
        t.set("DP", "local");
        assert_eq!(t.get("DP"), Some("local"));
        t.set("DP", "scatter 4");
        assert_eq!(t.get("DP"), Some("scatter 4"), "set replaces");
        assert_eq!(t.remove("DP"), Some("scatter 4".to_string()));
        assert!(t.is_empty());
    }

    #[test]
    fn typed_accessors() {
        let t = TagSet::from_pairs([
            ("DP", "collocation g1"),
            ("Replication", "4"),
            ("RepSmntc", "pessimistic"),
            ("BlockSize", "64K"),
            ("CacheSize", "1M"),
        ]);
        assert_eq!(t.placement(), Some(Hint::PlacementCollocate("g1".into())));
        assert_eq!(t.replication(), Some(4));
        assert_eq!(t.replication_semantics(), RepSemantics::Pessimistic);
        assert_eq!(t.block_size(), Some(65536));
        assert_eq!(t.cache_size(), Some(1 << 20));
    }

    #[test]
    fn defaults_when_untagged() {
        let t = TagSet::new();
        assert_eq!(t.placement(), None);
        assert_eq!(t.replication(), None);
        assert_eq!(t.replication_semantics(), RepSemantics::Optimistic);
    }

    #[test]
    fn malformed_placement_is_none() {
        let t = TagSet::from_pairs([("DP", "teleport")]);
        assert_eq!(t.placement(), None, "hints are hints: malformed → default path");
    }

    #[test]
    fn display_parse_roundtrip() {
        let t = TagSet::from_pairs([
            ("DP", "collocation g1"),
            ("Replication", "4"),
            ("app.note", "x=y is fine in values"),
        ]);
        let wire = t.to_string();
        let back: TagSet = wire.parse().unwrap();
        assert_eq!(back, t, "display→parse must round-trip: {wire}");
        assert_eq!("".parse::<TagSet>().unwrap(), TagSet::new());
        assert!("noequals".parse::<TagSet>().is_err());
        assert!("a=b;dangling\\".parse::<TagSet>().is_err());
    }

    /// Duplicate keys on the wire are a parse error, never a silent
    /// last-wins overwrite — a retagged pair is indistinguishable from
    /// corruption once serialized.
    #[test]
    fn duplicate_keys_are_a_parse_error() {
        let err = "DP=local;DP=scatter 4".parse::<TagSet>().unwrap_err();
        assert!(err.contains("duplicate tag key 'DP'"), "{err}");
        assert!("a=1;b=2;a=3".parse::<TagSet>().is_err());
        // An escaped '=' makes the keys distinct — not a duplicate.
        let ok: TagSet = "a\\=x=1;a=2".parse().unwrap();
        assert_eq!(ok.get("a=x"), Some("1"));
        assert_eq!(ok.get("a"), Some("2"));
    }

    #[test]
    fn lifetime_pattern_accessors() {
        let t = TagSet::from_pairs([
            ("Lifetime", "scratch"),
            ("Consumers", "4"),
            ("Pattern", "broadcast"),
        ]);
        assert_eq!(t.lifetime(), crate::hints::Lifetime::Scratch);
        assert_eq!(t.consumers(), Some(4));
        assert_eq!(t.pattern(), Some(crate::hints::AccessPattern::Broadcast));
        // Malformed values degrade to the safe defaults.
        let bad = TagSet::from_pairs([("Lifetime", "forever"), ("Consumers", "0")]);
        assert_eq!(bad.lifetime(), crate::hints::Lifetime::Durable);
        assert_eq!(bad.consumers(), None);
    }

    #[test]
    fn delimiters_in_tag_content_roundtrip() {
        // ';' in values, '=' in keys, and '\' anywhere must survive the
        // wire form via escaping.
        let t = TagSet::from_pairs([
            ("app.note", "a;b"),
            ("odd=key", "v"),
            ("path", "C:\\data;x=1"),
        ]);
        let wire = t.to_string();
        let back: TagSet = wire.parse().unwrap();
        assert_eq!(back, t, "escaped round-trip failed: {wire}");
        assert_eq!(back.get("app.note"), Some("a;b"));
        assert_eq!(back.get("odd=key"), Some("v"));
        assert_eq!(back.get("path"), Some("C:\\data;x=1"));
    }

    #[test]
    fn unknown_tags_carried_not_interpreted() {
        let t = TagSet::from_pairs([("app.provenance", "stage-7")]);
        assert_eq!(t.get("app.provenance"), Some("stage-7"));
        assert_eq!(t.placement(), None);
        assert_eq!(t.hints().len(), 1);
        assert!(matches!(t.hints()[0], Hint::Unknown { .. }));
    }
}
