//! Core identifier and metadata types shared across the storage stack.

use crate::hints::TagSet;
use std::fmt;

/// A node index in the simulated (or live) cluster. Node 0 hosts the
/// metadata manager; the backend endpoint uses the highest index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// A file identifier assigned by the metadata manager at create time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FileId(pub u64);

/// Per-chunk metadata: which nodes hold replicas of the chunk. The first
/// entry is the primary (write target); later entries are replicas.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Replica holders, primary first.
    pub replicas: Vec<NodeId>,
}

impl ChunkMeta {
    /// Primary holder.
    pub fn primary(&self) -> NodeId {
        self.replicas[0]
    }
}

/// Per-file metadata maintained by the manager: the block-map plus the
/// extended attributes that carry cross-layer hints.
#[derive(Debug, Clone)]
pub struct FileMeta {
    /// Manager-assigned id.
    pub id: FileId,
    /// Logical size in bytes.
    pub size: u64,
    /// Chunk size this file was laid out with (the `BlockSize` hint can
    /// override the system default — scatter/gather patterns).
    pub chunk_size: u64,
    /// Extended attributes (the cross-layer channel).
    pub tags: TagSet,
    /// Block-map: one entry per chunk.
    pub chunks: Vec<ChunkMeta>,
    /// Node whose SAI created the file (placement context).
    pub creator: NodeId,
}

impl FileMeta {
    /// Number of chunks for `size` bytes at `chunk_size`.
    pub fn chunk_count(size: u64, chunk_size: u64) -> u64 {
        if size == 0 {
            0
        } else {
            size.div_ceil(chunk_size)
        }
    }

    /// Byte range `[lo, hi)` of chunk `idx` in a file of `size` bytes
    /// laid out at `chunk_size` — the slice a data path copies for that
    /// chunk. Static because the write path needs spans before the
    /// [`FileMeta`] exists.
    pub fn chunk_span(size: u64, chunk_size: u64, idx: u64) -> (u64, u64) {
        let lo = idx.saturating_mul(chunk_size).min(size);
        let hi = (idx + 1).saturating_mul(chunk_size).min(size);
        (lo, hi)
    }

    /// Size in bytes of chunk `idx` (the last chunk may be short).
    pub fn chunk_bytes(&self, idx: u64) -> u64 {
        debug_assert!(idx < self.chunks.len() as u64);
        let full = self.size / self.chunk_size;
        if idx < full {
            self.chunk_size
        } else {
            self.size - full * self.chunk_size
        }
    }

    /// All distinct nodes holding at least one chunk of this file.
    pub fn holders(&self) -> Vec<NodeId> {
        let mut out: Vec<NodeId> = self
            .chunks
            .iter()
            .flat_map(|c| c.replicas.iter().copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Chunk index range covering `[offset, offset+len)`.
    pub fn chunk_range(&self, offset: u64, len: u64) -> std::ops::Range<u64> {
        if len == 0 || self.size == 0 {
            return 0..0;
        }
        let first = offset / self.chunk_size;
        let last = (offset + len - 1).min(self.size - 1) / self.chunk_size;
        first..(last + 1).min(self.chunks.len() as u64)
    }
}

/// Storage-node registry entry kept by the manager.
#[derive(Debug, Clone)]
pub struct NodeState {
    /// Node id.
    pub node: NodeId,
    /// Total chunk-store capacity, bytes.
    pub capacity: u64,
    /// Bytes currently allocated.
    pub used: u64,
}

impl NodeState {
    /// Remaining capacity.
    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used)
    }

    /// Can this node accept `bytes` more?
    pub fn fits(&self, bytes: u64) -> bool {
        self.free() >= bytes
    }
}

/// Storage-stack error type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StorageError {
    /// The path does not name a stored file.
    NotFound(String),
    /// Create was issued for a path that already exists.
    AlreadyExists(String),
    /// No storage node has room for an allocation of this many bytes.
    NoSpace(u64),
    /// Malformed request (bad range, cross-node local read, ...).
    Invalid(String),
}

impl fmt::Display for StorageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StorageError::NotFound(p) => write!(f, "file not found: {p}"),
            StorageError::AlreadyExists(p) => write!(f, "file already exists: {p}"),
            StorageError::NoSpace(b) => write!(f, "no storage node has {b} bytes free"),
            StorageError::Invalid(m) => write!(f, "invalid argument: {m}"),
        }
    }
}

impl std::error::Error for StorageError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(size: u64, chunk_size: u64) -> FileMeta {
        let n = FileMeta::chunk_count(size, chunk_size);
        FileMeta {
            id: FileId(1),
            size,
            chunk_size,
            tags: TagSet::new(),
            chunks: (0..n)
                .map(|i| ChunkMeta {
                    replicas: vec![NodeId((i % 3) as usize)],
                })
                .collect(),
            creator: NodeId(1),
        }
    }

    #[test]
    fn chunk_count() {
        assert_eq!(FileMeta::chunk_count(0, 1024), 0);
        assert_eq!(FileMeta::chunk_count(1, 1024), 1);
        assert_eq!(FileMeta::chunk_count(1024, 1024), 1);
        assert_eq!(FileMeta::chunk_count(1025, 1024), 2);
    }

    #[test]
    fn chunk_bytes_last_short() {
        let m = meta(2500, 1024);
        assert_eq!(m.chunks.len(), 3);
        assert_eq!(m.chunk_bytes(0), 1024);
        assert_eq!(m.chunk_bytes(1), 1024);
        assert_eq!(m.chunk_bytes(2), 452);
    }

    #[test]
    fn chunk_bytes_exact_multiple() {
        let m = meta(2048, 1024);
        assert_eq!(m.chunks.len(), 2);
        assert_eq!(m.chunk_bytes(1), 1024);
    }

    #[test]
    fn chunk_span_matches_chunk_bytes() {
        let m = meta(2500, 1024);
        for idx in 0..m.chunks.len() as u64 {
            let (lo, hi) = FileMeta::chunk_span(m.size, m.chunk_size, idx);
            assert_eq!(hi - lo, m.chunk_bytes(idx), "chunk {idx}");
            assert_eq!(lo, idx * 1024);
        }
        assert_eq!(FileMeta::chunk_span(2500, 1024, 2), (2048, 2500));
    }

    #[test]
    fn holders_dedup() {
        let m = meta(4096, 1024); // nodes 0,1,2,0
        assert_eq!(m.holders(), vec![NodeId(0), NodeId(1), NodeId(2)]);
    }

    #[test]
    fn chunk_range() {
        let m = meta(10_240, 1024); // 10 chunks
        assert_eq!(m.chunk_range(0, 1024), 0..1);
        assert_eq!(m.chunk_range(0, 1025), 0..2);
        assert_eq!(m.chunk_range(5000, 100), 4..5);
        assert_eq!(m.chunk_range(9000, 9999), 8..10, "clamped to file end");
        assert_eq!(m.chunk_range(0, 0), 0..0);
    }

    #[test]
    fn node_state_capacity() {
        let n = NodeState {
            node: NodeId(3),
            capacity: 100,
            used: 80,
        };
        assert_eq!(n.free(), 20);
        assert!(n.fits(20));
        assert!(!n.fits(21));
    }
}
