//! The storage-system interface the workflow engine drives.
//!
//! Each evaluated configuration — WOSS, the DSS baseline, NFS, GPFS, and
//! node-local storage — implements [`StorageModel`]. The interface is
//! deliberately POSIX-shaped: whole-file/range reads and writes plus
//! `setxattr`/`getxattr`; the cross-layer channel is *only* the xattr
//! calls, mirroring the paper's thesis that no API extension is needed.

use crate::hints::TagSet;
use crate::sim::{Cluster, Metrics, SimTime};
use crate::storage::types::{NodeId, StorageError};

/// One storage configuration under test.
pub trait StorageModel {
    /// Short label used in result tables ("WOSS-RAM", "NFS", ...).
    fn name(&self) -> String;

    /// Create + write a whole file from `client`. Returns the time the
    /// write is complete from the application's perspective (replication
    /// semantics decide whether background replicas block).
    fn write_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        size: u64,
        tags: &TagSet,
        at: SimTime,
    ) -> Result<SimTime, StorageError>;

    /// Read a whole file into `client`.
    fn read_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError>;

    /// Read `[offset, offset+len)` (scatter consumers read disjoint
    /// regions). Default: whole-file read.
    fn read_range(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        _offset: u64,
        _len: u64,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        self.read_file(cluster, client, path, at)
    }

    /// Set an extended attribute (top-down hints). Non-POSIX systems may
    /// accept and ignore (legacy interop — the incremental-adoption
    /// argument).
    fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError>;

    /// Set a batch of extended attributes on one file (top-down hints,
    /// amortized). Systems with a batched metadata path (WOSS's sharded
    /// manager) override this to carry the whole batch in one RPC; the
    /// default falls back to sequential [`StorageModel::set_xattr`]
    /// calls, so legacy systems keep per-attribute cost — exactly the
    /// incremental-adoption story.
    fn set_xattrs_bulk(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        pairs: &[(String, String)],
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let mut t = at;
        for (key, value) in pairs {
            t = self.set_xattr(cluster, client, path, key, value, t)?;
        }
        Ok(t)
    }

    /// Get an extended attribute (bottom-up info). Returns the value (if
    /// any) and the completion time.
    fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError>;

    /// Decision-time replica locations for the scheduler. Empty when the
    /// system does not expose location (DSS, NFS): the paper's point is
    /// that schedulers can only exploit what the storage exposes. The
    /// *query cost* is charged by the caller via
    /// `get_xattr("location")`; this accessor is the parsed result.
    fn locations(&self, path: &str) -> Vec<NodeId>;

    /// Per-chunk locations over a byte range (scatter scheduling).
    fn locations_range(&self, path: &str, _offset: u64, _len: u64) -> Vec<NodeId> {
        self.locations(path)
    }

    /// Size of a stored file, if it exists.
    fn file_size(&self, path: &str) -> Option<u64>;

    /// Delete a file (stage-out cleanup).
    fn delete(&mut self, path: &str) -> Result<(), StorageError>;

    /// Counters accumulated so far.
    fn metrics(&self) -> &Metrics;

    /// Does this system expose data location to applications?
    fn exposes_location(&self) -> bool {
        false
    }
}
