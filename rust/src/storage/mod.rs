//! The object-based distributed storage substrate (paper §3.2).
//!
//! Components mirror Figure 2: a centralized metadata [`manager`], the
//! storage nodes (capacity tracked in the manager registry, device
//! timing in [`crate::sim::disk`]), and the client SAI logic embedded in
//! [`distributed::DistributedStore`]. The [`model::StorageModel`] trait
//! is the POSIX-shaped surface the workflow engine drives; `DSS` and
//! `WOSS` differ *only* in the dispatcher registry installed.

pub mod distributed;
pub mod local;
pub mod manager;
pub mod model;
pub mod types;

pub use distributed::{standard_deployment, DistributedStore};
pub use local::LocalFs;
pub use manager::{ChunkPlacement, Manager};
pub use model::StorageModel;
pub use types::{ChunkMeta, FileId, FileMeta, NodeId, NodeState, StorageError};
