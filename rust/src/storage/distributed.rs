//! The distributed object store: DSS baseline and WOSS.
//!
//! Both configurations share this implementation — exactly as in the
//! paper, where WOSS is MosaStore re-architected around the dispatcher:
//! the *only* difference between `DSS` and `WOSS` is the module
//! [`Registry`] installed in the manager (baseline vs hint-dispatching)
//! — which is the cross-layer thesis in code form. Storage nodes run on
//! every cluster node except the manager host (node 0), mirroring the
//! paper's deployment.
//!
//! Data-path timing composes fabric transfers and device I/O through the
//! busy-until resources in [`crate::sim`]:
//!
//! * write: per chunk, client→primary transfer, then primary disk write;
//!   eager replication fans out from the primary; `RepSmntc` decides
//!   whether replication blocks completion.
//! * read: per chunk, prefer a local replica (free of fabric cost — the
//!   locality the pipeline/reduce hints manufacture), else a random
//!   remote replica (the broadcast pattern's load spreading).

use crate::dispatch::Registry;
use crate::hints::TagSet;
use crate::sim::{Cluster, Metrics, SimTime};
use crate::storage::manager::Manager;
use crate::storage::model::StorageModel;
use crate::storage::types::{NodeId, NodeState, StorageError};
use crate::util::Rng;
use std::collections::HashSet;

/// DSS / WOSS deployment over the simulated cluster.
pub struct DistributedStore {
    label: String,
    manager: Manager,
    /// SAI metadata caches: (client, file) pairs whose attributes are
    /// cached client-side (first open pays the manager RPC). Keyed by
    /// FileId, not path: the sim hot loop must not allocate strings
    /// (perf pass, EXPERIMENTS.md §Perf).
    attr_cache: HashSet<(NodeId, crate::storage::FileId)>,
    /// Per-client read caches for the reuse pattern: (client, file)
    /// pairs fully cached at the client.
    read_cache: HashSet<(NodeId, crate::storage::FileId)>,
    /// Replica readiness: a replica cannot serve reads before its
    /// creation completes (matters for the broadcast sweep — eager
    /// replication is optimistic, so the write returns while replicas
    /// are still materializing). Keyed by (file, chunk, holder).
    replica_ready: std::collections::HashMap<(crate::storage::FileId, u64, NodeId), SimTime>,
    metrics: Metrics,
    rng: Rng,
}

impl DistributedStore {
    /// Deploy over `cluster` with the given module registry. Storage
    /// nodes are nodes `1..n` (node 0 hosts the manager), each
    /// contributing `node_capacity` bytes of chunk store.
    pub fn new(
        cluster: &Cluster,
        registry: Registry,
        node_capacity: u64,
        seed: u64,
    ) -> Self {
        let label = if registry.hints_enabled() { "WOSS" } else { "DSS" };
        let nodes: Vec<NodeState> = (1..cluster.n_nodes())
            .map(|i| NodeState {
                node: NodeId(i),
                capacity: node_capacity,
                used: 0,
            })
            .collect();
        DistributedStore {
            label: label.to_string(),
            manager: Manager::new(NodeId(0), nodes, registry, cluster.calib()),
            attr_cache: HashSet::new(),
            read_cache: HashSet::new(),
            replica_ready: std::collections::HashMap::new(),
            metrics: Metrics::new(),
            rng: Rng::new(seed),
        }
    }

    /// Convenience: DSS baseline (hints carried, never dispatched).
    pub fn dss(cluster: &Cluster, node_capacity: u64, seed: u64) -> Self {
        DistributedStore::new(cluster, Registry::baseline(), node_capacity, seed)
    }

    /// Convenience: full WOSS registry.
    pub fn woss(cluster: &Cluster, node_capacity: u64, seed: u64) -> Self {
        DistributedStore::new(cluster, Registry::woss(), node_capacity, seed)
    }

    /// Set a custom display label (e.g. "WOSS-RAM").
    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Access the manager (tests, diagnostics, runtime extension).
    pub fn manager(&self) -> &Manager {
        &self.manager
    }

    /// Mutable manager access (registering new optimization modules at
    /// runtime — the extensibility path).
    pub fn manager_mut(&mut self) -> &mut Manager {
        &mut self.manager
    }

    /// Ensure the client's SAI has the file's attributes cached; charges
    /// one manager RPC on the first access (open path).
    fn ensure_attrs(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        file: crate::storage::FileId,
        path: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        if self.attr_cache.contains(&(client, file)) {
            return Ok(at);
        }
        let (_, done) = self
            .manager
            .open(cluster, &mut self.metrics, client, path, at)?;
        self.attr_cache.insert((client, file));
        Ok(done)
    }

    /// A re-read is served from client memory when the file fits the
    /// cache budget: the `CacheSize` hint when tagged (WOSS), else the
    /// OS page cache below FUSE (all configurations benefit — standard
    /// kernel behaviour, not a cross-layer optimization).
    fn cache_hit(&self, client: NodeId, file: crate::storage::FileId, size: u64, tags: &TagSet, os_cache: u64) -> bool {
        if !self.read_cache.contains(&(client, file)) {
            return false;
        }
        let budget = if self.manager.registry().hints_enabled() {
            tags.cache_size().unwrap_or(os_cache)
        } else {
            os_cache
        };
        size <= budget
    }
}

impl StorageModel for DistributedStore {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn write_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        size: u64,
        tags: &TagSet,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let t = cluster.fuse_op(at); // open/create VFS call
        // Tags previously set on the path (before creation) merge with
        // the tags stamped on this write.
        let mut all_tags = self.manager.take_pending_tags(path).unwrap_or_default();
        for (k, v) in tags.iter() {
            all_tags.set(k, v);
        }
        let blocking = self
            .manager
            .registry()
            .replication()
            .blocking(&all_tags);

        let (placements, t) = self.manager.create(
            cluster,
            &mut self.metrics,
            client,
            path,
            size,
            all_tags,
            t,
        )?;
        let meta = self.manager.peek(path).expect("just created").clone();

        // Contiguous chunks headed to the same primary move as one
        // sequential run: one transfer, one device op (one seek). This is
        // the physical reason local placement wins on spinning disks —
        // round-robin striping degenerates to runs of length one.
        // The SAI data path is single-threaded (FUSE): successive runs
        // chain, so a striped remote write is still one ~stream-rate
        // flow, while a local run bypasses the network entirely.
        let mut completion = t;
        let mut chain = t;
        let mut idx = 0usize;
        while idx < placements.len() {
            let place = placements[idx].clone();
            let mut run_bytes = meta.chunk_bytes(idx as u64);
            let mut run_len = 1usize;
            while idx + run_len < placements.len()
                && placements[idx + run_len].primary == place.primary
            {
                run_bytes += meta.chunk_bytes((idx + run_len) as u64);
                run_len += 1;
            }

            let xfer = cluster
                .fabric
                .transfer(client, place.primary, run_bytes, chain);
            if place.primary == client {
                self.metrics.local_bytes += run_bytes;
            } else {
                self.metrics.net_bytes += run_bytes;
            }
            chain = chain.max(xfer.end);
            let written = if place.primary == client {
                // Local run: the device is the path (chain through it).
                let w = cluster.disks[place.primary.0].write(run_bytes, chain);
                chain = chain.max(w.end);
                w
            } else {
                // Remote run: the storage node's device write proceeds
                // off the client's critical path (ack on receipt).
                cluster.disks[place.primary.0].write(run_bytes, xfer.end)
            };
            self.metrics.chunk_writes += run_len as u64;
            completion = completion.max(written.end);
            for off in 0..run_len {
                self.replica_ready
                    .insert((meta.id, (idx + off) as u64, place.primary), written.end);
            }

            // Eager parallel replication: a star fan-out from the
            // primary, per chunk (replica targets rotate). The primary's
            // TX serializes the copies, so replication cost grows
            // linearly with the factor — the trade-off Table 4's
            // stage-in row and fig6's past-the-optimum region measure.
            for off in 0..run_len {
                let place = &placements[idx + off];
                let bytes = meta.chunk_bytes((idx + off) as u64);
                for &replica in place.replicas.iter() {
                    let rxfer =
                        cluster
                            .fabric
                            .transfer(place.primary, replica, bytes, xfer.end);
                    let rwritten = cluster.disks[replica.0].write(bytes, rxfer.end);
                    self.metrics.net_bytes += bytes;
                    self.metrics.chunk_writes += 1;
                    self.metrics.replicas_created += 1;
                    self.replica_ready
                        .insert((meta.id, (idx + off) as u64, replica), rwritten.end);
                    if blocking {
                        completion = completion.max(rwritten.end);
                    }
                }
            }
            idx += run_len;
        }

        self.attr_cache.insert((client, meta.id));
        Ok(cluster.fuse_op(completion)) // close
    }

    fn read_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let size = self
            .file_size(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        self.read_range(cluster, client, path, 0, size, at)
    }

    fn read_range(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        offset: u64,
        len: u64,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let t = cluster.fuse_op(at); // open
        let meta = self
            .manager
            .peek(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?
            .clone();
        let t = self.ensure_attrs(cluster, client, meta.id, path, t)?;

        if self.cache_hit(client, meta.id, meta.size, &meta.tags, cluster.calib().os_cache_bytes) {
            self.metrics.local_bytes += len.min(meta.size);
            return Ok(cluster.fuse_op(t));
        }

        // Pick a source per chunk (prefer local, else a random replica —
        // the broadcast pattern's load spreading), then coalesce
        // consecutive same-source chunks into sequential runs.
        let file = meta.id;
        let ready = |idx: u64, node: NodeId, at: SimTime, rr: &std::collections::HashMap<(crate::storage::FileId, u64, NodeId), SimTime>| {
            rr.get(&(file, idx, node)).map(|&r| r <= at).unwrap_or(true)
        };
        let chunk_sources: Vec<(NodeId, u64)> = meta
            .chunk_range(offset, len)
            .map(|idx| {
                let replicas = &meta.chunks[idx as usize].replicas;
                debug_assert!(!replicas.is_empty());
                // Only replicas that finished materializing can serve;
                // the primary (first entry) is always the fallback.
                let available: Vec<NodeId> = replicas
                    .iter()
                    .copied()
                    .filter(|&n| ready(idx, n, t, &self.replica_ready))
                    .collect();
                let pool: &[NodeId] = if available.is_empty() {
                    &replicas[..1]
                } else {
                    &available
                };
                let source = if pool.contains(&client) {
                    client
                } else {
                    *self.rng.choose(pool)
                };
                (source, meta.chunk_bytes(idx))
            })
            .collect();

        // Single-threaded SAI: runs chain back-to-back.
        let mut completion = t;
        let mut chain = t;
        let mut i = 0usize;
        while i < chunk_sources.len() {
            let source = chunk_sources[i].0;
            let mut run_bytes = 0u64;
            let mut run_len = 0usize;
            while i + run_len < chunk_sources.len() && chunk_sources[i + run_len].0 == source {
                run_bytes += chunk_sources[i + run_len].1;
                run_len += 1;
            }
            let read = cluster.disks[source.0].read(run_bytes, chain);
            self.metrics.chunk_reads += run_len as u64;
            if source == client {
                self.metrics.local_bytes += run_bytes;
                chain = chain.max(read.end);
            } else {
                self.metrics.net_bytes += run_bytes;
                let xfer = cluster.fabric.transfer(source, client, run_bytes, read.end);
                chain = chain.max(xfer.end);
            }
            completion = completion.max(chain);
            i += run_len;
        }

        self.read_cache.insert((client, meta.id));
        Ok(cluster.fuse_op(completion)) // close
    }

    fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let t = cluster.fuse_op(at);
        self.manager
            .set_xattr(cluster, &mut self.metrics, client, path, key, value, t)
    }

    fn set_xattrs_bulk(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        pairs: &[(String, String)],
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        if pairs.is_empty() {
            return Ok(at);
        }
        // One VFS call, one manager RPC, one queue slot for the batch.
        let t = cluster.fuse_op(at);
        self.manager
            .set_attrs_bulk(cluster, &mut self.metrics, client, path, pairs, t)
    }

    fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError> {
        let t = cluster.fuse_op(at);
        self.manager
            .get_xattr(cluster, &mut self.metrics, client, path, key, t)
    }

    fn locations(&self, path: &str) -> Vec<NodeId> {
        if !self.manager.registry().hints_enabled() {
            return Vec::new(); // DSS does not expose location
        }
        self.manager
            .peek(path)
            .map(|m| m.holders())
            .unwrap_or_default()
    }

    fn locations_range(&self, path: &str, offset: u64, len: u64) -> Vec<NodeId> {
        if !self.manager.registry().hints_enabled() {
            return Vec::new();
        }
        let Some(meta) = self.manager.peek(path) else {
            return Vec::new();
        };
        let mut out: Vec<NodeId> = meta
            .chunk_range(offset, len)
            .filter_map(|i| meta.chunks.get(i as usize))
            .map(|c| c.primary())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    fn file_size(&self, path: &str) -> Option<u64> {
        self.manager.peek(path).map(|m| m.size)
    }

    fn delete(&mut self, path: &str) -> Result<(), StorageError> {
        if let Some(meta) = self.manager.peek(path) {
            let id = meta.id;
            self.attr_cache.retain(|(_, f)| *f != id);
            self.read_cache.retain(|(_, f)| *f != id);
            self.replica_ready.retain(|(f, _, _), _| *f != id);
        }
        self.manager.delete(path)
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn exposes_location(&self) -> bool {
        self.manager.registry().hints_enabled()
    }
}

/// Default per-node chunk-store capacity for RAM-disk deployments
/// (4 GB machines keep ~3 GB usable).
pub const RAM_NODE_CAPACITY: u64 = 3 << 30;
/// Spinning-disk deployments are effectively unconstrained for these
/// workloads (300 GB disks).
pub const DISK_NODE_CAPACITY: u64 = 280 << 30;

/// Build the standard benchmark deployments over a cluster.
pub fn standard_deployment(
    cluster: &Cluster,
    woss: bool,
    ram: bool,
    seed: u64,
) -> DistributedStore {
    let capacity = if ram { RAM_NODE_CAPACITY } else { DISK_NODE_CAPACITY };
    let store = if woss {
        DistributedStore::woss(cluster, capacity, seed)
    } else {
        DistributedStore::dss(cluster, capacity, seed)
    };
    let suffix = if ram { "RAM" } else { "DISK" };
    let label = format!("{}-{}", if woss { "WOSS" } else { "DSS" }, suffix);
    store.with_label(&label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Calib, DiskKind};

    const MB: u64 = 1024 * 1024;

    fn setup(woss: bool) -> (Cluster, DistributedStore) {
        let calib = Calib::default();
        let cluster = Cluster::new(8, DiskKind::RamDisk, &calib);
        let store = standard_deployment(&cluster, woss, true, 42);
        (cluster, store)
    }

    #[test]
    fn write_then_read_roundtrip() {
        let (mut cl, mut st) = setup(true);
        let done = st
            .write_file(&mut cl, NodeId(1), "/a", 10 * MB, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        assert!(done > SimTime::ZERO);
        assert_eq!(st.file_size("/a"), Some(10 * MB));
        let rdone = st.read_file(&mut cl, NodeId(2), "/a", done).unwrap();
        assert!(rdone > done);
    }

    #[test]
    fn local_hint_eliminates_network() {
        let (mut cl, mut st) = setup(true);
        let tags = TagSet::from_pairs([("DP", "local")]);
        st.write_file(&mut cl, NodeId(3), "/local", 50 * MB, &tags, SimTime::ZERO)
            .unwrap();
        assert_eq!(st.metrics().net_bytes, 0, "all writes local");
        assert_eq!(st.metrics().local_bytes, 50 * MB);
        assert_eq!(st.locations("/local"), vec![NodeId(3)]);

        // A local read by the same node costs no network either.
        let before = st.metrics().net_bytes;
        st.read_file(&mut cl, NodeId(3), "/local", SimTime::ZERO)
            .unwrap();
        assert_eq!(st.metrics().net_bytes, before);
    }

    #[test]
    fn local_read_faster_than_remote() {
        let calib = Calib::default();
        // Spinning disks so device time is visible vs network.
        let mut cl = Cluster::new(8, DiskKind::Spinning, &calib);
        let mut st = standard_deployment(&cl_ref(&cl), true, false, 1);
        let tags = TagSet::from_pairs([("DP", "local")]);
        let w = st
            .write_file(&mut cl, NodeId(3), "/f", 100 * MB, &tags, SimTime::ZERO)
            .unwrap();
        let local = st.read_file(&mut cl, NodeId(3), "/f", w).unwrap();
        let mut cl2 = Cluster::new(8, DiskKind::Spinning, &calib);
        let mut st2 = standard_deployment(&cl2, true, false, 1);
        let w2 = st2
            .write_file(&mut cl2, NodeId(3), "/f", 100 * MB, &tags, SimTime::ZERO)
            .unwrap();
        let remote = st2.read_file(&mut cl2, NodeId(4), "/f", w2).unwrap();
        assert!(
            (local - w) < (remote - w2),
            "local read {:?} must beat remote {:?}",
            local - w,
            remote - w2
        );
    }

    fn cl_ref(c: &Cluster) -> &Cluster {
        c
    }

    #[test]
    fn dss_ignores_hints_and_hides_location() {
        let (mut cl, mut st) = setup(false);
        let tags = TagSet::from_pairs([("DP", "local"), ("Replication", "4")]);
        st.write_file(&mut cl, NodeId(3), "/f", 10 * MB, &tags, SimTime::ZERO)
            .unwrap();
        assert_eq!(st.metrics().replicas_created, 0, "DSS: no hint replication");
        assert_eq!(st.locations("/f"), Vec::<NodeId>::new());
        assert!(!st.exposes_location());
        let (loc, _) = st
            .get_xattr(&mut cl, NodeId(3), "/f", "location", SimTime::ZERO)
            .unwrap();
        assert_eq!(loc, None);
    }

    #[test]
    fn replication_tag_creates_replicas() {
        let (mut cl, mut st) = setup(true);
        let tags = TagSet::from_pairs([("Replication", "4")]);
        st.write_file(&mut cl, NodeId(1), "/db", 8 * MB, &tags, SimTime::ZERO)
            .unwrap();
        assert_eq!(st.metrics().replicas_created, 8 * 3, "8 chunks × 3 extra replicas");
        assert!(st.locations("/db").len() >= 4);
    }

    #[test]
    fn pessimistic_replication_blocks_longer() {
        let (mut cl, mut st) = setup(true);
        let opt = TagSet::from_pairs([("Replication", "4"), ("RepSmntc", "optimistic")]);
        let done_opt = st
            .write_file(&mut cl, NodeId(1), "/opt", 64 * MB, &opt, SimTime::ZERO)
            .unwrap();

        let (mut cl2, mut st2) = setup(true);
        let pes = TagSet::from_pairs([("Replication", "4"), ("RepSmntc", "pessimistic")]);
        let done_pes = st2
            .write_file(&mut cl2, NodeId(1), "/pes", 64 * MB, &pes, SimTime::ZERO)
            .unwrap();
        assert!(done_pes > done_opt);
    }

    #[test]
    fn pending_tags_applied_at_create() {
        let (mut cl, mut st) = setup(true);
        // Runtime tags the output path before the task writes it.
        st.set_xattr(&mut cl, NodeId(2), "/out", "DP", "local", SimTime::ZERO)
            .unwrap();
        st.write_file(&mut cl, NodeId(5), "/out", 10 * MB, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        assert_eq!(st.locations("/out"), vec![NodeId(5)], "local hint honored");
    }

    #[test]
    fn reuse_cache_hit_with_cache_hint() {
        let (mut cl, mut st) = setup(true);
        let tags = TagSet::from_pairs([("CacheSize", "100M")]);
        let w = st
            .write_file(&mut cl, NodeId(1), "/c", 10 * MB, &tags, SimTime::ZERO)
            .unwrap();
        let r1 = st.read_file(&mut cl, NodeId(2), "/c", w).unwrap();
        let net_after_first = st.metrics().net_bytes;
        let r2 = st.read_file(&mut cl, NodeId(2), "/c", r1).unwrap();
        assert_eq!(st.metrics().net_bytes, net_after_first, "second read cached");
        assert!(r2 - r1 < r1 - w, "cached read much faster");
    }

    #[test]
    fn scatter_layout_and_range_reads() {
        let (mut cl, mut st) = setup(true);
        let tags = TagSet::from_pairs([("DP", "scatter 2"), ("BlockSize", "1M")]);
        st.write_file(&mut cl, NodeId(1), "/s", 14 * MB, &tags, SimTime::ZERO)
            .unwrap();
        // 14 chunks in groups of 2 over 7 storage nodes
        let all = st.locations("/s");
        assert_eq!(all.len(), 7, "spread across the pool: {all:?}");
        let first_region = st.locations_range("/s", 0, 2 * MB);
        assert_eq!(first_region.len(), 1, "one node owns the first region");
    }

    #[test]
    fn missing_file_errors() {
        let (mut cl, mut st) = setup(true);
        assert!(st
            .read_file(&mut cl, NodeId(1), "/missing", SimTime::ZERO)
            .is_err());
        assert!(st.delete("/missing").is_err());
    }

    #[test]
    fn woss_no_tags_equals_dss_event_count() {
        // Design guideline: zero cost when unused. Untagged WOSS must do
        // exactly what DSS does (same ops, same bytes).
        let (mut cl_w, mut woss) = setup(true);
        let (mut cl_d, mut dss) = setup(false);
        for (i, size) in [(1u64, 5 * MB), (2, 12 * MB), (3, 1 * MB)] {
            let p = format!("/f{i}");
            woss.write_file(&mut cl_w, NodeId(i as usize), &p, size, &TagSet::new(), SimTime::ZERO)
                .unwrap();
            dss.write_file(&mut cl_d, NodeId(i as usize), &p, size, &TagSet::new(), SimTime::ZERO)
                .unwrap();
            woss.read_file(&mut cl_w, NodeId(4), &p, SimTime::ZERO).unwrap();
            dss.read_file(&mut cl_d, NodeId(4), &p, SimTime::ZERO).unwrap();
        }
        assert_eq!(woss.metrics().net_bytes, dss.metrics().net_bytes);
        assert_eq!(woss.metrics().chunk_writes, dss.metrics().chunk_writes);
        assert_eq!(woss.metrics().manager_ops, dss.metrics().manager_ops);
    }
}
