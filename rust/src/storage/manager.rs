//! The centralized metadata manager (paper Figure 2/3).
//!
//! The manager owns the namespace, per-file block-maps, the extended
//! attributes, and the storage-node registry, and it hosts the dispatcher
//! that routes allocation requests to placement modules and `getxattr`
//! requests to bottom-up providers.
//!
//! Timing model: every client→manager interaction is an RPC (fabric
//! latency) plus a service slot on the manager's worker pool. Matching
//! the prototype's acknowledged behaviour (§4.4), `set-attribute` calls
//! are serialized through a single queue when
//! `Calib::manager_setattr_serialized` is set — the dominant tagging
//! overhead in Table 6.

use crate::dispatch::{PlacementCtx, PlacementState, Registry};
use crate::hints::TagSet;
use crate::sim::{Cluster, Dur, Metrics, MultiResource, Resource, SimTime};
use crate::storage::types::{ChunkMeta, FileId, FileMeta, NodeId, NodeState, StorageError};
use std::collections::BTreeMap;

/// Chunk placement decision for one chunk: primary + replica holders.
#[derive(Debug, Clone)]
pub struct ChunkPlacement {
    pub primary: NodeId,
    pub replicas: Vec<NodeId>,
}

/// The metadata manager.
pub struct Manager {
    /// Node hosting the manager process.
    host: NodeId,
    files: BTreeMap<String, FileMeta>,
    nodes: Vec<NodeState>,
    registry: Registry,
    placement_state: PlacementState,
    workers: MultiResource,
    setattr_queue: Resource,
    op_cost: Dur,
    setattr_cost: Dur,
    setattr_serialized: bool,
    next_file_id: u64,
}

impl Manager {
    /// Build a manager hosted on `host` managing `storage_nodes`.
    pub fn new(
        host: NodeId,
        storage_nodes: Vec<NodeState>,
        registry: Registry,
        calib: &crate::sim::Calib,
    ) -> Self {
        Manager {
            host,
            files: BTreeMap::new(),
            nodes: storage_nodes,
            registry,
            placement_state: PlacementState::default(),
            workers: MultiResource::new(calib.manager_parallelism.max(1)),
            setattr_queue: Resource::new(),
            op_cost: Dur::from_millis_f64(calib.manager_op_ms),
            setattr_cost: Dur::from_millis_f64(calib.manager_setattr_ms),
            setattr_serialized: calib.manager_setattr_serialized,
            next_file_id: 1,
        }
    }

    /// Manager host node.
    pub fn host(&self) -> NodeId {
        self.host
    }

    /// The module registry (for diagnostics and extension).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Mutable registry access (runtime extension of the system —
    /// the paper's extensibility requirement).
    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    /// Current node registry view.
    pub fn nodes(&self) -> &[NodeState] {
        &self.nodes
    }

    /// One metadata RPC from `client`: request latency + a worker slot +
    /// response latency. Returns when the reply reaches the client.
    fn rpc(&mut self, cluster: &mut Cluster, client: NodeId, at: SimTime) -> SimTime {
        let req = cluster.fabric.rpc(client, self.host, at);
        let served = self.workers.acquire(req.end, self.op_cost);
        let resp = cluster.fabric.rpc(self.host, client, served.end);
        resp.end
    }

    /// A serialized `set-attribute` RPC (Table 6's bottleneck).
    fn setattr_rpc(&mut self, cluster: &mut Cluster, client: NodeId, at: SimTime) -> SimTime {
        let req = cluster.fabric.rpc(client, self.host, at);
        let served = if self.setattr_serialized {
            self.setattr_queue.acquire(req.end, self.setattr_cost)
        } else {
            self.workers.acquire(req.end, self.setattr_cost)
        };
        let resp = cluster.fabric.rpc(self.host, client, served.end);
        resp.end
    }

    /// Create a file and lay out its chunks through the dispatcher.
    /// Returns the per-chunk placements and the reply time.
    #[allow(clippy::too_many_arguments)]
    pub fn create(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        size: u64,
        tags: TagSet,
        at: SimTime,
    ) -> Result<(Vec<ChunkPlacement>, SimTime), StorageError> {
        if self.files.contains_key(path) {
            return Err(StorageError::AlreadyExists(path.to_string()));
        }
        let chunk_size = tags
            .block_size()
            .filter(|_| self.registry.hints_enabled())
            .unwrap_or(cluster.calib().chunk_size);
        let n_chunks = FileMeta::chunk_count(size, chunk_size);
        let factor = self.registry.replication_factor(&tags);

        let mut placements = Vec::with_capacity(n_chunks as usize);
        let mut chunks = Vec::with_capacity(n_chunks as usize);
        // Default layout: the file stripes round-robin over
        // `default_stripe_width` nodes starting from a per-file base slot
        // (MosaStore-style narrow striping).
        let stripe_width = cluster.calib().default_stripe_width.max(1);
        let mut base_slot: Option<usize> = None;
        for idx in 0..n_chunks {
            let chunk_bytes = if idx == n_chunks - 1 {
                size - idx * chunk_size
            } else {
                chunk_size
            };
            let mut ctx = PlacementCtx {
                client,
                tags: &tags,
                nodes: &self.nodes,
                state: &mut self.placement_state,
            };
            let hinted = self.registry.place_hinted(&mut ctx, idx, chunk_bytes);
            let primary = match hinted {
                Some(node) => node,
                None => {
                    let slot = match base_slot {
                        Some(b) => {
                            let n = self.nodes.len();
                            (b + (idx as usize % stripe_width)) % n
                        }
                        None => {
                            let mut c2 = PlacementCtx {
                                client,
                                tags: &tags,
                                nodes: &self.nodes,
                                state: &mut self.placement_state,
                            };
                            let first = c2
                                .next_rr(chunk_bytes)
                                .ok_or(StorageError::NoSpace(chunk_bytes))?;
                            let slot = self
                                .nodes
                                .iter()
                                .position(|s| s.node == first)
                                .expect("node in registry");
                            base_slot = Some(slot);
                            slot
                        }
                    };
                    // Capacity fallback: spill to round-robin when the
                    // stripe target is full.
                    if self.nodes[slot].fits(chunk_bytes) {
                        self.nodes[slot].node
                    } else {
                        let mut c3 = PlacementCtx {
                            client,
                            tags: &tags,
                            nodes: &self.nodes,
                            state: &mut self.placement_state,
                        };
                        c3.next_rr(chunk_bytes)
                            .ok_or(StorageError::NoSpace(chunk_bytes))?
                    }
                }
            };
            let replicas = if factor > 1 {
                let mut rctx = PlacementCtx {
                    client,
                    tags: &tags,
                    nodes: &self.nodes,
                    state: &mut self.placement_state,
                };
                self.registry
                    .replication()
                    .replica_targets(&mut rctx, primary, factor, chunk_bytes)
            } else {
                Vec::new()
            };
            // Commit usage.
            for holder in std::iter::once(primary).chain(replicas.iter().copied()) {
                if let Some(n) = self.nodes.iter_mut().find(|n| n.node == holder) {
                    n.used += chunk_bytes;
                }
            }
            let mut all = vec![primary];
            all.extend(replicas.iter().copied());
            chunks.push(ChunkMeta { replicas: all });
            placements.push(ChunkPlacement { primary, replicas });
        }

        let meta = FileMeta {
            id: FileId(self.next_file_id),
            size,
            chunk_size,
            tags,
            chunks,
            creator: client,
        };
        self.next_file_id += 1;
        self.files.insert(path.to_string(), meta);

        metrics.manager_ops += 1;
        let done = self.rpc(cluster, client, at);
        Ok((placements, done))
    }

    /// Look up file metadata (allocates a manager op; the SAI caches the
    /// result, so charge this once per open).
    pub fn open(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<(FileMeta, SimTime), StorageError> {
        let meta = self
            .files
            .get(path)
            .cloned()
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        metrics.manager_ops += 1;
        let done = self.rpc(cluster, client, at);
        Ok((meta, done))
    }

    /// Zero-cost metadata peek for decision logic (scheduler look-ups are
    /// charged explicitly through [`Manager::get_xattr`]).
    pub fn peek(&self, path: &str) -> Option<&FileMeta> {
        self.files.get(path)
    }

    /// Set one extended attribute (the top-down hint channel).
    pub fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        key: &str,
        value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        // Tags on yet-to-be-created files are held as pending: the paper's
        // workflow runtimes tag outputs before the producing task opens
        // them. We model that by creating a zero-size placeholder.
        let entry = self.files.entry(path.to_string()).or_insert_with(|| FileMeta {
            id: FileId(0),
            size: 0,
            chunk_size: cluster.calib().chunk_size,
            tags: TagSet::new(),
            chunks: Vec::new(),
            creator: client,
        });
        if entry.id == FileId(0) && entry.size == 0 {
            // placeholder gets a real id lazily at create()
        }
        entry.tags.set(key, value);
        metrics.manager_ops += 1;
        metrics.setattr_ops += 1;
        Ok(self.setattr_rpc(cluster, client, at))
    }

    /// Pending tags attached to `path` before creation (consumed by
    /// the SAI at create time).
    pub fn take_pending_tags(&mut self, path: &str) -> Option<TagSet> {
        match self.files.get(path) {
            Some(meta) if meta.chunks.is_empty() && meta.size == 0 => {
                let meta = self.files.remove(path).unwrap();
                Some(meta.tags)
            }
            _ => None,
        }
    }

    /// Get one extended attribute. System-reserved attributes (location,
    /// chunk_location, ...) are served by the bottom-up providers when
    /// the registry has hints enabled; everything else reads the plain
    /// xattr store.
    pub fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError> {
        let meta = self
            .files
            .get(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        let value = self
            .registry
            .get_system_attr(key, meta, &self.nodes)
            .or_else(|| meta.tags.get(key).map(str::to_string));
        metrics.manager_ops += 1;
        metrics.getattr_ops += 1;
        let done = self.rpc(cluster, client, at);
        Ok((value, done))
    }

    /// Delete a file, releasing capacity.
    pub fn delete(&mut self, path: &str) -> Result<(), StorageError> {
        let meta = self
            .files
            .remove(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            let bytes = meta.chunk_bytes(idx as u64);
            for holder in &chunk.replicas {
                if let Some(n) = self.nodes.iter_mut().find(|n| n.node == *holder) {
                    n.used = n.used.saturating_sub(bytes);
                }
            }
        }
        Ok(())
    }

    /// Number of files in the namespace.
    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Iterate paths (tests/diagnostics).
    pub fn paths(&self) -> impl Iterator<Item = &str> {
        self.files.keys().map(String::as_str)
    }
}

impl std::fmt::Debug for Manager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Manager")
            .field("host", &self.host)
            .field("files", &self.files.len())
            .field("nodes", &self.nodes.len())
            .field("registry", &self.registry)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Calib, DiskKind};

    fn setup(registry: Registry) -> (Cluster, Manager, Metrics) {
        let calib = Calib::default();
        let cluster = Cluster::new(4, DiskKind::RamDisk, &calib);
        let nodes = (1..4)
            .map(|i| NodeState {
                node: NodeId(i),
                capacity: 1 << 30,
                used: 0,
            })
            .collect();
        let mgr = Manager::new(NodeId(0), nodes, registry, &calib);
        (cluster, mgr, Metrics::new())
    }

    #[test]
    fn create_lays_out_chunks() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        let (pl, done) = mgr
            .create(
                &mut cl,
                &mut m,
                NodeId(1),
                "/f",
                3 * 1024 * 1024,
                TagSet::new(),
                SimTime::ZERO,
            )
            .unwrap();
        assert_eq!(pl.len(), 3);
        assert!(done > SimTime::ZERO);
        assert_eq!(mgr.peek("/f").unwrap().chunks.len(), 3);
        assert_eq!(m.manager_ops, 1);
        // usage committed
        let used: u64 = mgr.nodes().iter().map(|n| n.used).sum();
        assert_eq!(used, 3 * 1024 * 1024);
    }

    #[test]
    fn local_hint_places_on_creator() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        let tags = TagSet::from_pairs([("DP", "local")]);
        let (pl, _) = mgr
            .create(&mut cl, &mut m, NodeId(2), "/f", 2 << 20, tags, SimTime::ZERO)
            .unwrap();
        assert!(pl.iter().all(|p| p.primary == NodeId(2)));
    }

    #[test]
    fn baseline_location_not_exposed() {
        let (mut cl, mut mgr, mut m) = setup(Registry::baseline());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1024, TagSet::new(), SimTime::ZERO)
            .unwrap();
        let (v, _) = mgr
            .get_xattr(&mut cl, &mut m, NodeId(1), "/f", "location", SimTime::ZERO)
            .unwrap();
        assert_eq!(v, None, "DSS does not expose data location");
    }

    #[test]
    fn woss_location_exposed() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1024, TagSet::new(), SimTime::ZERO)
            .unwrap();
        let (v, _) = mgr
            .get_xattr(&mut cl, &mut m, NodeId(1), "/f", "location", SimTime::ZERO)
            .unwrap();
        assert!(v.is_some());
        assert_eq!(m.getattr_ops, 1);
    }

    #[test]
    fn setattr_serialized_queue_backs_up() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1024, TagSet::new(), SimTime::ZERO)
            .unwrap();
        // 10 concurrent setattrs from different clients all start at t=0:
        // the serialized queue must stretch them out.
        let mut last = SimTime::ZERO;
        for i in 0..10 {
            let done = mgr
                .set_xattr(
                    &mut cl,
                    &mut m,
                    NodeId(1 + (i % 3)),
                    "/f",
                    &format!("k{i}"),
                    "v",
                    SimTime::ZERO,
                )
                .unwrap();
            last = last.max(done);
        }
        let serial_floor = 10.0 * Calib::default().manager_op_ms / 1e3;
        assert!(
            last.as_secs_f64() >= serial_floor,
            "10 serialized ops must take ≥ {serial_floor}s, got {last}"
        );
        assert_eq!(m.setattr_ops, 10);
    }

    #[test]
    fn pending_tags_survive_until_create() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.set_xattr(&mut cl, &mut m, NodeId(1), "/out", "DP", "local", SimTime::ZERO)
            .unwrap();
        let pending = mgr.take_pending_tags("/out").unwrap();
        assert_eq!(pending.get("DP"), Some("local"));
        assert!(mgr.peek("/out").is_none(), "placeholder consumed");
    }

    #[test]
    fn delete_releases_capacity() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1 << 20, TagSet::new(), SimTime::ZERO)
            .unwrap();
        mgr.delete("/f").unwrap();
        assert_eq!(mgr.nodes().iter().map(|n| n.used).sum::<u64>(), 0);
        assert!(mgr.peek("/f").is_none());
    }

    #[test]
    fn no_space_error() {
        let calib = Calib::default();
        let mut cl = Cluster::new(3, DiskKind::RamDisk, &calib);
        let nodes = vec![NodeState {
            node: NodeId(1),
            capacity: 1024,
            used: 0,
        }];
        let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
        let mut m = Metrics::new();
        let err = mgr
            .create(&mut cl, &mut m, NodeId(1), "/big", 1 << 20, TagSet::new(), SimTime::ZERO)
            .unwrap_err();
        assert!(matches!(err, StorageError::NoSpace(_)));
    }
}
