//! The metadata manager (paper Figure 2/3), sharded.
//!
//! The manager owns the namespace, per-file block-maps, the extended
//! attributes, and the storage-node registry, and it hosts the dispatcher
//! that routes allocation requests to placement modules and `getxattr`
//! requests to bottom-up providers.
//!
//! ## Sharding
//!
//! The paper's prototype is centralized: one manager process, and —
//! acknowledged in §4.4 — one serialized queue for every `set-attribute`
//! call, which Table 6 identifies as the dominant tagging overhead. To
//! scale past that bottleneck the namespace here is split into
//! [`Calib::manager_shards`](crate::sim::Calib) shards keyed by
//! file-path hash; each shard owns its slice of the namespace plus its
//! **own worker pool and `set-attribute` queue**, so metadata load from
//! independent files spreads instead of funneling through one queue.
//! Placement state follows the same split through
//! [`ShardedPlacementState`]: per-shard round-robin cursors, global
//! collocation anchors. With `manager_shards = 1` (the default) every
//! path hashes to shard 0 and the original centralized behaviour — and
//! Table 6 — is reproduced exactly.
//!
//! ## Batched tagging
//!
//! [`Manager::set_attrs_bulk`] carries a file's whole tag set in one RPC:
//! one fabric round-trip and one queue slot whose service time is
//! `setattr_cost + (k−1)·op_cost` for `k` attributes, amortizing the
//! per-RPC cost the prototype pays per tag. A batch of one is exactly the
//! legacy [`Manager::set_xattr`] cost, so the Table 6 ladder is untouched
//! when `Calib::setattr_batch = 1`.
//!
//! Timing model: every client→manager interaction is an RPC (fabric
//! latency) plus a service slot on the owning shard's worker pool.
//! Matching the prototype's acknowledged behaviour (§4.4),
//! `set-attribute` calls are serialized through the shard's single queue
//! when `Calib::manager_setattr_serialized` is set.

use crate::dispatch::{PlacementCtx, Registry, ShardedPlacementState};
use crate::hints::TagSet;
use crate::sim::{Cluster, Dur, Metrics, MultiResource, Resource, SimTime};
use crate::storage::types::{ChunkMeta, FileId, FileMeta, NodeId, NodeState, StorageError};
use std::collections::BTreeMap;

/// Chunk placement decision for one chunk: primary + replica holders.
#[derive(Debug, Clone)]
pub struct ChunkPlacement {
    /// Node receiving the chunk's primary copy (the write target).
    pub primary: NodeId,
    /// Replica holders (excluding the primary).
    pub replicas: Vec<NodeId>,
}

/// One metadata shard: a namespace slice with its own service resources.
struct Shard {
    /// Files whose path hashes to this shard.
    files: BTreeMap<String, FileMeta>,
    /// Shard-local worker pool for general metadata ops.
    workers: MultiResource,
    /// Shard-local serialized `set-attribute` queue.
    setattr_queue: Resource,
}

/// The metadata manager.
pub struct Manager {
    /// Node hosting the manager process.
    host: NodeId,
    shards: Vec<Shard>,
    nodes: Vec<NodeState>,
    registry: Registry,
    placement: ShardedPlacementState,
    op_cost: Dur,
    setattr_cost: Dur,
    setattr_serialized: bool,
    next_file_id: u64,
}

impl Manager {
    /// Build a manager hosted on `host` managing `storage_nodes`, with
    /// `calib.manager_shards` namespace shards.
    pub fn new(
        host: NodeId,
        storage_nodes: Vec<NodeState>,
        registry: Registry,
        calib: &crate::sim::Calib,
    ) -> Self {
        let n_shards = calib.manager_shards.max(1);
        let shards = (0..n_shards)
            .map(|_| Shard {
                files: BTreeMap::new(),
                workers: MultiResource::new(calib.manager_parallelism.max(1)),
                setattr_queue: Resource::new(),
            })
            .collect();
        Manager {
            host,
            shards,
            nodes: storage_nodes,
            registry,
            placement: ShardedPlacementState::new(n_shards),
            op_cost: Dur::from_millis_f64(calib.manager_op_ms),
            setattr_cost: Dur::from_millis_f64(calib.manager_setattr_ms),
            setattr_serialized: calib.manager_setattr_serialized,
            next_file_id: 1,
        }
    }

    /// Manager host node.
    pub fn host(&self) -> NodeId {
        self.host
    }

    /// Number of namespace shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The module registry (for diagnostics and extension).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Mutable registry access (runtime extension of the system —
    /// the paper's extensibility requirement).
    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    /// Current node registry view.
    pub fn nodes(&self) -> &[NodeState] {
        &self.nodes
    }

    /// Which shard owns `path` (FNV-1a over the path bytes, shared with
    /// the live store's lock stripes via [`crate::dispatch::shard_for_path`]).
    fn shard_of(&self, path: &str) -> usize {
        crate::dispatch::shard_for_path(path, self.shards.len())
    }

    /// One metadata RPC from `client` served by `shard`: request latency
    /// + a worker slot + response latency. Returns when the reply reaches
    /// the client.
    fn rpc(&mut self, cluster: &mut Cluster, client: NodeId, shard: usize, at: SimTime) -> SimTime {
        let req = cluster.fabric.rpc(client, self.host, at);
        let served = self.shards[shard].workers.acquire(req.end, self.op_cost);
        let resp = cluster.fabric.rpc(self.host, client, served.end);
        resp.end
    }

    /// A (possibly serialized) `set-attribute` RPC carrying `batch_len`
    /// attributes in one message. The first attribute pays the full
    /// `set-attribute` service cost; each further attribute in the batch
    /// adds only a plain-op increment — the amortization the batched API
    /// exists for.
    fn setattr_rpc(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        shard: usize,
        batch_len: usize,
        at: SimTime,
    ) -> SimTime {
        let req = cluster.fabric.rpc(client, self.host, at);
        let service = self
            .setattr_cost
            .saturating_add(self.op_cost.scale(batch_len.saturating_sub(1) as f64));
        let serialized = self.setattr_serialized;
        let shard = &mut self.shards[shard];
        let served = if serialized {
            shard.setattr_queue.acquire(req.end, service)
        } else {
            shard.workers.acquire(req.end, service)
        };
        let resp = cluster.fabric.rpc(self.host, client, served.end);
        resp.end
    }

    /// Create a file and lay out its chunks through the dispatcher.
    /// Returns the per-chunk placements and the reply time.
    #[allow(clippy::too_many_arguments)]
    pub fn create(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        size: u64,
        tags: TagSet,
        at: SimTime,
    ) -> Result<(Vec<ChunkPlacement>, SimTime), StorageError> {
        let shard_idx = self.shard_of(path);
        if self.shards[shard_idx].files.contains_key(path) {
            return Err(StorageError::AlreadyExists(path.to_string()));
        }
        let chunk_size = tags
            .block_size()
            .filter(|_| self.registry.hints_enabled())
            .unwrap_or(cluster.calib().chunk_size);
        let n_chunks = FileMeta::chunk_count(size, chunk_size);
        let factor = self.registry.replication_factor(&tags);
        // Default layout: the file stripes round-robin over
        // `default_stripe_width` nodes starting from a per-file base slot
        // (MosaStore-style narrow striping).
        let stripe_width = cluster.calib().default_stripe_width.max(1);

        let nodes = &mut self.nodes;
        let registry = &self.registry;
        let (placements, chunks) = self.placement.with_view(shard_idx, |state| {
            let mut placements = Vec::with_capacity(n_chunks as usize);
            let mut chunks: Vec<ChunkMeta> = Vec::with_capacity(n_chunks as usize);
            let mut base_slot: Option<usize> = None;
            // `break 'place Some(e)` aborts placement; committed usage
            // from already-placed chunks is rolled back below so a
            // failed create leaks no capacity.
            let failed = 'place: {
                for idx in 0..n_chunks {
                    let chunk_bytes = if idx == n_chunks - 1 {
                        size - idx * chunk_size
                    } else {
                        chunk_size
                    };
                    let hinted = {
                        let mut ctx = PlacementCtx {
                            client,
                            tags: &tags,
                            nodes: &*nodes,
                            state: &mut *state,
                        };
                        registry.place_hinted(&mut ctx, idx, chunk_bytes)
                    };
                    let primary = match hinted {
                        Some(node) => node,
                        None => {
                            let slot = match base_slot {
                                Some(b) => {
                                    let n = nodes.len();
                                    (b + (idx as usize % stripe_width)) % n
                                }
                                None => {
                                    let mut c2 = PlacementCtx {
                                        client,
                                        tags: &tags,
                                        nodes: &*nodes,
                                        state: &mut *state,
                                    };
                                    let first = match c2.next_rr(chunk_bytes) {
                                        Some(f) => f,
                                        None => break 'place Some(StorageError::NoSpace(
                                            chunk_bytes,
                                        )),
                                    };
                                    let slot = nodes
                                        .iter()
                                        .position(|s| s.node == first)
                                        .expect("node in registry");
                                    base_slot = Some(slot);
                                    slot
                                }
                            };
                            // Capacity fallback: spill to round-robin when
                            // the stripe target is full.
                            if nodes[slot].fits(chunk_bytes) {
                                nodes[slot].node
                            } else {
                                let mut c3 = PlacementCtx {
                                    client,
                                    tags: &tags,
                                    nodes: &*nodes,
                                    state: &mut *state,
                                };
                                match c3.next_rr(chunk_bytes) {
                                    Some(n) => n,
                                    None => break 'place Some(StorageError::NoSpace(
                                        chunk_bytes,
                                    )),
                                }
                            }
                        }
                    };
                    let replicas = if factor > 1 {
                        let mut rctx = PlacementCtx {
                            client,
                            tags: &tags,
                            nodes: &*nodes,
                            state: &mut *state,
                        };
                        registry
                            .replication()
                            .replica_targets(&mut rctx, primary, factor, chunk_bytes)
                    } else {
                        Vec::new()
                    };
                    // Commit usage.
                    for holder in std::iter::once(primary).chain(replicas.iter().copied()) {
                        if let Some(n) = nodes.iter_mut().find(|n| n.node == holder) {
                            n.used += chunk_bytes;
                        }
                    }
                    let mut all = vec![primary];
                    all.extend(replicas.iter().copied());
                    chunks.push(ChunkMeta { replicas: all });
                    placements.push(ChunkPlacement { primary, replicas });
                }
                None
            };
            if let Some(err) = failed {
                // Roll back committed usage. Every committed chunk is a
                // full `chunk_size`: the short tail chunk is only ever
                // committed last, after which no failure can occur.
                for chunk in &chunks {
                    for holder in &chunk.replicas {
                        if let Some(n) = nodes.iter_mut().find(|n| n.node == *holder) {
                            n.used = n.used.saturating_sub(chunk_size);
                        }
                    }
                }
                return Err(err);
            }
            Ok((placements, chunks))
        })?;

        let meta = FileMeta {
            id: FileId(self.next_file_id),
            size,
            chunk_size,
            tags,
            chunks,
            creator: client,
        };
        self.next_file_id += 1;
        self.shards[shard_idx].files.insert(path.to_string(), meta);

        metrics.manager_ops += 1;
        let done = self.rpc(cluster, client, shard_idx, at);
        Ok((placements, done))
    }

    /// Look up file metadata (allocates a manager op; the SAI caches the
    /// result, so charge this once per open).
    pub fn open(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<(FileMeta, SimTime), StorageError> {
        let shard_idx = self.shard_of(path);
        let meta = self.shards[shard_idx]
            .files
            .get(path)
            .cloned()
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        metrics.manager_ops += 1;
        let done = self.rpc(cluster, client, shard_idx, at);
        Ok((meta, done))
    }

    /// Zero-cost metadata peek for decision logic (scheduler look-ups are
    /// charged explicitly through [`Manager::get_xattr`]).
    pub fn peek(&self, path: &str) -> Option<&FileMeta> {
        self.shards[self.shard_of(path)].files.get(path)
    }

    /// Set one extended attribute (the top-down hint channel). Cost and
    /// semantics of a single-attribute [`Manager::set_attrs_bulk`].
    pub fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        key: &str,
        value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let pair = [(key.to_string(), value.to_string())];
        self.set_attrs_bulk(cluster, metrics, client, path, &pair, at)
    }

    /// Set a batch of extended attributes on `path` with **one** RPC and
    /// one queue slot (see the module docs for the cost model). Tags on
    /// yet-to-be-created files are held as pending: the paper's workflow
    /// runtimes tag outputs before the producing task opens them. We
    /// model that by creating a zero-size placeholder.
    pub fn set_attrs_bulk(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        pairs: &[(String, String)],
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        if pairs.is_empty() {
            return Ok(at);
        }
        let shard_idx = self.shard_of(path);
        let default_chunk = cluster.calib().chunk_size;
        let entry = self.shards[shard_idx]
            .files
            .entry(path.to_string())
            .or_insert_with(|| FileMeta {
                id: FileId(0),
                size: 0,
                chunk_size: default_chunk,
                tags: TagSet::new(),
                chunks: Vec::new(),
                creator: client,
            });
        for (key, value) in pairs {
            entry.tags.set(key, value);
        }
        metrics.manager_ops += 1;
        metrics.setattr_ops += pairs.len() as u64;
        Ok(self.setattr_rpc(cluster, client, shard_idx, pairs.len(), at))
    }

    /// Pending tags attached to `path` before creation (consumed by
    /// the SAI at create time).
    pub fn take_pending_tags(&mut self, path: &str) -> Option<TagSet> {
        let shard_idx = self.shard_of(path);
        let files = &mut self.shards[shard_idx].files;
        match files.get(path) {
            Some(meta) if meta.chunks.is_empty() && meta.size == 0 => {
                let meta = files.remove(path).unwrap();
                Some(meta.tags)
            }
            _ => None,
        }
    }

    /// Get one extended attribute. System-reserved attributes (location,
    /// chunk_location, ...) are served by the bottom-up providers when
    /// the registry has hints enabled; everything else reads the plain
    /// xattr store.
    pub fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        metrics: &mut Metrics,
        client: NodeId,
        path: &str,
        key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError> {
        let shard_idx = self.shard_of(path);
        let meta = self.shards[shard_idx]
            .files
            .get(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        let value = self
            .registry
            .get_system_attr(key, meta, &self.nodes)
            .or_else(|| meta.tags.get(key).map(str::to_string));
        metrics.manager_ops += 1;
        metrics.getattr_ops += 1;
        let done = self.rpc(cluster, client, shard_idx, at);
        Ok((value, done))
    }

    /// Delete a file, releasing capacity.
    pub fn delete(&mut self, path: &str) -> Result<(), StorageError> {
        let shard_idx = self.shard_of(path);
        let meta = self.shards[shard_idx]
            .files
            .remove(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            let bytes = meta.chunk_bytes(idx as u64);
            for holder in &chunk.replicas {
                if let Some(n) = self.nodes.iter_mut().find(|n| n.node == *holder) {
                    n.used = n.used.saturating_sub(bytes);
                }
            }
        }
        Ok(())
    }

    /// Number of files in the namespace (all shards).
    pub fn file_count(&self) -> usize {
        self.shards.iter().map(|s| s.files.len()).sum()
    }

    /// Iterate paths across every shard, in sorted order
    /// (tests/diagnostics).
    pub fn paths(&self) -> impl Iterator<Item = &str> {
        let mut all: Vec<&str> = self
            .shards
            .iter()
            .flat_map(|s| s.files.keys().map(String::as_str))
            .collect();
        all.sort_unstable();
        all.into_iter()
    }
}

impl std::fmt::Debug for Manager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Manager")
            .field("host", &self.host)
            .field("shards", &self.shards.len())
            .field("files", &self.file_count())
            .field("nodes", &self.nodes.len())
            .field("registry", &self.registry)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Calib, DiskKind};

    fn setup(registry: Registry) -> (Cluster, Manager, Metrics) {
        setup_with(registry, Calib::default())
    }

    fn setup_with(registry: Registry, calib: Calib) -> (Cluster, Manager, Metrics) {
        let cluster = Cluster::new(4, DiskKind::RamDisk, &calib);
        let nodes = (1..4)
            .map(|i| NodeState {
                node: NodeId(i),
                capacity: 1 << 30,
                used: 0,
            })
            .collect();
        let mgr = Manager::new(NodeId(0), nodes, registry, &calib);
        (cluster, mgr, Metrics::new())
    }

    #[test]
    fn create_lays_out_chunks() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        let (pl, done) = mgr
            .create(
                &mut cl,
                &mut m,
                NodeId(1),
                "/f",
                3 * 1024 * 1024,
                TagSet::new(),
                SimTime::ZERO,
            )
            .unwrap();
        assert_eq!(pl.len(), 3);
        assert!(done > SimTime::ZERO);
        assert_eq!(mgr.peek("/f").unwrap().chunks.len(), 3);
        assert_eq!(m.manager_ops, 1);
        // usage committed
        let used: u64 = mgr.nodes().iter().map(|n| n.used).sum();
        assert_eq!(used, 3 * 1024 * 1024);
    }

    #[test]
    fn local_hint_places_on_creator() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        let tags = TagSet::from_pairs([("DP", "local")]);
        let (pl, _) = mgr
            .create(&mut cl, &mut m, NodeId(2), "/f", 2 << 20, tags, SimTime::ZERO)
            .unwrap();
        assert!(pl.iter().all(|p| p.primary == NodeId(2)));
    }

    #[test]
    fn baseline_location_not_exposed() {
        let (mut cl, mut mgr, mut m) = setup(Registry::baseline());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1024, TagSet::new(), SimTime::ZERO)
            .unwrap();
        let (v, _) = mgr
            .get_xattr(&mut cl, &mut m, NodeId(1), "/f", "location", SimTime::ZERO)
            .unwrap();
        assert_eq!(v, None, "DSS does not expose data location");
    }

    #[test]
    fn woss_location_exposed() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1024, TagSet::new(), SimTime::ZERO)
            .unwrap();
        let (v, _) = mgr
            .get_xattr(&mut cl, &mut m, NodeId(1), "/f", "location", SimTime::ZERO)
            .unwrap();
        assert!(v.is_some());
        assert_eq!(m.getattr_ops, 1);
    }

    #[test]
    fn setattr_serialized_queue_backs_up() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1024, TagSet::new(), SimTime::ZERO)
            .unwrap();
        // 10 concurrent setattrs from different clients all start at t=0:
        // the serialized queue must stretch them out.
        let mut last = SimTime::ZERO;
        for i in 0..10 {
            let done = mgr
                .set_xattr(
                    &mut cl,
                    &mut m,
                    NodeId(1 + (i % 3)),
                    "/f",
                    &format!("k{i}"),
                    "v",
                    SimTime::ZERO,
                )
                .unwrap();
            last = last.max(done);
        }
        let serial_floor = 10.0 * Calib::default().manager_op_ms / 1e3;
        assert!(
            last.as_secs_f64() >= serial_floor,
            "10 serialized ops must take ≥ {serial_floor}s, got {last}"
        );
        assert_eq!(m.setattr_ops, 10);
    }

    #[test]
    fn sharded_setattr_scales() {
        // The same storm of setattrs over distinct files, against 1 vs 4
        // shards: per-shard queues must cut the completion time by at
        // least 2x (hashing is not perfectly balanced, so not exactly 4x).
        let run = |shards: usize| -> f64 {
            let mut calib = Calib::default();
            calib.manager_shards = shards;
            let (mut cl, mut mgr, mut m) = setup_with(Registry::woss(), calib);
            assert_eq!(mgr.shard_count(), shards);
            let mut last = SimTime::ZERO;
            for i in 0..64 {
                let done = mgr
                    .set_xattr(
                        &mut cl,
                        &mut m,
                        NodeId(1 + (i % 3)),
                        &format!("/f{i}"),
                        "DP",
                        "local",
                        SimTime::ZERO,
                    )
                    .unwrap();
                last = last.max(done);
            }
            last.as_secs_f64()
        };
        let centralized = run(1);
        let sharded = run(4);
        assert!(
            sharded < centralized / 2.0,
            "4 shards must be >2x faster: {sharded:.4}s vs {centralized:.4}s"
        );
    }

    #[test]
    fn bulk_setattr_amortizes_rpc_cost() {
        let pairs: Vec<(String, String)> = (0..8)
            .map(|i| (format!("k{i}"), "v".to_string()))
            .collect();

        // Eight per-attribute RPCs, serialized.
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        let mut serial_last = SimTime::ZERO;
        for (k, v) in &pairs {
            let done = mgr
                .set_xattr(&mut cl, &mut m, NodeId(1), "/f", k, v, SimTime::ZERO)
                .unwrap();
            serial_last = serial_last.max(done);
        }

        // One batched RPC carrying all eight.
        let (mut cl2, mut mgr2, mut m2) = setup(Registry::woss());
        let bulk_done = mgr2
            .set_attrs_bulk(&mut cl2, &mut m2, NodeId(1), "/f", &pairs, SimTime::ZERO)
            .unwrap();

        assert!(
            bulk_done < serial_last,
            "bulk ({bulk_done}) must beat {} serial RPCs ({serial_last})",
            pairs.len()
        );
        // Same attributes stored either way.
        assert_eq!(mgr.peek("/f").unwrap().tags.len(), 8);
        assert_eq!(mgr2.peek("/f").unwrap().tags.len(), 8);
        // One RPC, eight attributes, in the counters.
        assert_eq!(m2.manager_ops, 1);
        assert_eq!(m2.setattr_ops, 8);
    }

    #[test]
    fn sharded_namespace_roundtrip() {
        let mut calib = Calib::default();
        calib.manager_shards = 4;
        let (mut cl, mut mgr, mut m) = setup_with(Registry::woss(), calib);
        for i in 0..16 {
            mgr.create(
                &mut cl,
                &mut m,
                NodeId(1),
                &format!("/d/f{i}"),
                1 << 20,
                TagSet::new(),
                SimTime::ZERO,
            )
            .unwrap();
        }
        assert_eq!(mgr.file_count(), 16);
        let listed: Vec<&str> = mgr.paths().collect();
        assert_eq!(listed.len(), 16);
        assert!(listed.windows(2).all(|w| w[0] < w[1]), "sorted across shards");
        for i in 0..16 {
            let path = format!("/d/f{i}");
            assert!(mgr.peek(&path).is_some(), "{path} resolvable");
            let (meta, _) = mgr.open(&mut cl, &mut m, NodeId(2), &path, SimTime::ZERO).unwrap();
            assert_eq!(meta.size, 1 << 20);
        }
        // Deleting through the shard router releases all capacity.
        for i in 0..16 {
            mgr.delete(&format!("/d/f{i}")).unwrap();
        }
        assert_eq!(mgr.file_count(), 0);
        assert_eq!(mgr.nodes().iter().map(|n| n.used).sum::<u64>(), 0);
    }

    #[test]
    fn pending_tags_survive_until_create() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.set_xattr(&mut cl, &mut m, NodeId(1), "/out", "DP", "local", SimTime::ZERO)
            .unwrap();
        let pending = mgr.take_pending_tags("/out").unwrap();
        assert_eq!(pending.get("DP"), Some("local"));
        assert!(mgr.peek("/out").is_none(), "placeholder consumed");
    }

    #[test]
    fn delete_releases_capacity() {
        let (mut cl, mut mgr, mut m) = setup(Registry::woss());
        mgr.create(&mut cl, &mut m, NodeId(1), "/f", 1 << 20, TagSet::new(), SimTime::ZERO)
            .unwrap();
        mgr.delete("/f").unwrap();
        assert_eq!(mgr.nodes().iter().map(|n| n.used).sum::<u64>(), 0);
        assert!(mgr.peek("/f").is_none());
    }

    #[test]
    fn failed_create_rolls_back_capacity() {
        // Pool with room for exactly one chunk: a two-chunk create must
        // fail AND leave the capacity accounting untouched.
        let calib = Calib::default();
        let mut cl = Cluster::new(3, DiskKind::RamDisk, &calib);
        let nodes = vec![NodeState {
            node: NodeId(1),
            capacity: 1 << 20,
            used: 0,
        }];
        let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
        let mut m = Metrics::new();
        let err = mgr
            .create(&mut cl, &mut m, NodeId(1), "/two", 2 << 20, TagSet::new(), SimTime::ZERO)
            .unwrap_err();
        assert!(matches!(err, StorageError::NoSpace(_)));
        assert_eq!(
            mgr.nodes().iter().map(|n| n.used).sum::<u64>(),
            0,
            "failed create must not leak committed capacity"
        );
        assert!(mgr.peek("/two").is_none());
    }

    #[test]
    fn no_space_error() {
        let calib = Calib::default();
        let mut cl = Cluster::new(3, DiskKind::RamDisk, &calib);
        let nodes = vec![NodeState {
            node: NodeId(1),
            capacity: 1024,
            used: 0,
        }];
        let mut mgr = Manager::new(NodeId(0), nodes, Registry::woss(), &calib);
        let mut m = Metrics::new();
        let err = mgr
            .create(&mut cl, &mut m, NodeId(1), "/big", 1 << 20, TagSet::new(), SimTime::ZERO)
            .unwrap_err();
        assert!(matches!(err, StorageError::NoSpace(_)));
    }
}
