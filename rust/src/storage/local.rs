//! Node-local storage: the optimal-performance baseline.
//!
//! The pipeline benchmark (Figure 5) includes a "local" configuration —
//! a plain local file system on RAM-disk — representing the best
//! possible performance on the hardware. Files live on the node that
//! wrote them; reads from other nodes are *not* supported (the paper
//! uses it only for single-node pipelines).

use crate::hints::TagSet;
use crate::sim::{Cluster, Metrics, SimTime};
use crate::storage::model::StorageModel;
use crate::storage::types::{NodeId, StorageError};
use std::collections::BTreeMap;

/// Per-node local file system (no network, no manager).
pub struct LocalFs {
    files: BTreeMap<String, (NodeId, u64)>,
    metrics: Metrics,
}

impl LocalFs {
    /// Empty local store.
    pub fn new() -> Self {
        LocalFs {
            files: BTreeMap::new(),
            metrics: Metrics::new(),
        }
    }
}

impl Default for LocalFs {
    fn default() -> Self {
        LocalFs::new()
    }
}

impl StorageModel for LocalFs {
    fn name(&self) -> String {
        "local".to_string()
    }

    fn write_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        size: u64,
        _tags: &TagSet,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let t = cluster.fuse_op(at);
        let written = cluster.disks[client.0].write(size, t);
        self.files.insert(path.to_string(), (client, size));
        self.metrics.local_bytes += size;
        self.metrics.chunk_writes += 1;
        Ok(written.end)
    }

    fn read_file(
        &mut self,
        cluster: &mut Cluster,
        client: NodeId,
        path: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        let (holder, size) = *self
            .files
            .get(path)
            .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
        if holder != client {
            return Err(StorageError::Invalid(format!(
                "local fs: {path} lives on {holder}, read from {client}"
            )));
        }
        let t = cluster.fuse_op(at);
        let read = cluster.disks[client.0].read(size, t);
        self.metrics.local_bytes += size;
        self.metrics.chunk_reads += 1;
        Ok(read.end)
    }

    fn set_xattr(
        &mut self,
        cluster: &mut Cluster,
        _client: NodeId,
        _path: &str,
        _key: &str,
        _value: &str,
        at: SimTime,
    ) -> Result<SimTime, StorageError> {
        // Plain local xattrs: a VFS call, no cross-layer behaviour.
        Ok(cluster.fuse_op(at))
    }

    fn get_xattr(
        &mut self,
        cluster: &mut Cluster,
        _client: NodeId,
        path: &str,
        _key: &str,
        at: SimTime,
    ) -> Result<(Option<String>, SimTime), StorageError> {
        if !self.files.contains_key(path) {
            return Err(StorageError::NotFound(path.to_string()));
        }
        Ok((None, cluster.fuse_op(at)))
    }

    fn locations(&self, path: &str) -> Vec<NodeId> {
        self.files.get(path).map(|(n, _)| vec![*n]).unwrap_or_default()
    }

    fn file_size(&self, path: &str) -> Option<u64> {
        self.files.get(path).map(|(_, s)| *s)
    }

    fn delete(&mut self, path: &str) -> Result<(), StorageError> {
        self.files
            .remove(path)
            .map(|_| ())
            .ok_or_else(|| StorageError::NotFound(path.to_string()))
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn exposes_location(&self) -> bool {
        true // trivially: everything is local
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Calib, Cluster, DiskKind};

    #[test]
    fn local_roundtrip() {
        let mut cl = Cluster::new(2, DiskKind::RamDisk, &Calib::default());
        let mut fs = LocalFs::new();
        let w = fs
            .write_file(&mut cl, NodeId(1), "/x", 1 << 20, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        let r = fs.read_file(&mut cl, NodeId(1), "/x", w).unwrap();
        assert!(r > w);
        assert_eq!(fs.metrics().net_bytes, 0);
        assert_eq!(fs.locations("/x"), vec![NodeId(1)]);
    }

    #[test]
    fn cross_node_read_rejected() {
        let mut cl = Cluster::new(2, DiskKind::RamDisk, &Calib::default());
        let mut fs = LocalFs::new();
        fs.write_file(&mut cl, NodeId(0), "/x", 1024, &TagSet::new(), SimTime::ZERO)
            .unwrap();
        assert!(fs.read_file(&mut cl, NodeId(1), "/x", SimTime::ZERO).is_err());
    }
}
