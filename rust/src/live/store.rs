//! In-process WOSS deployment with real chunk bytes.
//!
//! The same dispatcher [`Registry`] that drives the simulator drives
//! this store: chunk placement, replication fan-out, and the reserved
//! `location` attribute all run the identical decision logic — only
//! here the chunks are actual `Vec<u8>` held in per-node stores and the
//! callers are concurrent worker threads.

use crate::dispatch::{PlacementCtx, PlacementState, Registry};
use crate::hints::TagSet;
use crate::storage::types::{ChunkMeta, FileId, FileMeta, NodeId, NodeState, StorageError};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

/// Default chunk size for the live store (256 KiB = one kernel tile).
pub const LIVE_CHUNK: u64 = 256 * 1024;

/// One storage node's chunk store.
#[derive(Default)]
struct NodeStore {
    chunks: Mutex<HashMap<(FileId, u64), Vec<u8>>>,
}

/// Manager-side state (namespace + placement), one lock.
struct ManagerState {
    files: HashMap<String, FileMeta>,
    nodes: Vec<NodeState>,
    placement: PlacementState,
    next_id: u64,
}

/// The live object store.
pub struct LiveStore {
    registry: Registry,
    manager: Mutex<ManagerState>,
    stores: Vec<NodeStore>,
    /// Bytes written through [`LiveStore::write_file`] (lock-free counter).
    pub bytes_written: AtomicU64,
    /// Bytes returned by [`LiveStore::read_file`].
    pub bytes_read: AtomicU64,
    /// Chunk reads served from the reader's own node store.
    pub local_reads: AtomicU64,
    /// Chunk reads that had to fetch from another node's store.
    pub remote_reads: AtomicU64,
    /// `set-attribute` operations (top-down channel traffic).
    pub setattr_ops: AtomicU64,
    /// `get-attribute` operations (bottom-up channel traffic).
    pub getattr_ops: AtomicU64,
    /// Pending tags set before file creation.
    pending_tags: RwLock<HashMap<String, TagSet>>,
    /// Failure injection: nodes marked dead serve nothing.
    dead: RwLock<Vec<bool>>,
}

impl LiveStore {
    /// A deployment over `n_nodes` stores with `capacity` bytes each.
    pub fn new(registry: Registry, n_nodes: usize, capacity: u64) -> Self {
        LiveStore {
            registry,
            manager: Mutex::new(ManagerState {
                files: HashMap::new(),
                nodes: (0..n_nodes)
                    .map(|i| NodeState {
                        node: NodeId(i),
                        capacity,
                        used: 0,
                    })
                    .collect(),
                placement: PlacementState::default(),
                next_id: 1,
            }),
            stores: (0..n_nodes).map(|_| NodeStore::default()).collect(),
            bytes_written: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            local_reads: AtomicU64::new(0),
            remote_reads: AtomicU64::new(0),
            setattr_ops: AtomicU64::new(0),
            getattr_ops: AtomicU64::new(0),
            pending_tags: RwLock::new(HashMap::new()),
            dead: RwLock::new(vec![false; n_nodes]),
        }
    }

    /// Failure injection: mark a node dead. Chunks it held are only
    /// recoverable through replicas on surviving nodes — the
    /// reliability rationale behind the lazy-chained replication policy.
    pub fn kill_node(&self, node: NodeId) {
        self.dead.write().unwrap()[node.0] = true;
    }

    /// Revive a node (its chunk store contents survive the outage).
    pub fn revive_node(&self, node: NodeId) {
        self.dead.write().unwrap()[node.0] = false;
    }

    /// Is the node currently alive?
    pub fn is_alive(&self, node: NodeId) -> bool {
        !self.dead.read().unwrap()[node.0]
    }

    /// WOSS deployment (full hint registry).
    pub fn woss(n_nodes: usize) -> Self {
        LiveStore::new(Registry::woss(), n_nodes, u64::MAX / 2)
    }

    /// DSS baseline deployment.
    pub fn dss(n_nodes: usize) -> Self {
        LiveStore::new(Registry::baseline(), n_nodes, u64::MAX / 2)
    }

    /// Number of storage nodes.
    pub fn n_nodes(&self) -> usize {
        self.stores.len()
    }

    /// Set an extended attribute (top-down channel). Works before the
    /// file exists — the runtime tags outputs ahead of execution.
    pub fn set_xattr(&self, path: &str, key: &str, value: &str) {
        self.setattr_ops.fetch_add(1, Ordering::Relaxed);
        let mut mgr = self.manager.lock().unwrap();
        if let Some(meta) = mgr.files.get_mut(path) {
            meta.tags.set(key, value);
            return;
        }
        drop(mgr);
        self.pending_tags
            .write()
            .unwrap()
            .entry(path.to_string())
            .or_default()
            .set(key, value);
    }

    /// Get an extended attribute (bottom-up channel): system-reserved
    /// attributes are served by the registry's providers.
    pub fn get_xattr(&self, path: &str, key: &str) -> Option<String> {
        self.getattr_ops.fetch_add(1, Ordering::Relaxed);
        let mgr = self.manager.lock().unwrap();
        let meta = mgr.files.get(path)?;
        self.registry
            .get_system_attr(key, meta, &mgr.nodes)
            .or_else(|| meta.tags.get(key).map(str::to_string))
    }

    /// Replica holders (decision-time view for the scheduler).
    pub fn locations(&self, path: &str) -> Vec<NodeId> {
        if !self.registry.hints_enabled() {
            return Vec::new();
        }
        let mgr = self.manager.lock().unwrap();
        mgr.files.get(path).map(|m| m.holders()).unwrap_or_default()
    }

    /// Stored size of a file.
    pub fn file_size(&self, path: &str) -> Option<u64> {
        self.manager.lock().unwrap().files.get(path).map(|m| m.size)
    }

    /// Create + write a file from `client`, dispatching placement
    /// through the registry (pending tags merge in).
    pub fn write_file(
        &self,
        client: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> Result<(), StorageError> {
        let mut all_tags = self
            .pending_tags
            .write()
            .unwrap()
            .remove(path)
            .unwrap_or_default();
        for (k, v) in tags.iter() {
            all_tags.set(k, v);
        }

        // Placement decisions under the manager lock.
        let (meta, placements) = {
            let mut mgr = self.manager.lock().unwrap();
            if mgr.files.contains_key(path) {
                return Err(StorageError::AlreadyExists(path.to_string()));
            }
            let chunk_size = all_tags.block_size().unwrap_or(LIVE_CHUNK);
            let n_chunks = FileMeta::chunk_count(data.len() as u64, chunk_size);
            let factor = self.registry.replication_factor(&all_tags);
            let mut chunks = Vec::with_capacity(n_chunks as usize);
            let mut placements = Vec::with_capacity(n_chunks as usize);
            for idx in 0..n_chunks {
                let lo = (idx * chunk_size) as usize;
                let hi = ((idx + 1) * chunk_size).min(data.len() as u64) as usize;
                let bytes = (hi - lo) as u64;
                let ManagerState {
                    ref nodes,
                    ref mut placement,
                    ..
                } = *mgr;
                let mut ctx = PlacementCtx {
                    client,
                    tags: &all_tags,
                    nodes,
                    state: placement,
                };
                let primary = self
                    .registry
                    .place_chunk(&mut ctx, idx, bytes)
                    .ok_or(StorageError::NoSpace(bytes))?;
                let replicas = if factor > 1 {
                    let ManagerState {
                        ref nodes,
                        ref mut placement,
                        ..
                    } = *mgr;
                    let mut rctx = PlacementCtx {
                        client,
                        tags: &all_tags,
                        nodes,
                        state: placement,
                    };
                    self.registry
                        .replication()
                        .replica_targets(&mut rctx, primary, factor, bytes)
                } else {
                    Vec::new()
                };
                let mut all = vec![primary];
                all.extend(replicas.iter().copied());
                for holder in &all {
                    if let Some(n) = mgr.nodes.iter_mut().find(|n| n.node == *holder) {
                        n.used += bytes;
                    }
                }
                chunks.push(ChunkMeta { replicas: all });
                placements.push((idx, lo, hi));
            }
            let id = FileId(mgr.next_id);
            mgr.next_id += 1;
            let meta = FileMeta {
                id,
                size: data.len() as u64,
                chunk_size,
                tags: all_tags,
                chunks,
                creator: client,
            };
            mgr.files.insert(path.to_string(), meta.clone());
            (meta, placements)
        };

        // Data path outside the manager lock: copy bytes to each holder.
        for (idx, lo, hi) in placements {
            let payload = &data[lo..hi];
            for holder in &meta.chunks[idx as usize].replicas {
                self.stores[holder.0]
                    .chunks
                    .lock()
                    .unwrap()
                    .insert((meta.id, idx), payload.to_vec());
            }
        }
        self.bytes_written
            .fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Read a whole file into a buffer from `client`'s perspective
    /// (locality counted per chunk).
    pub fn read_file(&self, client: NodeId, path: &str) -> Result<Vec<u8>, StorageError> {
        let meta = {
            let mgr = self.manager.lock().unwrap();
            mgr.files
                .get(path)
                .cloned()
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        let mut out = Vec::with_capacity(meta.size as usize);
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            // Fail over to the first live replica; error only when every
            // holder of the chunk is down.
            let live: Vec<NodeId> = chunk
                .replicas
                .iter()
                .copied()
                .filter(|&n| self.is_alive(n))
                .collect();
            if live.is_empty() {
                return Err(StorageError::Invalid(format!(
                    "all {} replicas of chunk {idx} of {path} are on dead nodes",
                    chunk.replicas.len()
                )));
            }
            let source = if live.contains(&client) {
                self.local_reads.fetch_add(1, Ordering::Relaxed);
                client
            } else {
                self.remote_reads.fetch_add(1, Ordering::Relaxed);
                live[0]
            };
            let store = self.stores[source.0].chunks.lock().unwrap();
            let bytes = store
                .get(&(meta.id, idx as u64))
                .ok_or_else(|| StorageError::Invalid(format!("missing chunk {idx} of {path}")))?;
            out.extend_from_slice(bytes);
        }
        self.bytes_read.fetch_add(out.len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Delete a file and free its chunks.
    pub fn delete(&self, path: &str) -> Result<(), StorageError> {
        let meta = {
            let mut mgr = self.manager.lock().unwrap();
            let meta = mgr
                .files
                .remove(path)
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?;
            for (idx, chunk) in meta.chunks.iter().enumerate() {
                let bytes = meta.chunk_bytes(idx as u64);
                for holder in &chunk.replicas {
                    if let Some(n) = mgr.nodes.iter_mut().find(|n| n.node == *holder) {
                        n.used = n.used.saturating_sub(bytes);
                    }
                }
            }
            meta
        };
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            for holder in &chunk.replicas {
                self.stores[holder.0]
                    .chunks
                    .lock()
                    .unwrap()
                    .remove(&(meta.id, idx as u64));
            }
        }
        Ok(())
    }

    /// Does the store expose data location?
    pub fn exposes_location(&self) -> bool {
        self.registry.hints_enabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn roundtrip_bytes_exact() {
        let store = LiveStore::woss(4);
        let data: Vec<u8> = (0..600_000u32).map(|i| (i % 251) as u8).collect();
        store
            .write_file(NodeId(1), "/f", &data, &TagSet::new())
            .unwrap();
        let back = store.read_file(NodeId(2), "/f").unwrap();
        assert_eq!(back, data, "bytes must survive the storage path");
        assert_eq!(store.file_size("/f"), Some(600_000));
    }

    #[test]
    fn local_hint_places_all_chunks_on_writer() {
        let store = LiveStore::woss(4);
        let tags = TagSet::from_pairs([("DP", "local")]);
        let data = vec![7u8; 1_000_000];
        store.write_file(NodeId(3), "/local", &data, &tags).unwrap();
        assert_eq!(store.locations("/local"), vec![NodeId(3)]);
        // Reading from the writer is all-local.
        store.read_file(NodeId(3), "/local").unwrap();
        assert!(store.local_reads.load(Ordering::Relaxed) > 0);
        assert_eq!(store.remote_reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn location_attr_via_getxattr() {
        let store = LiveStore::woss(4);
        store
            .set_xattr("/out", "DP", "local");
        store
            .write_file(NodeId(2), "/out", &[1u8; 1000], &TagSet::new())
            .unwrap();
        let loc = store.get_xattr("/out", "location").unwrap();
        assert_eq!(loc, "n2", "pending tag honored + location exposed");
    }

    #[test]
    fn dss_hides_location_and_ignores_hints() {
        let store = LiveStore::dss(4);
        let tags = TagSet::from_pairs([("DP", "local"), ("Replication", "3")]);
        store.write_file(NodeId(1), "/f", &[0u8; 1000], &tags).unwrap();
        assert!(store.locations("/f").is_empty());
        assert_eq!(store.get_xattr("/f", "location"), None);
        assert!(!store.exposes_location());
    }

    #[test]
    fn replication_copies_chunks() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        store
            .write_file(NodeId(0), "/db", &[9u8; 600_000], &tags)
            .unwrap();
        assert!(store.locations("/db").len() >= 3);
        // Replica holders serve a large share of chunk reads locally
        // (replica targets rotate per chunk, so not necessarily all).
        for holder in store.locations("/db") {
            store.read_file(holder, "/db").unwrap();
        }
        let local = store.local_reads.load(Ordering::Relaxed);
        let remote = store.remote_reads.load(Ordering::Relaxed);
        assert!(
            local > remote,
            "replication should localize most reads: {local} local vs {remote} remote"
        );
    }

    #[test]
    fn concurrent_writers_and_readers() {
        let store = Arc::new(LiveStore::woss(8));
        let mut handles = Vec::new();
        for w in 0..8usize {
            let st = Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                let data: Vec<u8> = (0..300_000u32)
                    .map(|i| ((i as usize * (w + 1)) % 256) as u8)
                    .collect();
                let tags = TagSet::from_pairs([("DP", "local")]);
                st.write_file(NodeId(w % 8), &format!("/t{w}"), &data, &tags)
                    .unwrap();
                let back = st.read_file(NodeId((w + 1) % 8), &format!("/t{w}")).unwrap();
                assert_eq!(back, data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.bytes_written.load(Ordering::Relaxed), 8 * 300_000);
    }

    #[test]
    fn failure_injection_replicas_survive() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        let data: Vec<u8> = (0..700_000u32).map(|i| (i % 241) as u8).collect();
        store.write_file(NodeId(0), "/db", &data, &tags).unwrap();
        let holders = store.locations("/db");
        assert!(holders.len() >= 3);
        // Kill one holder: reads must fail over and return exact bytes.
        store.kill_node(holders[0]);
        let back = store.read_file(NodeId(4), "/db").unwrap();
        assert_eq!(back, data, "replica failover must preserve bytes");
        store.revive_node(holders[0]);
    }

    #[test]
    fn failure_injection_unreplicated_file_lost() {
        let store = LiveStore::woss(3);
        store
            .write_file(NodeId(1), "/single", &[7u8; 400_000], &TagSet::from_pairs([("DP", "local")]))
            .unwrap();
        store.kill_node(NodeId(1));
        assert!(
            store.read_file(NodeId(0), "/single").is_err(),
            "an unreplicated file on a dead node is unreadable"
        );
        store.revive_node(NodeId(1));
        assert!(store.read_file(NodeId(0), "/single").is_ok(), "outage, not loss");
    }

    #[test]
    fn delete_frees_chunks() {
        let store = LiveStore::woss(3);
        store
            .write_file(NodeId(0), "/f", &[1u8; 100_000], &TagSet::new())
            .unwrap();
        store.delete("/f").unwrap();
        assert!(store.read_file(NodeId(0), "/f").is_err());
        assert!(store.delete("/f").is_err());
    }
}
