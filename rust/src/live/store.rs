//! In-process WOSS deployment with real chunk bytes.
//!
//! The same dispatcher [`Registry`] that drives the simulator drives
//! this store: chunk placement, replication fan-out, and the reserved
//! `location` attribute all run the identical decision logic — only
//! here the chunks are actual `Vec<u8>` held in per-node stores and the
//! callers are concurrent worker threads.
//!
//! # Concurrency layout
//!
//! The manager side is **lock-striped**: the namespace splits into
//! [`LiveTuning::stripes`] shards keyed by file-path hash
//! ([`crate::dispatch::shard_for_path`], the same routing the simulated
//! sharded manager uses), so metadata operations on unrelated files
//! never contend. Placement state (node usage + round-robin cursors +
//! collocation anchors) lives behind one short-critical-section lock,
//! with per-stripe cursors and global anchors provided by the existing
//! [`ShardedPlacementState`]. Per-node chunk stores are `RwLock`s:
//! concurrent readers of the same node never block each other, and the
//! data-path byte copies run outside every manager lock.
//!
//! Replication honors the paper's `RepSmntc` semantics for real:
//! **pessimistic** writes return only after every replica holds the
//! bytes, while **optimistic** writes (the Table 3 default) return
//! after the primary copy and drain the remaining replicas through a
//! small background worker pool. [`LiveStore::flush_replication`] is
//! the barrier that makes shutdown and tests deterministic; dropping
//! the store drains the queue before joining the workers.
//!
//! Visibility contract: a file is readable with its full byte content
//! as soon as [`LiveStore::write_file`] returns (the primary copy is
//! synchronous); reads racing an in-progress create may transiently
//! fail, exactly as with the previous single-lock store. While
//! optimistic replicas are still draining, reads transparently fall
//! back to a holder that has materialized the chunk.

use crate::dispatch::{shard_for_path, PlacementCtx, Registry, ShardedPlacementState};
use crate::hints::TagSet;
use crate::storage::types::{ChunkMeta, FileId, FileMeta, NodeId, NodeState, StorageError};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// Default chunk size for the live store (256 KiB = one kernel tile).
pub const LIVE_CHUNK: u64 = 256 * 1024;

/// Concurrency tuning for a [`LiveStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveTuning {
    /// Namespace lock stripes. `1` reproduces the previous single-lock
    /// manager behaviour; values are clamped to ≥ 1.
    pub stripes: usize,
    /// Background replication worker threads (optimistic `RepSmntc`);
    /// clamped to ≥ 1.
    pub repl_workers: usize,
}

impl Default for LiveTuning {
    fn default() -> Self {
        LiveTuning {
            stripes: 8,
            repl_workers: 2,
        }
    }
}

/// One storage node's chunk store. Readers share the lock.
#[derive(Default)]
struct NodeStore {
    chunks: RwLock<HashMap<(FileId, u64), Vec<u8>>>,
}

/// One namespace stripe: the files (and pre-creation tags) whose path
/// hashes here.
#[derive(Default)]
struct NamespaceShard {
    files: HashMap<String, FileMeta>,
    /// Tags set before file creation (the runtime tags outputs ahead of
    /// execution); merged into the file at create time.
    pending_tags: HashMap<String, TagSet>,
}

/// Shared placement state: node usage plus the sharded cursor/anchor
/// state. Critical sections here are decision-sized (no byte copies).
struct PlacementCore {
    nodes: Vec<NodeState>,
    placement: ShardedPlacementState,
}

/// One background replication job: copy a chunk's payload to the
/// remaining replica holders.
struct ReplJob {
    file: FileId,
    chunk: u64,
    payload: Arc<Vec<u8>>,
    targets: Vec<NodeId>,
}

/// Backpressure bound: at most this many queued jobs per worker. Each
/// queued job holds one extra heap copy of its chunk payload, so an
/// unbounded queue would let optimistic writers that outpace the pool
/// grow memory without limit; past the bound, `enqueue` blocks the
/// writer until a worker pops — degrading toward pessimistic latency
/// instead of toward OOM.
const MAX_QUEUED_JOBS_PER_WORKER: usize = 64;

/// Queue state guarded by the pool mutex.
struct ReplQueue {
    jobs: VecDeque<ReplJob>,
    /// In-flight job count per file — lets `delete` wait out exactly the
    /// copies that could resurrect its chunks.
    in_flight: HashMap<FileId, usize>,
    shutdown: bool,
}

/// State shared between the store and its replication workers.
struct ReplShared {
    queue: Mutex<ReplQueue>,
    /// Signaled when work arrives or shutdown flips.
    work: Condvar,
    /// Signaled when a job completes (flush / cancel barriers re-check).
    drained: Condvar,
    stores: Arc<Vec<NodeStore>>,
    /// Replica chunk copies completed in the background.
    copied: AtomicU64,
}

/// The background replication worker pool.
struct ReplPool {
    shared: Arc<ReplShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Queued-job bound (workers × [`MAX_QUEUED_JOBS_PER_WORKER`]).
    cap: usize,
}

impl ReplPool {
    fn new(stores: Arc<Vec<NodeStore>>, workers: usize) -> Self {
        let shared = Arc::new(ReplShared {
            queue: Mutex::new(ReplQueue {
                jobs: VecDeque::new(),
                in_flight: HashMap::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            drained: Condvar::new(),
            stores,
            copied: AtomicU64::new(0),
        });
        let n_workers = workers.max(1);
        let workers = (0..n_workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("woss-repl-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn replication worker")
            })
            .collect();
        ReplPool {
            shared,
            workers,
            cap: n_workers * MAX_QUEUED_JOBS_PER_WORKER,
        }
    }

    /// Queue a copy job; blocks (backpressure) while the queue is at
    /// capacity, so writers cannot outrun the pool without bound.
    fn enqueue(&self, job: ReplJob) {
        let mut q = self.shared.queue.lock().unwrap();
        while q.jobs.len() >= self.cap {
            q = self.shared.drained.wait(q).unwrap();
        }
        q.jobs.push_back(job);
        drop(q);
        self.shared.work.notify_one();
    }

    /// Block until every queued and in-flight copy has landed.
    fn flush(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !(q.jobs.is_empty() && q.in_flight.is_empty()) {
            q = self.shared.drained.wait(q).unwrap();
        }
    }

    /// Drop queued jobs for `file` and wait out its in-flight copies,
    /// so a subsequent chunk sweep cannot be resurrected by a straggler.
    fn cancel_file(&self, file: FileId) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.retain(|j| j.file != file);
        while q.in_flight.contains_key(&file) {
            q = self.shared.drained.wait(q).unwrap();
        }
    }

    /// Queued + in-flight copy jobs (diagnostics).
    fn pending(&self) -> usize {
        let q = self.shared.queue.lock().unwrap();
        q.jobs.len() + q.in_flight.values().sum::<usize>()
    }
}

impl Drop for ReplPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker body: drain jobs (even after shutdown flips — shutdown means
/// "no new work", not "drop queued replicas"), then exit.
fn worker_loop(shared: &ReplShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    *q.in_flight.entry(job.file).or_insert(0) += 1;
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        // A slot just freed: wake any writer blocked on backpressure
        // (flush/cancel waiters re-check their conditions and re-sleep).
        shared.drained.notify_all();
        for &target in &job.targets {
            shared.stores[target.0]
                .chunks
                .write()
                .unwrap()
                .insert((job.file, job.chunk), job.payload.as_ref().clone());
            shared.copied.fetch_add(1, Ordering::Relaxed);
        }
        let mut q = shared.queue.lock().unwrap();
        if let Some(n) = q.in_flight.get_mut(&job.file) {
            *n -= 1;
            if *n == 0 {
                q.in_flight.remove(&job.file);
            }
        }
        drop(q);
        shared.drained.notify_all();
    }
}

/// The live object store.
pub struct LiveStore {
    registry: Registry,
    stripes: Vec<Mutex<NamespaceShard>>,
    core: Mutex<PlacementCore>,
    stores: Arc<Vec<NodeStore>>,
    next_id: AtomicU64,
    repl: ReplPool,
    /// Bytes written through [`LiveStore::write_file`] (lock-free counter).
    pub bytes_written: AtomicU64,
    /// Bytes returned by [`LiveStore::read_file`].
    pub bytes_read: AtomicU64,
    /// Chunk reads served from the reader's own node store.
    pub local_reads: AtomicU64,
    /// Chunk reads that had to fetch from another node's store.
    pub remote_reads: AtomicU64,
    /// `set-attribute` operations (top-down channel traffic).
    pub setattr_ops: AtomicU64,
    /// `get-attribute` operations (bottom-up channel traffic).
    pub getattr_ops: AtomicU64,
    /// Replica chunk copies handed to the background pool (optimistic
    /// `RepSmntc` writes).
    pub replicas_deferred: AtomicU64,
    /// Failure injection: nodes marked dead serve nothing.
    dead: RwLock<Vec<bool>>,
}

impl LiveStore {
    /// A deployment over `n_nodes` stores with `capacity` bytes each and
    /// default [`LiveTuning`].
    pub fn new(registry: Registry, n_nodes: usize, capacity: u64) -> Self {
        LiveStore::with_tuning(registry, n_nodes, capacity, LiveTuning::default())
    }

    /// A deployment with explicit concurrency tuning.
    pub fn with_tuning(
        registry: Registry,
        n_nodes: usize,
        capacity: u64,
        tuning: LiveTuning,
    ) -> Self {
        let stores: Arc<Vec<NodeStore>> =
            Arc::new((0..n_nodes).map(|_| NodeStore::default()).collect());
        let n_stripes = tuning.stripes.max(1);
        LiveStore {
            registry,
            stripes: (0..n_stripes)
                .map(|_| Mutex::new(NamespaceShard::default()))
                .collect(),
            core: Mutex::new(PlacementCore {
                nodes: (0..n_nodes)
                    .map(|i| NodeState {
                        node: NodeId(i),
                        capacity,
                        used: 0,
                    })
                    .collect(),
                placement: ShardedPlacementState::new(n_stripes),
            }),
            stores: Arc::clone(&stores),
            next_id: AtomicU64::new(1),
            repl: ReplPool::new(stores, tuning.repl_workers),
            bytes_written: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            local_reads: AtomicU64::new(0),
            remote_reads: AtomicU64::new(0),
            setattr_ops: AtomicU64::new(0),
            getattr_ops: AtomicU64::new(0),
            replicas_deferred: AtomicU64::new(0),
            dead: RwLock::new(vec![false; n_nodes]),
        }
    }

    /// WOSS deployment (full hint registry, default tuning).
    pub fn woss(n_nodes: usize) -> Self {
        LiveStore::new(Registry::woss(), n_nodes, u64::MAX / 2)
    }

    /// WOSS deployment with explicit stripe / worker counts.
    pub fn woss_tuned(n_nodes: usize, stripes: usize, repl_workers: usize) -> Self {
        LiveStore::with_tuning(
            Registry::woss(),
            n_nodes,
            u64::MAX / 2,
            LiveTuning {
                stripes,
                repl_workers,
            },
        )
    }

    /// DSS baseline deployment (default tuning).
    pub fn dss(n_nodes: usize) -> Self {
        LiveStore::new(Registry::baseline(), n_nodes, u64::MAX / 2)
    }

    /// DSS baseline deployment with explicit stripe / worker counts.
    pub fn dss_tuned(n_nodes: usize, stripes: usize, repl_workers: usize) -> Self {
        LiveStore::with_tuning(
            Registry::baseline(),
            n_nodes,
            u64::MAX / 2,
            LiveTuning {
                stripes,
                repl_workers,
            },
        )
    }

    /// Number of storage nodes.
    pub fn n_nodes(&self) -> usize {
        self.stores.len()
    }

    /// Number of namespace lock stripes.
    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    fn stripe_of(&self, path: &str) -> usize {
        shard_for_path(path, self.stripes.len())
    }

    /// Failure injection: mark a node dead. Chunks it held are only
    /// recoverable through replicas on surviving nodes — the
    /// reliability rationale behind the lazy-chained replication policy.
    pub fn kill_node(&self, node: NodeId) {
        self.dead.write().unwrap()[node.0] = true;
    }

    /// Revive a node (its chunk store contents survive the outage).
    pub fn revive_node(&self, node: NodeId) {
        self.dead.write().unwrap()[node.0] = false;
    }

    /// Is the node currently alive?
    pub fn is_alive(&self, node: NodeId) -> bool {
        !self.dead.read().unwrap()[node.0]
    }

    /// Barrier: block until every background replica copy has landed.
    /// After this returns (and absent concurrent writes), every file
    /// holds its full replica count — the determinism hook tests and
    /// shutdown paths rely on.
    pub fn flush_replication(&self) {
        self.repl.flush();
    }

    /// Replica chunk copies completed by the background pool so far.
    pub fn background_copies(&self) -> u64 {
        self.repl.shared.copied.load(Ordering::Relaxed)
    }

    /// Queued + in-flight background replication jobs (diagnostics).
    pub fn pending_replication(&self) -> usize {
        self.repl.pending()
    }

    /// Does every replica holder of every chunk of `path` hold the
    /// chunk's bytes right now? (`false` while optimistic replication
    /// is still draining; always `true` after [`Self::flush_replication`].)
    pub fn fully_replicated(&self, path: &str) -> Result<bool, StorageError> {
        let meta = {
            let stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
            stripe
                .files
                .get(path)
                .cloned()
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            for holder in &chunk.replicas {
                let present = self.stores[holder.0]
                    .chunks
                    .read()
                    .unwrap()
                    .contains_key(&(meta.id, idx as u64));
                if !present {
                    return Ok(false);
                }
            }
        }
        Ok(true)
    }

    /// Set an extended attribute (top-down channel). Works before the
    /// file exists — the runtime tags outputs ahead of execution.
    pub fn set_xattr(&self, path: &str, key: &str, value: &str) {
        self.setattr_ops.fetch_add(1, Ordering::Relaxed);
        let mut stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
        if let Some(meta) = stripe.files.get_mut(path) {
            meta.tags.set(key, value);
            return;
        }
        stripe
            .pending_tags
            .entry(path.to_string())
            .or_default()
            .set(key, value);
    }

    /// Get an extended attribute (bottom-up channel): system-reserved
    /// attributes are served by the registry's providers. Plain user
    /// tags never touch the shared placement core, so getattr traffic
    /// on unrelated files scales with the stripes.
    pub fn get_xattr(&self, path: &str, key: &str) -> Option<String> {
        self.getattr_ops.fetch_add(1, Ordering::Relaxed);
        let stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
        let meta = stripe.files.get(path)?;
        if self.registry.serves_attr(key) {
            let core = self.core.lock().unwrap();
            if let Some(value) = self.registry.get_system_attr(key, meta, &core.nodes) {
                return Some(value);
            }
        }
        meta.tags.get(key).map(str::to_string)
    }

    /// Replica holders (decision-time view for the scheduler).
    pub fn locations(&self, path: &str) -> Vec<NodeId> {
        if !self.registry.hints_enabled() {
            return Vec::new();
        }
        let stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
        stripe
            .files
            .get(path)
            .map(|m| m.holders())
            .unwrap_or_default()
    }

    /// Stored size of a file.
    pub fn file_size(&self, path: &str) -> Option<u64> {
        let stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
        stripe.files.get(path).map(|m| m.size)
    }

    /// Create + write a file from `client`, dispatching placement
    /// through the registry (pending tags merge in). Returns once the
    /// file is durable per its `RepSmntc` semantics: pessimistic waits
    /// for every replica, optimistic (the default) for the primary copy.
    pub fn write_file(
        &self,
        client: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> Result<(), StorageError> {
        let stripe_idx = self.stripe_of(path);
        let mut stripe = self.stripes[stripe_idx].lock().unwrap();
        if stripe.files.contains_key(path) {
            return Err(StorageError::AlreadyExists(path.to_string()));
        }
        let mut all_tags = stripe.pending_tags.remove(path).unwrap_or_default();
        for (k, v) in tags.iter() {
            all_tags.set(k, v);
        }
        let size = data.len() as u64;
        let chunk_size = all_tags.block_size().unwrap_or(LIVE_CHUNK);
        let n_chunks = FileMeta::chunk_count(size, chunk_size);
        let factor = self.registry.replication_factor(&all_tags);
        let blocking = factor > 1 && self.registry.replication().blocking(&all_tags);

        // Placement decisions: a short critical section on the shared
        // core (node usage + cursors); the stripe keeps its own
        // round-robin cursor, collocation anchors stay global.
        let chunks = {
            let mut core = self.core.lock().unwrap();
            let PlacementCore { nodes, placement } = &mut *core;
            let registry = &self.registry;
            placement.with_view(stripe_idx, |state| {
                let mut chunks: Vec<ChunkMeta> = Vec::with_capacity(n_chunks as usize);
                let failed = 'place: {
                    for idx in 0..n_chunks {
                        let (lo, hi) = FileMeta::chunk_span(size, chunk_size, idx);
                        let bytes = hi - lo;
                        let primary = {
                            let mut ctx = PlacementCtx {
                                client,
                                tags: &all_tags,
                                nodes: &*nodes,
                                state: &mut *state,
                            };
                            match registry.place_chunk(&mut ctx, idx, bytes) {
                                Some(node) => node,
                                None => break 'place Some(StorageError::NoSpace(bytes)),
                            }
                        };
                        let replicas = if factor > 1 {
                            let mut rctx = PlacementCtx {
                                client,
                                tags: &all_tags,
                                nodes: &*nodes,
                                state: &mut *state,
                            };
                            registry
                                .replication()
                                .replica_targets(&mut rctx, primary, factor, bytes)
                        } else {
                            Vec::new()
                        };
                        let mut all = vec![primary];
                        all.extend(replicas);
                        for holder in &all {
                            if let Some(n) = nodes.iter_mut().find(|n| n.node == *holder) {
                                n.used += bytes;
                            }
                        }
                        chunks.push(ChunkMeta { replicas: all });
                    }
                    None
                };
                if let Some(err) = failed {
                    // Roll back usage committed by already-placed chunks
                    // so a failed create leaks no capacity.
                    for (idx, chunk) in chunks.iter().enumerate() {
                        let (lo, hi) = FileMeta::chunk_span(size, chunk_size, idx as u64);
                        for holder in &chunk.replicas {
                            if let Some(n) = nodes.iter_mut().find(|n| n.node == *holder) {
                                n.used = n.used.saturating_sub(hi - lo);
                            }
                        }
                    }
                    return Err(err);
                }
                Ok(chunks)
            })?
        };

        let meta = FileMeta {
            id: FileId(self.next_id.fetch_add(1, Ordering::Relaxed)),
            size,
            chunk_size,
            tags: all_tags,
            chunks,
            creator: client,
        };
        stripe.files.insert(path.to_string(), meta.clone());
        drop(stripe);

        // Data path outside every manager lock: the primary copy lands
        // synchronously; replicas follow per the file's semantics.
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            let idx = idx as u64;
            let (lo, hi) = FileMeta::chunk_span(meta.size, meta.chunk_size, idx);
            let payload = &data[lo as usize..hi as usize];
            let key = (meta.id, idx);
            self.stores[chunk.primary().0]
                .chunks
                .write()
                .unwrap()
                .insert(key, payload.to_vec());
            let replicas = &chunk.replicas[1..];
            if replicas.is_empty() {
                continue;
            }
            if blocking {
                for holder in replicas {
                    self.stores[holder.0]
                        .chunks
                        .write()
                        .unwrap()
                        .insert(key, payload.to_vec());
                }
            } else {
                self.replicas_deferred
                    .fetch_add(replicas.len() as u64, Ordering::Relaxed);
                self.repl.enqueue(ReplJob {
                    file: meta.id,
                    chunk: idx,
                    payload: Arc::new(payload.to_vec()),
                    targets: replicas.to_vec(),
                });
            }
        }
        // A delete racing this create could have removed the meta while
        // the copies above were still landing — it would have found no
        // queued jobs to cancel. Re-check and sweep our own bytes so the
        // race cannot orphan chunks (an id check, so a file re-created
        // at this path after the delete is left untouched).
        let raced_delete = {
            let stripe = self.stripes[stripe_idx].lock().unwrap();
            stripe.files.get(path).map(|m| m.id) != Some(meta.id)
        };
        if raced_delete {
            self.repl.cancel_file(meta.id);
            for (idx, chunk) in meta.chunks.iter().enumerate() {
                for holder in &chunk.replicas {
                    self.stores[holder.0]
                        .chunks
                        .write()
                        .unwrap()
                        .remove(&(meta.id, idx as u64));
                }
            }
        }
        self.bytes_written.fetch_add(size, Ordering::Relaxed);
        Ok(())
    }

    /// Read a whole file into a buffer from `client`'s perspective
    /// (locality counted per chunk). Prefers the reader's own store,
    /// then any live holder that has materialized the chunk — so reads
    /// stay correct while optimistic replication is still draining.
    pub fn read_file(&self, client: NodeId, path: &str) -> Result<Vec<u8>, StorageError> {
        let meta = {
            let stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
            stripe
                .files
                .get(path)
                .cloned()
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        let mut out = Vec::with_capacity(meta.size as usize);
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            let key = (meta.id, idx as u64);
            // Fail over to a live replica; error only when every holder
            // of the chunk is down.
            let live: Vec<NodeId> = chunk
                .replicas
                .iter()
                .copied()
                .filter(|&n| self.is_alive(n))
                .collect();
            if live.is_empty() {
                return Err(StorageError::Invalid(format!(
                    "all {} replicas of chunk {idx} of {path} are on dead nodes",
                    chunk.replicas.len()
                )));
            }
            let ordered = std::iter::once(client)
                .filter(|c| live.contains(c))
                .chain(live.iter().copied().filter(|&n| n != client));
            let mut served = false;
            for source in ordered {
                let store = self.stores[source.0].chunks.read().unwrap();
                if let Some(bytes) = store.get(&key) {
                    out.extend_from_slice(bytes);
                    if source == client {
                        self.local_reads.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.remote_reads.fetch_add(1, Ordering::Relaxed);
                    }
                    served = true;
                    break;
                }
            }
            if !served {
                return Err(StorageError::Invalid(format!(
                    "missing chunk {idx} of {path}"
                )));
            }
        }
        self.bytes_read
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Delete a file and free its chunks. Queued background copies for
    /// the file are cancelled (and in-flight ones waited out) so a
    /// straggler cannot resurrect swept chunks.
    pub fn delete(&self, path: &str) -> Result<(), StorageError> {
        let meta = {
            let mut stripe = self.stripes[self.stripe_of(path)].lock().unwrap();
            stripe
                .files
                .remove(path)
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        {
            let mut core = self.core.lock().unwrap();
            for (idx, chunk) in meta.chunks.iter().enumerate() {
                let bytes = meta.chunk_bytes(idx as u64);
                for holder in &chunk.replicas {
                    if let Some(n) = core.nodes.iter_mut().find(|n| n.node == *holder) {
                        n.used = n.used.saturating_sub(bytes);
                    }
                }
            }
        }
        self.repl.cancel_file(meta.id);
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            for holder in &chunk.replicas {
                self.stores[holder.0]
                    .chunks
                    .write()
                    .unwrap()
                    .remove(&(meta.id, idx as u64));
            }
        }
        Ok(())
    }

    /// Does the store expose data location?
    pub fn exposes_location(&self) -> bool {
        self.registry.hints_enabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn roundtrip_bytes_exact() {
        let store = LiveStore::woss(4);
        let data: Vec<u8> = (0..600_000u32).map(|i| (i % 251) as u8).collect();
        store
            .write_file(NodeId(1), "/f", &data, &TagSet::new())
            .unwrap();
        let back = store.read_file(NodeId(2), "/f").unwrap();
        assert_eq!(back, data, "bytes must survive the storage path");
        assert_eq!(store.file_size("/f"), Some(600_000));
    }

    #[test]
    fn local_hint_places_all_chunks_on_writer() {
        let store = LiveStore::woss(4);
        let tags = TagSet::from_pairs([("DP", "local")]);
        let data = vec![7u8; 1_000_000];
        store.write_file(NodeId(3), "/local", &data, &tags).unwrap();
        assert_eq!(store.locations("/local"), vec![NodeId(3)]);
        // Reading from the writer is all-local.
        store.read_file(NodeId(3), "/local").unwrap();
        assert!(store.local_reads.load(Ordering::Relaxed) > 0);
        assert_eq!(store.remote_reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn location_attr_via_getxattr() {
        let store = LiveStore::woss(4);
        store.set_xattr("/out", "DP", "local");
        store
            .write_file(NodeId(2), "/out", &[1u8; 1000], &TagSet::new())
            .unwrap();
        let loc = store.get_xattr("/out", "location").unwrap();
        assert_eq!(loc, "n2", "pending tag honored + location exposed");
    }

    #[test]
    fn dss_hides_location_and_ignores_hints() {
        let store = LiveStore::dss(4);
        let tags = TagSet::from_pairs([("DP", "local"), ("Replication", "3")]);
        store
            .write_file(NodeId(1), "/f", &[0u8; 1000], &tags)
            .unwrap();
        assert!(store.locations("/f").is_empty());
        assert_eq!(store.get_xattr("/f", "location"), None);
        assert!(!store.exposes_location());
    }

    #[test]
    fn replication_copies_chunks() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        store
            .write_file(NodeId(0), "/db", &[9u8; 600_000], &tags)
            .unwrap();
        // Optimistic default: replicas drain in the background; the
        // barrier makes the locality assertion deterministic.
        store.flush_replication();
        assert!(store.locations("/db").len() >= 3);
        assert!(store.fully_replicated("/db").unwrap());
        // Replica holders serve a large share of chunk reads locally
        // (replica targets rotate per chunk, so not necessarily all).
        for holder in store.locations("/db") {
            store.read_file(holder, "/db").unwrap();
        }
        let local = store.local_reads.load(Ordering::Relaxed);
        let remote = store.remote_reads.load(Ordering::Relaxed);
        assert!(
            local > remote,
            "replication should localize most reads: {local} local vs {remote} remote"
        );
    }

    #[test]
    fn optimistic_defers_pessimistic_blocks() {
        let store = LiveStore::woss(5);
        let opt = TagSet::from_pairs([("Replication", "3"), ("RepSmntc", "optimistic")]);
        store
            .write_file(NodeId(0), "/opt", &[1u8; 600_000], &opt)
            .unwrap();
        assert!(
            store.replicas_deferred.load(Ordering::Relaxed) > 0,
            "optimistic replicas go through the background pool"
        );
        // Reads are correct even while replication drains: the primary
        // always has the bytes.
        let back = store.read_file(NodeId(4), "/opt").unwrap();
        assert_eq!(back, vec![1u8; 600_000]);
        store.flush_replication();
        assert!(store.fully_replicated("/opt").unwrap());
        assert_eq!(
            store.background_copies(),
            store.replicas_deferred.load(Ordering::Relaxed),
            "flush means every deferred copy landed"
        );

        // Pessimistic: durable on return, nothing deferred.
        let deferred_before = store.replicas_deferred.load(Ordering::Relaxed);
        let pess = TagSet::from_pairs([("Replication", "3"), ("RepSmntc", "pessimistic")]);
        store
            .write_file(NodeId(0), "/pess", &[2u8; 600_000], &pess)
            .unwrap();
        assert!(store.fully_replicated("/pess").unwrap(), "no flush needed");
        assert_eq!(
            store.replicas_deferred.load(Ordering::Relaxed),
            deferred_before,
            "pessimistic writes defer nothing"
        );
    }

    #[test]
    fn stripe_count_one_reproduces_single_lock_store() {
        let store = LiveStore::woss_tuned(4, 1, 1);
        assert_eq!(store.stripe_count(), 1);
        let tags = TagSet::from_pairs([("DP", "local")]);
        let data: Vec<u8> = (0..400_000u32).map(|i| (i % 199) as u8).collect();
        store.write_file(NodeId(2), "/one", &data, &tags).unwrap();
        assert_eq!(store.locations("/one"), vec![NodeId(2)]);
        assert_eq!(store.read_file(NodeId(1), "/one").unwrap(), data);
    }

    #[test]
    fn delete_cancels_background_replication() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        store
            .write_file(NodeId(0), "/gone", &[3u8; 900_000], &tags)
            .unwrap();
        store.delete("/gone").unwrap();
        store.flush_replication();
        // No node store may hold a chunk of the deleted file: queued
        // jobs were cancelled, in-flight ones waited out before sweep.
        for ns in store.stores.iter() {
            assert!(
                ns.chunks.read().unwrap().is_empty(),
                "deleted file left chunks behind"
            );
        }
    }

    #[test]
    fn racing_delete_never_orphans_chunks() {
        // A delete can land between a create's meta publish and its
        // data copies; whichever side sweeps last must leave no bytes
        // behind. Stress the window a few rounds.
        for round in 0..8 {
            let store = Arc::new(LiveStore::woss(4));
            std::thread::scope(|scope| {
                let writer = Arc::clone(&store);
                scope.spawn(move || {
                    let tags = TagSet::from_pairs([("Replication", "3")]);
                    let _ = writer.write_file(NodeId(0), "/r", &[5u8; 700_000], &tags);
                });
                let deleter = Arc::clone(&store);
                scope.spawn(move || loop {
                    match deleter.delete("/r") {
                        Ok(()) => break,
                        Err(_) => std::thread::yield_now(),
                    }
                });
            });
            store.flush_replication();
            for ns in store.stores.iter() {
                assert!(
                    ns.chunks.read().unwrap().is_empty(),
                    "round {round} leaked chunks"
                );
            }
        }
    }

    #[test]
    fn concurrent_writers_and_readers() {
        let store = Arc::new(LiveStore::woss(8));
        let mut handles = Vec::new();
        for w in 0..8usize {
            let st = Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                let data: Vec<u8> = (0..300_000u32)
                    .map(|i| ((i as usize * (w + 1)) % 256) as u8)
                    .collect();
                let tags = TagSet::from_pairs([("DP", "local")]);
                st.write_file(NodeId(w % 8), &format!("/t{w}"), &data, &tags)
                    .unwrap();
                let back = st.read_file(NodeId((w + 1) % 8), &format!("/t{w}")).unwrap();
                assert_eq!(back, data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.bytes_written.load(Ordering::Relaxed), 8 * 300_000);
    }

    #[test]
    fn failure_injection_replicas_survive() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        let data: Vec<u8> = (0..700_000u32).map(|i| (i % 241) as u8).collect();
        store.write_file(NodeId(0), "/db", &data, &tags).unwrap();
        store.flush_replication();
        let holders = store.locations("/db");
        assert!(holders.len() >= 3);
        // Kill one holder: reads must fail over and return exact bytes.
        store.kill_node(holders[0]);
        let back = store.read_file(NodeId(4), "/db").unwrap();
        assert_eq!(back, data, "replica failover must preserve bytes");
        store.revive_node(holders[0]);
    }

    #[test]
    fn failure_injection_unreplicated_file_lost() {
        let store = LiveStore::woss(3);
        store
            .write_file(
                NodeId(1),
                "/single",
                &[7u8; 400_000],
                &TagSet::from_pairs([("DP", "local")]),
            )
            .unwrap();
        store.kill_node(NodeId(1));
        assert!(
            store.read_file(NodeId(0), "/single").is_err(),
            "an unreplicated file on a dead node is unreadable"
        );
        store.revive_node(NodeId(1));
        assert!(
            store.read_file(NodeId(0), "/single").is_ok(),
            "outage, not loss"
        );
    }

    #[test]
    fn delete_frees_chunks() {
        let store = LiveStore::woss(3);
        store
            .write_file(NodeId(0), "/f", &[1u8; 100_000], &TagSet::new())
            .unwrap();
        store.delete("/f").unwrap();
        assert!(store.read_file(NodeId(0), "/f").is_err());
        assert!(store.delete("/f").is_err());
    }
}
