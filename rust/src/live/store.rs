//! In-process WOSS deployment with real chunk bytes.
//!
//! The same dispatcher [`Registry`] that drives the simulator drives
//! this store: chunk placement, replication fan-out, and the reserved
//! `location` attribute all run the identical decision logic — only
//! here the chunks are actual `Vec<u8>` held in per-node stores and the
//! callers are concurrent worker threads.
//!
//! # Concurrency layout
//!
//! The manager side is **lock-striped**: the namespace splits into
//! [`LiveTuning::stripes`] shards keyed by file-path hash
//! ([`crate::dispatch::shard_for_path`], the same routing the simulated
//! sharded manager uses), so metadata operations on unrelated files
//! never contend. Placement state (node usage + round-robin cursors +
//! collocation anchors) lives behind one short-critical-section lock,
//! with per-stripe cursors and global anchors provided by the existing
//! [`ShardedPlacementState`]. Per-node chunk stores sit behind the
//! [`ChunkBackend`] trait (shared-read-lock memory maps or spill
//! files); concurrent readers of the same node never block each other,
//! and the data-path byte copies run outside every manager lock.
//!
//! Replication honors the paper's `RepSmntc` semantics for real:
//! **pessimistic** writes return only after every replica holds the
//! bytes, while **optimistic** writes (the Table 3 default) return
//! after the primary copy and drain the remaining replicas through a
//! small background worker pool. [`LiveStore::flush_replication`] is
//! the barrier that makes shutdown and tests deterministic; dropping
//! the store drains the queue before joining the workers.
//!
//! Visibility contract: a file is readable with its full byte content
//! as soon as [`LiveStore::write_file`] returns (the primary copy is
//! synchronous); reads racing an in-progress create may transiently
//! fail, exactly as with the previous single-lock store. While
//! optimistic replicas are still draining, reads transparently fall
//! back to a holder that has materialized the chunk.
//!
//! # Lifetime & cache tier
//!
//! On top of the authoritative per-node stores sits an **optional,
//! capacity-bounded hot-chunk cache** ([`LiveTuning::cache_bytes`],
//! budget per node, disabled by default so the default store behaves
//! exactly like the uncached one). Remote chunk reads populate the
//! reader's cache; [`LiveStore::prefetch`] promotes a file's chunks
//! into a consumer node's cache off-thread through the replication
//! worker pool (the `Pattern=pipeline` optimization). Eviction is
//! hint-aware ([`CachePolicy::HintAware`]): `Lifetime=scratch` entries
//! evict first, durable entries next, and `Pattern=broadcast` entries
//! stay pinned until the declared fan-out completes; a plain
//! [`CachePolicy::Lru`] baseline ignores the hints.
//!
//! With [`LiveTuning::lifetime`] enabled the store also *enforces*
//! lifetimes: a file tagged `Lifetime=scratch;Consumers=<n>` is
//! reclaimed automatically — namespace entry, capacity, chunks, cache
//! entries, queued background copies — after its `n`-th whole-file
//! read. The remaining count is exposed bottom-up through the
//! reserved `consumers_left` attribute and cache residency through
//! `cache_state`, so a runtime can verify the protocol. Reads beyond
//! the declared consumer count see `NotFound` — the count is a
//! contract, not a guess.
//!
//! # Chunk backends
//!
//! The authoritative per-node chunk stores sit behind the
//! [`ChunkBackend`] trait ([`LiveTuning::backend`]):
//! [`crate::live::MemoryBackend`] reproduces the previous in-memory
//! `HashMap` store exactly, while [`crate::live::FileBackend`] spills
//! every chunk to one file under a per-node `--data-dir` directory
//! (temp-file + rename, so a chunk is never observable half-written).
//! Under the disk backend the cache tier becomes a true
//! memory-over-disk hot tier: a cache hit never touches the disk, and
//! `Lifetime=scratch` chunks (with lifetime enforcement on) skip the
//! spill entirely — they live **cache-only** as *dirty* entries until
//! reclaimed, and are written back to the backend only if eviction
//! pressure forces them out first, so correctness never depends on the
//! hint being truthful. The reserved `cache_state` attribute reports
//! the backend in its `tier=` field.
//!
//! # Restart & recovery
//!
//! A disk-backed store is **re-openable**: `Lifetime=durable` is a
//! promise the store keeps across process death, not just across
//! reads. Three durable artifacts live under the data dir:
//!
//! * per-node chunk **manifests** (`node<i>/manifest.log`, see
//!   [`crate::live::backend`]) — chunk key → length → checksum,
//!   fsynced on every publish;
//! * a store-level **namespace journal** (`namespace.log`) — one
//!   `create` record per file (id, path, tags, block map), appended
//!   under the namespace stripe lock and fsynced before `write_file`
//!   returns, plus `del` records from delete/reclaim sweeps;
//! * per-stripe namespace **snapshots** (`ns-stripe<k>.snap` + the
//!   `CLEAN` marker), written by [`LiveStore::shutdown`] — the clean
//!   path that also captures post-create tag mutations (consumer
//!   countdowns, later `set_xattr`s) the journal does not replay.
//!
//! [`LiveStore::reopen`] brings a data dir back: snapshots when the
//! previous instance shut down cleanly, journal replay + manifest
//! verification otherwise (the crash path). Either way every candidate
//! file is checked bottom-up — a chunk counts only where its manifest
//! record and on-disk bytes agree — holders that lost their copy are
//! pruned, files with an unrecoverable chunk are dropped, scratch
//! files never resurrect, and chunks no surviving file claims are
//! unlinked. What survived is reported through
//! [`LiveStore::recovery_report`] and the reserved `recovered=` field
//! on `cache_state` (per file) and `system_status` (count), so a
//! scheduler can see which files outlived the crash.

use super::backend::{
    auto_data_dir, lockscope, AppendLog, BackendKind, ChunkBackend, ChunkKey, DirGuard,
    FileBackend, MemoryBackend, NodeRecovery, SegBackend,
};
use super::fault::{FaultBackend, FaultControl, FaultSpec};
use crate::dispatch::placement::place_cost_based;
use crate::dispatch::{shard_for_path, PlacementCtx, Registry, ShardedPlacementState};
use crate::hints::{AccessPattern, Lifetime, TagSet};
use crate::storage::types::{ChunkMeta, FileId, FileMeta, NodeId, NodeState, StorageError};
use crate::util::Summary;
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Default chunk size for the live store (256 KiB = one kernel tile).
pub const LIVE_CHUNK: u64 = 256 * 1024;

/// Store-level metadata file under the data dir (node count, capacity)
/// — what [`LiveStore::reopen`] needs before it can rebuild anything.
const STORE_META: &str = "store.meta";

/// Store-level append-only namespace journal under the data dir.
const NAMESPACE_LOG: &str = "namespace.log";

/// Marker written by a clean [`LiveStore::shutdown`]; its presence
/// tells [`LiveStore::reopen`] the per-stripe snapshots are
/// trustworthy. Removed the moment the namespace mutates again.
const CLEAN_MARKER: &str = "CLEAN";

/// What [`LiveStore::reopen`] rebuilt — and what the crash cost.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// `true` when the namespace came from a clean-shutdown snapshot;
    /// `false` when it was salvaged from the journal + chunk manifests.
    pub clean: bool,
    /// Files fully recovered (every chunk verified on ≥ 1 holder).
    pub files_recovered: usize,
    /// Durable files dropped because at least one chunk survived on no
    /// holder (torn mid-crash).
    pub files_dropped: usize,
    /// `Lifetime=scratch` files discarded on principle: a scratch file
    /// must never resurrect across a restart.
    pub scratch_discarded: usize,
    /// Logical bytes across the recovered files.
    pub bytes_recovered: u64,
    /// Backend chunks that replayed and verified clean.
    pub chunks_recovered: usize,
    /// Backend chunks discarded: torn manifest records, corrupt or
    /// orphaned chunk files, and chunks no surviving file claims.
    pub chunks_dropped: usize,
}

/// Backslash-escape the namespace-record delimiters (tab, newline) so
/// arbitrary paths and tag values survive the line format.
fn ns_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\t' => out.push_str("\\t"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn ns_unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('t') => out.push('\t'),
            Some('n') => out.push('\n'),
            Some(other) => out.push(other),
            None => break,
        }
    }
    out
}

/// Render one namespace `create` record: the full [`FileMeta`] a
/// recovery needs to serve the file again (journal and snapshot share
/// the format).
fn encode_create(path: &str, meta: &FileMeta) -> String {
    let chunks = if meta.chunks.is_empty() {
        "-".to_string()
    } else {
        meta.chunks
            .iter()
            .map(|c| {
                c.replicas
                    .iter()
                    .map(|n| n.0.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            })
            .collect::<Vec<_>>()
            .join(";")
    };
    format!(
        "create\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
        meta.id.0,
        meta.size,
        meta.chunk_size,
        meta.creator.0,
        ns_escape(path),
        ns_escape(&meta.tags.to_string()),
        chunks
    )
}

/// Parse a `create` record back into `(path, FileMeta)`; `None` for
/// anything garbled (a torn journal tail ends the replay).
fn decode_create(line: &str) -> Option<(String, FileMeta)> {
    let fields: Vec<&str> = line.split('\t').collect();
    if fields.len() != 8 || fields[0] != "create" {
        return None;
    }
    let id = FileId(fields[1].parse().ok()?);
    let size: u64 = fields[2].parse().ok()?;
    let chunk_size: u64 = fields[3].parse().ok()?;
    if chunk_size == 0 {
        return None; // corrupt: would divide the chunk math by zero
    }
    let creator = NodeId(fields[4].parse().ok()?);
    let path = ns_unescape(fields[5]);
    let tags: TagSet = ns_unescape(fields[6]).parse().ok()?;
    let chunks = if fields[7] == "-" {
        Vec::new()
    } else {
        let mut out = Vec::new();
        for part in fields[7].split(';') {
            let mut replicas = Vec::new();
            for n in part.split(',') {
                replicas.push(NodeId(n.parse().ok()?));
            }
            if replicas.is_empty() {
                return None;
            }
            out.push(ChunkMeta { replicas });
        }
        out
    };
    if FileMeta::chunk_count(size, chunk_size) != chunks.len() as u64 {
        return None;
    }
    Some((
        path,
        FileMeta {
            id,
            size,
            chunk_size,
            tags,
            chunks,
            creator,
        },
    ))
}

/// Write `contents` durably at `path` via temp file + fsync + rename,
/// then fsync the parent directory so the rename itself survives power
/// loss — without it, later renames (e.g. the `CLEAN` marker) could
/// become durable while earlier ones (the snapshots it vouches for)
/// did not.
fn write_durable(path: &Path, contents: &str) -> Result<(), StorageError> {
    let tmp = path.with_extension("tmp");
    let io = std::fs::File::create(&tmp)
        .and_then(|mut f| f.write_all(contents.as_bytes()).and_then(|()| f.sync_all()))
        .and_then(|()| std::fs::rename(&tmp, path))
        .and_then(|()| {
            match path.parent() {
                Some(dir) => std::fs::File::open(dir).and_then(|d| d.sync_all()),
                None => Ok(()),
            }
        });
    io.map_err(|e| StorageError::Invalid(format!("write {}: {e}", path.display())))
}

/// Remove a file and fsync its parent directory, so the unlink itself
/// survives power loss. Removing the `CLEAN` marker with a bare
/// `remove_file` would leave the unlink in the page cache: a crash
/// could resurrect the marker and let stale snapshots shadow journal
/// records that *were* fsynced after it was "removed".
fn remove_durable(path: &Path) {
    let _ = std::fs::remove_file(path);
    if let Some(dir) = path.parent() {
        let _ = std::fs::File::open(dir).and_then(|d| d.sync_all());
    }
}


/// Eviction policy for the hot-chunk cache tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CachePolicy {
    /// Plain least-recently-used: every entry is equal. The baseline a
    /// hint-blind cache would implement — pinned entries are evicted
    /// like any other.
    Lru,
    /// Hint-aware eviction: `Lifetime=scratch` entries evict first
    /// (LRU among themselves), durable entries next, and pinned
    /// broadcast entries never — under pressure the cache declines to
    /// admit a new chunk rather than break a pin.
    #[default]
    HintAware,
}

/// Concurrency tuning for a [`LiveStore`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LiveTuning {
    /// Namespace lock stripes. `1` reproduces the previous single-lock
    /// manager behaviour; values are clamped to ≥ 1.
    pub stripes: usize,
    /// Background replication worker threads (optimistic `RepSmntc`);
    /// clamped to ≥ 1.
    pub repl_workers: usize,
    /// Per-node hot-chunk cache budget in bytes. `None` (the default)
    /// disables the cache tier entirely — the store behaves exactly
    /// like the uncached concurrent store.
    pub cache_bytes: Option<u64>,
    /// Eviction policy for the cache tier (ignored while the tier is
    /// disabled).
    pub cache_policy: CachePolicy,
    /// Enforce `Lifetime=scratch;Consumers=<n>` reclamation and
    /// broadcast cache pinning. Off by default: lifetime tags are
    /// carried but inert, exactly as before this tier existed.
    pub lifetime: bool,
    /// Which chunk backend the per-node stores run on. The default is
    /// resolved from the `LIVE_BACKEND` environment variable
    /// ([`BackendKind::from_env`], `mem` when unset) so the CI matrix
    /// can re-run every live test against the disk spill tier; an
    /// explicit value always wins.
    pub backend: BackendKind,
    /// Root directory for the persistent backends (`disk` | `seg`;
    /// one `node<i>/` subdirectory per storage node). `None` lets the
    /// store create — and remove on drop — a process-unique directory
    /// under `WOSS_DATA_DIR` (or the system temp dir); a user-supplied
    /// directory is never deleted. Ignored by the memory backend.
    pub data_dir: Option<PathBuf>,
    /// Deterministic fault injection: when set, every node's chunk
    /// backend is wrapped in a [`FaultBackend`] drawing its schedule
    /// from this spec (seed mixed per node). `None` — the default —
    /// adds no decorator at all. The store's [`LiveStore::fault_control`]
    /// exposes the shared switch/counters.
    pub fault: Option<FaultSpec>,
    /// Worker threads for the bounded I/O submission/completion pool
    /// that background disk work drains through — dirty-entry spills,
    /// optimistic replica copies, prefetch promotes, and churn
    /// restores. `1` (the default) runs every submission inline on the
    /// submitting thread, reproducing the pre-pool serial behavior
    /// exactly; `>= 2` spawns that many workers so independent disk
    /// operations overlap. Clamped to ≥ 1.
    pub io_workers: usize,
    /// Adaptive load-aware placement & read scheduling: consume the
    /// per-node load-feedback plane ([`NodeLoad`]) in every placement,
    /// read-source, and churn-repair decision, and widen/trim replicas
    /// of read-hot files automatically. Off (the default) keeps every
    /// decision byte-identical to the static store — the signals are
    /// still *collected* (cheap atomics), only the decisions change.
    pub adaptive: bool,
    /// Deadline in milliseconds for the [`LiveStore::flush_replication`]
    /// barrier (and the I/O-pool drain inside it). `None` — the default
    /// — waits forever, exactly as before; with a deadline a wedged
    /// worker or dead peer can no longer hang a client: the barrier
    /// returns at the deadline and the miss is counted in
    /// [`LiveStore::flush_timeouts`].
    pub flush_timeout_ms: Option<u64>,
}

impl Default for LiveTuning {
    fn default() -> Self {
        LiveTuning {
            stripes: 8,
            repl_workers: 2,
            cache_bytes: None,
            cache_policy: CachePolicy::default(),
            lifetime: false,
            backend: BackendKind::from_env(),
            data_dir: None,
            fault: None,
            io_workers: 1,
            adaptive: false,
            flush_timeout_ms: None,
        }
    }
}

/// Eviction class of a cached chunk, derived from its file's tags at
/// insert time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CacheClass {
    /// `Lifetime=scratch`: first out under pressure.
    Scratch,
    /// Untagged / durable: plain LRU among themselves.
    Durable,
    /// `Pattern=broadcast` with consumers outstanding: never evicted
    /// (hint-aware policy) until the fan-out completes.
    Pinned,
}

/// Life-cycle state of a cached chunk — the write-back pipeline.
///
/// ```text
///   insert ──────────────► Clean ──────────────────► (evicted)
///   insert_dirty ────────► Dirty ──mark victim─────► Spilling
///   Spilling ──write-back landed──────────────────► (evicted)
///   Spilling ──write-back failed──────────────────► Dirty
///   Spilling ──entry purged mid-flight────────────► (gone; the
///                 spiller deletes the stray backend copy itself)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EntryState {
    /// The backend also holds these bytes: eviction is free.
    Clean,
    /// Cache-only chunk: the backend does not hold these bytes (the
    /// `Lifetime=scratch` spill-skip). Evicting a dirty entry writes it
    /// back to the node's backend first — the bytes here are the only
    /// copy this node owns.
    Dirty,
    /// A dirty victim whose write-back is in flight on the I/O pool.
    /// The entry stays resident (and readable) but is no longer an
    /// eviction candidate; the spilling thread completes or aborts the
    /// transition when the write-back returns. Cache hits and
    /// evictions of *other* entries proceed while a spill is in
    /// flight — the node's mutex is not held across the disk write.
    Spilling,
}

/// One cached chunk.
struct CacheEntry {
    /// Shared so a spill (or a read) can snapshot the payload and
    /// release the node's mutex before touching the disk or copying.
    bytes: Arc<Vec<u8>>,
    class: CacheClass,
    last_used: u64,
    state: EntryState,
}

/// One node's cache: entries + resident accounting + an LRU clock.
#[derive(Default)]
struct NodeCache {
    entries: HashMap<(FileId, u64), CacheEntry>,
    resident: u64,
    tick: u64,
}

/// Observable cache-tier counters (see [`LiveStore::cache_stats`]).
/// All zeros while the tier is disabled.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    /// Bytes currently resident per node cache.
    pub resident: Vec<u64>,
    /// Highest bytes ever resident in any single node's cache — must
    /// never exceed the configured per-node budget.
    pub peak_node_resident: u64,
    /// Chunk reads served from a cache.
    pub hits: u64,
    /// Chunks admitted into a cache.
    pub insertions: u64,
    /// Chunks evicted under pressure.
    pub evictions: u64,
    /// Chunks promoted by the off-thread prefetch path.
    pub prefetched: u64,
    /// Dirty (cache-only) chunks written back to the node's backend on
    /// eviction — the spill the `Lifetime=scratch` hint deferred until
    /// pressure forced it.
    pub spilled: u64,
    /// Entries currently pinned (broadcast fan-out outstanding).
    pub pinned_entries: u64,
    /// Scratch files auto-reclaimed after their last declared read.
    pub files_reclaimed: u64,
    /// Logical bytes freed by auto-reclamation.
    pub bytes_reclaimed: u64,
    /// Chunk reads that failed on a *present* chunk (I/O error or
    /// checksum mismatch), summed over node backends. Before this
    /// counter a damaged disk chunk looked exactly like an absent one
    /// — the read silently failed over and the fault dissolved into
    /// remote-traffic noise. Always 0 on the memory backend.
    pub read_errors: u64,
    /// Median per-chunk foreground put latency, µs — the time to land
    /// a chunk's primary copy in [`LiveStore::write_file`]. 0.0 before
    /// the first write.
    pub put_p50_us: f64,
    /// 95th-percentile per-chunk foreground put latency, µs.
    pub put_p95_us: f64,
    /// 99th-percentile per-chunk foreground put latency, µs.
    pub put_p99_us: f64,
    /// Median per-chunk foreground read latency, µs — the time to
    /// serve one chunk in [`LiveStore::read_file`], cache hits
    /// included (that is the point: hits should pull this down).
    pub get_p50_us: f64,
    /// 95th-percentile per-chunk foreground read latency, µs.
    pub get_p95_us: f64,
    /// 99th-percentile per-chunk foreground read latency, µs.
    pub get_p99_us: f64,
    /// Median dirty write-back (spill) latency, µs — submission to
    /// completion through the I/O pool. 0.0 while nothing spilled.
    pub spill_p50_us: f64,
    /// 95th-percentile spill latency, µs.
    pub spill_p95_us: f64,
    /// 99th-percentile spill latency, µs.
    pub spill_p99_us: f64,
}

/// The per-node, capacity-bounded hot-chunk cache tier.
///
/// Caches sit beside the authoritative stores: they hold copies of
/// chunks a node does not own, so a consumer's repeat reads stay
/// node-local. Inserts are best-effort — when the budget cannot be met
/// without evicting a pinned entry (hint-aware policy), the chunk is
/// simply not cached. Cache bytes are bounded by the budget and do not
/// count against node storage capacity.
struct CacheTier {
    nodes: Vec<Mutex<NodeCache>>,
    /// Per-node budget, bytes.
    budget: u64,
    policy: CachePolicy,
    /// Write-back target for dirty (cache-only) entries: the same
    /// per-node backends the store owns. `None` only in unit tests —
    /// a tier without a spill target declines dirty inserts.
    spill: Option<Arc<Vec<Box<dyn ChunkBackend>>>>,
    /// The pool dirty write-backs drain through (shared with the
    /// store and its replication workers).
    io: Arc<IoPool>,
    /// Per-node load signals shared with the store — spill latency is
    /// one of the EWMAs the adaptive placement plane reads, and the
    /// cache is the only layer that sees it.
    loads: Arc<Vec<NodeLoad>>,
    /// Spill latencies, µs (submission to completion).
    spill_samples: Mutex<Reservoir>,
    hits: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    prefetched: AtomicU64,
    spills: AtomicU64,
    peak_node_resident: AtomicU64,
}

/// A locked node cache plus the [`lockscope`] token that lets the
/// debug-only guard catch backend I/O issued while the lock is held.
/// Field order matters: the mutex guard drops before the token.
struct CacheGuard<'a> {
    cache: std::sync::MutexGuard<'a, NodeCache>,
    _token: lockscope::Token,
}

impl std::ops::Deref for CacheGuard<'_> {
    type Target = NodeCache;
    fn deref(&self) -> &NodeCache {
        &self.cache
    }
}

impl std::ops::DerefMut for CacheGuard<'_> {
    fn deref_mut(&mut self) -> &mut NodeCache {
        &mut self.cache
    }
}

impl CacheTier {
    fn new(
        n_nodes: usize,
        budget: u64,
        policy: CachePolicy,
        spill: Option<Arc<Vec<Box<dyn ChunkBackend>>>>,
        io: Arc<IoPool>,
        loads: Arc<Vec<NodeLoad>>,
    ) -> Self {
        CacheTier {
            nodes: (0..n_nodes).map(|_| Mutex::new(NodeCache::default())).collect(),
            budget,
            policy,
            spill,
            io,
            loads,
            spill_samples: Mutex::new(Reservoir::default()),
            hits: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            peak_node_resident: AtomicU64::new(0),
        }
    }

    /// Lock `node`'s cache, registering the hold with the debug
    /// lock-scope guard — every acquisition in this tier goes through
    /// here so no code path can reach backend I/O with the mutex held
    /// without tripping [`lockscope::assert_unlocked`].
    fn lock_node(&self, node: NodeId) -> CacheGuard<'_> {
        let token = lockscope::token();
        CacheGuard {
            cache: self.nodes[node.0].lock().unwrap(),
            _token: token,
        }
    }

    /// Look up a chunk in `node`'s cache, refreshing its recency.
    fn get(&self, node: NodeId, key: (FileId, u64)) -> Option<Vec<u8>> {
        let bytes = {
            let mut c = self.lock_node(node);
            c.tick += 1;
            let tick = c.tick;
            let entry = c.entries.get_mut(&key)?;
            entry.last_used = tick;
            Arc::clone(&entry.bytes)
        };
        self.hits.fetch_add(1, Ordering::Relaxed);
        // Materialize the caller's copy outside the node mutex: a
        // large-chunk memcpy under the lock would stall every other
        // hit on this node for the duration.
        Some(bytes.as_ref().clone())
    }

    /// Is the chunk resident in `node`'s cache? (No recency touch.)
    fn contains(&self, node: NodeId, key: (FileId, u64)) -> bool {
        self.lock_node(node).entries.contains_key(&key)
    }

    /// Is the chunk a *dirty* (cache-only) resident of `node`'s cache?
    /// Dirty bytes are the node's only copy — the backend presence
    /// checks ([`LiveStore::fully_replicated`]) count them. A
    /// [`EntryState::Spilling`] entry still counts: its write-back has
    /// not landed yet, so the cache copy is still the only one.
    fn contains_dirty(&self, node: NodeId, key: (FileId, u64)) -> bool {
        self.lock_node(node)
            .entries
            .get(&key)
            .is_some_and(|e| matches!(e.state, EntryState::Dirty | EntryState::Spilling))
    }

    /// Read a chunk from `node`'s cache without touching recency or the
    /// hit counter — the background promote path and remote fallbacks
    /// use this so diagnostics only count foreground reads.
    fn peek(&self, node: NodeId, key: (FileId, u64)) -> Option<Vec<u8>> {
        let bytes = {
            let c = self.lock_node(node);
            c.entries.get(&key).map(|e| Arc::clone(&e.bytes))
        }?;
        Some(bytes.as_ref().clone())
    }

    /// Best-effort clean insert into `node`'s cache (the bytes also
    /// exist in some backend). Returns `false` when the chunk cannot be
    /// admitted within the budget (larger than the whole budget, or —
    /// hint-aware policy — only pinned entries could make room).
    fn insert(&self, node: NodeId, key: (FileId, u64), bytes: Vec<u8>, class: CacheClass) -> bool {
        self.insert_entry(node, key, bytes, class, false)
    }

    /// Insert a *dirty* (cache-only) chunk: the backend holds no copy,
    /// so a later eviction must write the bytes back first. Returns
    /// `false` when the entry cannot be admitted — the caller then
    /// spills synchronously instead.
    fn insert_dirty(
        &self,
        node: NodeId,
        key: (FileId, u64),
        bytes: Vec<u8>,
        class: CacheClass,
    ) -> bool {
        self.insert_entry(node, key, bytes, class, true)
    }

    /// Write a dirty victim back to `node`'s backend through the I/O
    /// pool. `false` when no spill target is wired or the backend
    /// write failed — the victim must then stay resident. Called with
    /// **no cache lock held**: the victim sits in
    /// [`EntryState::Spilling`] while this runs.
    fn spill_back(&self, node: NodeId, key: (FileId, u64), bytes: Arc<Vec<u8>>) -> bool {
        let Some(stores) = &self.spill else {
            return false;
        };
        let stores = Arc::clone(stores);
        let started = std::time::Instant::now();
        let ok = {
            let _slot = self.loads[node.0].begin();
            self.io.run(move || stores[node.0].put(key, &bytes).is_ok())
        };
        let us = started.elapsed().as_secs_f64() * 1e6;
        self.loads[node.0].observe_spill(us);
        self.spill_samples.lock().unwrap().record(us);
        if ok {
            self.spills.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    fn insert_entry(
        &self,
        node: NodeId,
        key: (FileId, u64),
        bytes: Vec<u8>,
        class: CacheClass,
        dirty: bool,
    ) -> bool {
        let need = bytes.len() as u64;
        if need > self.budget {
            return false;
        }
        let bytes = Arc::new(bytes);
        let mut c = self.lock_node(node);
        // The loop re-enters after every lock reacquisition (a dirty
        // victim's write-back drops the mutex): budget, residency, and
        // the key itself are re-checked from scratch each round.
        loop {
            c.tick += 1;
            let tick = c.tick;
            if let Some(entry) = c.entries.get_mut(&key) {
                // Same key ⇒ same bytes (a chunk's content is immutable
                // for a given FileId): refresh class and recency in
                // place. The dirty state is sticky — downgrading it
                // here would tell a later eviction the backend holds
                // bytes it does not. (A `Spilling` entry stays
                // spilling: its in-flight write-back finishes the
                // transition.)
                entry.class = class;
                entry.last_used = tick;
                if dirty && entry.state == EntryState::Clean {
                    entry.state = EntryState::Dirty;
                }
                return true;
            }
            if c.resident + need <= self.budget {
                c.resident += need;
                c.entries.insert(
                    key,
                    CacheEntry {
                        bytes,
                        class,
                        last_used: tick,
                        state: if dirty {
                            EntryState::Dirty
                        } else {
                            EntryState::Clean
                        },
                    },
                );
                let resident = c.resident;
                drop(c);
                self.insertions.fetch_add(1, Ordering::Relaxed);
                self.peak_node_resident.fetch_max(resident, Ordering::Relaxed);
                return true;
            }
            // Pick a victim. `Spilling` entries are never candidates:
            // their transition belongs to the thread that started it.
            let victim = match self.policy {
                CachePolicy::Lru => c
                    .entries
                    .iter()
                    .filter(|(_, e)| e.state != EntryState::Spilling)
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k),
                CachePolicy::HintAware => {
                    let oldest_of = |want: CacheClass| {
                        c.entries
                            .iter()
                            .filter(|(_, e)| e.class == want && e.state != EntryState::Spilling)
                            .min_by_key(|(_, e)| e.last_used)
                            .map(|(k, _)| *k)
                    };
                    oldest_of(CacheClass::Scratch).or_else(|| oldest_of(CacheClass::Durable))
                }
            };
            // Only pinned (or mid-spill) entries left: decline to cache.
            let Some(k) = victim else { return false };
            let victim_state = c.entries.get(&k).expect("victim resident").state;
            if victim_state == EntryState::Clean {
                // The backend already holds these bytes: eviction is
                // free and the mutex never drops.
                let evicted = c.entries.remove(&k).expect("victim resident");
                c.resident -= evicted.bytes.len() as u64;
                self.evictions.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // Dirty victim: mark it `Spilling`, drop the mutex, write
            // the bytes back outside every lock, then re-lock and
            // finish (or abort) the transition. Hits and evictions of
            // other entries proceed while the disk write is in flight.
            let payload = {
                let e = c.entries.get_mut(&k).expect("victim resident");
                e.state = EntryState::Spilling;
                Arc::clone(&e.bytes)
            };
            drop(c);
            let ok = self.spill_back(node, k, payload);
            c = self.lock_node(node);
            match c.entries.get(&k).map(|e| e.state) {
                Some(EntryState::Spilling) if ok => {
                    // Write-back landed and the entry is still ours:
                    // complete the eviction.
                    let evicted = c.entries.remove(&k).expect("still resident");
                    c.resident -= evicted.bytes.len() as u64;
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                Some(EntryState::Spilling) => {
                    // The victim's bytes exist nowhere else and we
                    // could not write them back: revert to `Dirty`
                    // (keeping it resident) and decline the newcomer
                    // instead of losing data.
                    if let Some(e) = c.entries.get_mut(&k) {
                        e.state = EntryState::Dirty;
                    }
                    return false;
                }
                None if ok => {
                    // The entry was purged mid-spill (its file died),
                    // but our write-back landed a backend copy the
                    // sweep never saw. Undo it ourselves — outside the
                    // lock, like all backend I/O.
                    drop(c);
                    if let Some(stores) = &self.spill {
                        stores[node.0].delete(k);
                    }
                    c = self.lock_node(node);
                }
                // Purged with nothing written (residency already
                // released), or re-inserted by a racing thread (never
                // evict a just-admitted entry) — loop and re-evaluate.
                _ => {}
            }
        }
    }

    /// Drop every cached chunk of `file` on every node (delete /
    /// reclaim sweep). Entries mid-spill are removed like any other:
    /// the spilling thread detects the removal when its write-back
    /// returns and deletes the stray backend copy itself (see
    /// [`Self::insert_entry`]).
    fn purge_file(&self, file: FileId) {
        for node in 0..self.nodes.len() {
            let mut c = self.lock_node(NodeId(node));
            let keys: Vec<(FileId, u64)> =
                c.entries.keys().filter(|k| k.0 == file).copied().collect();
            for k in keys {
                let e = c.entries.remove(&k).expect("key just listed");
                c.resident -= e.bytes.len() as u64;
            }
        }
    }

    /// Demote `file`'s pinned entries to durable: its broadcast
    /// fan-out completed, ordinary LRU applies from here on.
    fn unpin_file(&self, file: FileId) {
        for node in 0..self.nodes.len() {
            let mut c = self.lock_node(NodeId(node));
            for (k, e) in c.entries.iter_mut() {
                if k.0 == file && e.class == CacheClass::Pinned {
                    e.class = CacheClass::Durable;
                }
            }
        }
    }

    /// Residency of `file` across all node caches:
    /// `(chunk copies, bytes, pinned copies)`.
    fn file_state(&self, file: FileId) -> (u64, u64, u64) {
        let (mut chunks, mut bytes, mut pinned) = (0u64, 0u64, 0u64);
        for node in 0..self.nodes.len() {
            let c = self.lock_node(NodeId(node));
            for (k, e) in c.entries.iter() {
                if k.0 == file {
                    chunks += 1;
                    bytes += e.bytes.len() as u64;
                    if e.class == CacheClass::Pinned {
                        pinned += 1;
                    }
                }
            }
        }
        (chunks, bytes, pinned)
    }

    /// Fill the tier's counters into `stats`.
    fn fill_stats(&self, stats: &mut CacheStats) {
        for node in 0..self.nodes.len() {
            let c = self.lock_node(NodeId(node));
            stats.resident.push(c.resident);
            stats.pinned_entries += c
                .entries
                .values()
                .filter(|e| e.class == CacheClass::Pinned)
                .count() as u64;
        }
        stats.peak_node_resident = self.peak_node_resident.load(Ordering::Relaxed);
        stats.hits = self.hits.load(Ordering::Relaxed);
        stats.insertions = self.insertions.load(Ordering::Relaxed);
        stats.evictions = self.evictions.load(Ordering::Relaxed);
        stats.prefetched = self.prefetched.load(Ordering::Relaxed);
        stats.spilled = self.spills.load(Ordering::Relaxed);
        let (p50, p95, p99) = latency_percentiles(&self.spill_samples);
        stats.spill_p50_us = p50;
        stats.spill_p95_us = p95;
        stats.spill_p99_us = p99;
    }
}

/// Retained-sample cap for the latency reservoirs. 4096 doubles give a
/// stable p99 estimate while bounding each buffer at 32 KiB — a
/// week-long run holds the same memory as a one-minute one.
const LATENCY_RESERVOIR: usize = 4096;

/// Fixed-capacity latency sample buffer: reservoir sampling (Algorithm
/// R) over the stream of observed latencies. The first
/// [`LATENCY_RESERVOIR`] samples are kept outright; after that each
/// newcomer replaces a uniformly random retained slot with probability
/// `cap/seen`, so every sample in the stream is retained with equal
/// probability and the percentiles stay unbiased while memory stays
/// flat. Replacement slots come from a deterministic xorshift64* —
/// equal operation sequences reproduce equal reports.
struct Reservoir {
    samples: Vec<f64>,
    /// Samples offered so far (not just kept).
    seen: u64,
    /// xorshift64* state; seeded non-zero (all-zero is its fixed point).
    rng: u64,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            rng: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

impl Reservoir {
    fn next_rng(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Offer one sample to the reservoir.
    fn record(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(v);
            return;
        }
        let j = (self.next_rng() % self.seen) as usize;
        if j < LATENCY_RESERVOIR {
            self.samples[j] = v;
        }
    }

    /// Drop every retained sample and restart the sampler — the
    /// per-row reset the experiment sweeps use so one configuration's
    /// latencies never bleed into the next row's percentiles. Resets
    /// the RNG too: each row's replacement schedule is then a pure
    /// function of its own operation count.
    fn reset(&mut self) {
        *self = Reservoir::default();
    }

    fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// p50/p95/p99 over a latency sample reservoir (µs); zeros when empty.
fn latency_percentiles(samples: &Mutex<Reservoir>) -> (f64, f64, f64) {
    let s = samples.lock().unwrap();
    if s.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let sum = Summary::from_iter(s.samples.iter().copied());
    (
        sum.percentile(50.0),
        sum.percentile(95.0),
        sum.percentile(99.0),
    )
}

/// EWMA smoothing factor for the per-node latency signals: each new
/// sample moves the average 20% of the way — slow enough to ride out
/// one injected delay spike, fast enough that a node mid-compaction
/// looks expensive within a handful of operations.
const LOAD_EWMA_ALPHA: f64 = 0.2;

/// Lock-free per-node load signals — the upward half of the paper's
/// bidirectional channel, collected continuously on the data path and
/// consumed by adaptive placement ([`LiveTuning::adaptive`]), the
/// read-source scheduler, and the `load=` field of `system_status`.
/// All atomics, no locks: the f64 EWMAs are stored as bit patterns in
/// `AtomicU64` (bit pattern 0 ⇒ no samples yet) and updated with a CAS
/// loop; a lost race under contention skews one sample's weight, never
/// the invariant.
#[derive(Default)]
pub struct NodeLoad {
    /// EWMA foreground primary-put latency, µs (f64 bits).
    put_ewma_us: AtomicU64,
    /// EWMA foreground chunk-serve latency, µs (f64 bits).
    get_ewma_us: AtomicU64,
    /// EWMA dirty-spill write-back latency, µs (f64 bits).
    spill_ewma_us: AtomicU64,
    /// Store-level mutations in flight against this node right now:
    /// foreground puts, cache spills, background copy/restore puts.
    /// Complements [`ChunkBackend::io_depth`], which counts mutations
    /// already *inside* the backend.
    inflight: AtomicU64,
    /// Chunk serves from this node satisfied by its cache.
    hits: AtomicU64,
    /// Chunk serves from this node that had to touch its backend.
    misses: AtomicU64,
}

/// RAII in-flight marker on a [`NodeLoad`]: increments on
/// [`NodeLoad::begin`], decrements on drop — panic- and
/// early-return-safe, so the depth gauge can never leak.
struct LoadSlot<'a> {
    load: &'a NodeLoad,
}

impl Drop for LoadSlot<'_> {
    fn drop(&mut self) {
        self.load.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

impl NodeLoad {
    fn ewma_observe(cell: &AtomicU64, sample: f64) {
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            // Bit pattern 0 doubles as "no samples yet": the first
            // observation seeds the average instead of decaying from
            // zero. (A sub-resolution 0.0 µs sample re-seeds — harmless.)
            let next = if cur == 0 {
                sample
            } else {
                let prev = f64::from_bits(cur);
                prev + LOAD_EWMA_ALPHA * (sample - prev)
            };
            match cell.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    fn observe_put(&self, us: f64) {
        Self::ewma_observe(&self.put_ewma_us, us);
    }

    fn observe_get(&self, us: f64) {
        Self::ewma_observe(&self.get_ewma_us, us);
    }

    fn observe_spill(&self, us: f64) {
        Self::ewma_observe(&self.spill_ewma_us, us);
    }

    fn begin(&self) -> LoadSlot<'_> {
        self.inflight.fetch_add(1, Ordering::Relaxed);
        LoadSlot { load: self }
    }

    fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Smoothed foreground put latency, µs (0.0 before any sample).
    pub fn put_ewma_us(&self) -> f64 {
        f64::from_bits(self.put_ewma_us.load(Ordering::Relaxed))
    }

    /// Smoothed foreground chunk-serve latency, µs.
    pub fn get_ewma_us(&self) -> f64 {
        f64::from_bits(self.get_ewma_us.load(Ordering::Relaxed))
    }

    /// Smoothed dirty-spill write-back latency, µs.
    pub fn spill_ewma_us(&self) -> f64 {
        f64::from_bits(self.spill_ewma_us.load(Ordering::Relaxed))
    }

    /// Store-level operations in flight against this node right now.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Fraction of this node's chunk serves satisfied by its cache
    /// (0.0 before any serve — a node nobody reads claims no cheapness).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed);
        let m = self.misses.load(Ordering::Relaxed);
        if h + m == 0 {
            return 0.0;
        }
        h as f64 / (h + m) as f64
    }
}

/// Write-cost score of placing `bytes`-agnostic work on node `n`
/// (lower = cheaper): capacity pressure × smoothed write latency ×
/// queue depth, the cost formula adaptive placement minimizes. A
/// zero-capacity (failed) node is infinitely expensive. `io_depth` is
/// the backend's own in-flight mutation count
/// ([`ChunkBackend::io_depth`]), added to the store-level depth so a
/// node mid-spill or mid-compaction prices itself out.
fn write_cost(n: &NodeState, load: &NodeLoad, io_depth: u64) -> f64 {
    if n.capacity == 0 {
        return f64::INFINITY;
    }
    let used_frac = n.used as f64 / n.capacity as f64;
    let depth = (load.inflight() + io_depth) as f64;
    (1.0 + used_frac) * (1.0 + load.put_ewma_us() / 1e3) * (1.0 + depth)
}

/// Read-cost score of serving a chunk from a node (lower = cheaper):
/// smoothed serve latency × queue depth × cache coldness — a holder
/// with a warm cache (`hit_rate → 1`) halves its score relative to one
/// that must touch its backend for every serve.
fn read_cost(load: &NodeLoad, io_depth: u64) -> f64 {
    let depth = (load.inflight() + io_depth) as f64;
    (1.0 + load.get_ewma_us() / 1e3) * (1.0 + depth) * (2.0 - load.hit_rate())
}

/// Half-life of file heat, in tracker ticks. The clock is *operation
/// count* (one tick per tracked read store-wide), not wall time — heat
/// is then deterministic for a given operation sequence, which the
/// seeded scenarios and the convergence property test rely on.
const HEAT_HALF_LIFE_TICKS: f64 = 256.0;
/// Heat at which a file earns one extra replica per chunk (the
/// dynamically-derived `broadcast` hint).
const HEAT_WIDEN: f64 = 8.0;
/// Heat below which a widened file gives its extra replica back. The
/// wide gap below [`HEAT_WIDEN`] is deliberate hysteresis: a file
/// oscillating near one threshold never crosses the other, so the
/// widen/trim pair cannot ping-pong.
const HEAT_TRIM: f64 = 2.0;
/// Lock shards for the heat map (path-keyed, same router as the
/// namespace stripes).
const HEAT_SHARDS: usize = 16;

struct HeatEntry {
    heat: f64,
    /// Tracker tick of the last update (decay is computed lazily).
    tick: u64,
}

/// Per-file read-popularity tracker with exponential decay — the
/// signal behind the reserved `heat=` attribute and the adaptive
/// replica widening loop. Sharded like the namespace so hot-path reads
/// of unrelated files never contend; each update is one shard-lock
/// hold around a float multiply.
struct HeatTracker {
    /// Tracked reads so far — the decay clock.
    ticks: AtomicU64,
    shards: Vec<Mutex<HashMap<String, HeatEntry>>>,
}

impl HeatTracker {
    fn new() -> Self {
        HeatTracker {
            ticks: AtomicU64::new(0),
            shards: (0..HEAT_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    /// Heat after decaying from `then` to `now`. `saturating_sub`:
    /// a concurrent `record` can push an entry's tick past a tick this
    /// reader loaded earlier — that must read as "no time passed", not
    /// as a huge negative exponent.
    fn decayed(heat: f64, then: u64, now: u64) -> f64 {
        let dt = now.saturating_sub(then) as f64;
        heat * 0.5f64.powf(dt / HEAT_HALF_LIFE_TICKS)
    }

    /// Count one read of `path`; returns the file's updated heat.
    fn record(&self, path: &str) -> f64 {
        let now = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        let mut shard = self.shards[shard_for_path(path, HEAT_SHARDS)].lock().unwrap();
        let e = shard.entry(path.to_string()).or_insert(HeatEntry {
            heat: 0.0,
            tick: now,
        });
        e.heat = Self::decayed(e.heat, e.tick, now) + 1.0;
        e.tick = now;
        e.heat
    }

    /// Current decayed heat of `path` without counting a read.
    fn peek(&self, path: &str) -> f64 {
        let now = self.ticks.load(Ordering::Relaxed);
        let shard = self.shards[shard_for_path(path, HEAT_SHARDS)].lock().unwrap();
        shard
            .get(path)
            .map(|e| Self::decayed(e.heat, e.tick, now))
            .unwrap_or(0.0)
    }

    /// Drop a deleted/reclaimed file's entry so a later file re-created
    /// at the same path starts cold.
    fn forget(&self, path: &str) {
        self.shards[shard_for_path(path, HEAT_SHARDS)]
            .lock()
            .unwrap()
            .remove(path);
    }
}

/// One namespace stripe: the files (and pre-creation tags) whose path
/// hashes here.
#[derive(Default)]
struct NamespaceShard {
    files: HashMap<String, FileMeta>,
    /// Tags set before file creation (the runtime tags outputs ahead of
    /// execution); merged into the file at create time.
    pending_tags: HashMap<String, TagSet>,
}

/// Shared placement state: node usage plus the sharded cursor/anchor
/// state. Critical sections here are decision-sized (no byte copies).
struct PlacementCore {
    nodes: Vec<NodeState>,
    placement: ShardedPlacementState,
}

/// What a background job does with its chunk.
enum ReplWork {
    /// Copy a write's payload to the remaining replica holders
    /// (optimistic `RepSmntc`).
    Copy {
        payload: Arc<Vec<u8>>,
        targets: Vec<NodeId>,
    },
    /// Promote the chunk from any holder's store into `target`'s cache
    /// (the `Pattern=pipeline` prefetch path). No payload is held in
    /// the queue: the bytes are fetched at execution time.
    Promote {
        sources: Vec<NodeId>,
        target: NodeId,
        class: CacheClass,
    },
    /// Re-replicate a chunk lost with a failed node: fetch the bytes
    /// from any surviving holder and land them on `target`'s backend
    /// (the [`LiveStore::fail_node`] churn path). Like `Promote`, no
    /// payload is queued — bytes are fetched at execution time.
    Restore {
        sources: Vec<NodeId>,
        target: NodeId,
    },
}

/// One background job: a chunk plus the work to do with it.
struct ReplJob {
    file: FileId,
    chunk: u64,
    work: ReplWork,
}

/// Backpressure bound: at most this many queued jobs per worker. Each
/// queued job holds one extra heap copy of its chunk payload, so an
/// unbounded queue would let optimistic writers that outpace the pool
/// grow memory without limit; past the bound, `enqueue` blocks the
/// writer until a worker pops — degrading toward pessimistic latency
/// instead of toward OOM.
const MAX_QUEUED_JOBS_PER_WORKER: usize = 64;

/// Queue state guarded by the pool mutex.
struct ReplQueue {
    jobs: VecDeque<ReplJob>,
    /// In-flight job count per file — lets `delete` wait out exactly the
    /// copies that could resurrect its chunks.
    in_flight: HashMap<FileId, usize>,
    shutdown: bool,
}

/// State shared between the store and its replication workers.
struct ReplShared {
    queue: Mutex<ReplQueue>,
    /// Signaled when work arrives or shutdown flips.
    work: Condvar,
    /// Signaled when a job completes (flush / cancel barriers re-check).
    drained: Condvar,
    stores: Arc<Vec<Box<dyn ChunkBackend>>>,
    /// Cache tier promote jobs land in (absent when the tier is off).
    cache: Option<Arc<CacheTier>>,
    /// Every backend put/get a worker performs drains through this
    /// pool, so replica copies, promote reads, and churn restores
    /// share the same bounded I/O lanes as cache spills.
    io: Arc<IoPool>,
    /// Per-node load signals shared with the store: background puts
    /// hold an in-flight slot on their target so the depth gauge the
    /// adaptive plane reads covers background byte movement too.
    loads: Arc<Vec<NodeLoad>>,
    /// Replica chunk copies completed in the background.
    copied: AtomicU64,
    /// Restore jobs queued or in flight — the store-wide
    /// `under_replicated` gauge: chunks whose replica count is below
    /// target while churn recovery drains.
    restore_pending: AtomicU64,
    /// Chunks re-replicated onto a replacement holder after node churn.
    restored_chunks: AtomicU64,
    /// Bytes re-replicated onto replacement holders after node churn.
    restored_bytes: AtomicU64,
}

/// The background replication worker pool.
struct ReplPool {
    shared: Arc<ReplShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Queued-job bound (workers × [`MAX_QUEUED_JOBS_PER_WORKER`]).
    cap: usize,
}

impl ReplPool {
    fn new(
        stores: Arc<Vec<Box<dyn ChunkBackend>>>,
        cache: Option<Arc<CacheTier>>,
        io: Arc<IoPool>,
        loads: Arc<Vec<NodeLoad>>,
        workers: usize,
    ) -> Self {
        let shared = Arc::new(ReplShared {
            queue: Mutex::new(ReplQueue {
                jobs: VecDeque::new(),
                in_flight: HashMap::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            drained: Condvar::new(),
            stores,
            cache,
            io,
            loads,
            copied: AtomicU64::new(0),
            restore_pending: AtomicU64::new(0),
            restored_chunks: AtomicU64::new(0),
            restored_bytes: AtomicU64::new(0),
        });
        let n_workers = workers.max(1);
        let workers = (0..n_workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("woss-repl-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn replication worker")
            })
            .collect();
        ReplPool {
            shared,
            workers,
            cap: n_workers * MAX_QUEUED_JOBS_PER_WORKER,
        }
    }

    /// Queue a copy job; blocks (backpressure) while the queue is at
    /// capacity, so writers cannot outrun the pool without bound.
    fn enqueue(&self, job: ReplJob) {
        let mut q = self.shared.queue.lock().unwrap();
        while q.jobs.len() >= self.cap {
            q = self.shared.drained.wait(q).unwrap();
        }
        q.jobs.push_back(job);
        drop(q);
        self.shared.work.notify_one();
    }

    /// Block until every queued and in-flight copy has landed.
    fn flush(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !(q.jobs.is_empty() && q.in_flight.is_empty()) {
            q = self.shared.drained.wait(q).unwrap();
        }
    }

    /// [`ReplPool::flush`] with a give-up point: returns `true` when
    /// the pool drained, `false` when `deadline` passed first. A wedged
    /// worker (fault injection, dead remote peer) can no longer park a
    /// client forever on the barrier.
    fn flush_deadline(&self, deadline: Instant) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        while !(q.jobs.is_empty() && q.in_flight.is_empty()) {
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            if left.is_zero() {
                return false;
            }
            q = self.shared.drained.wait_timeout(q, left).unwrap().0;
        }
        true
    }

    /// Drop queued jobs for `file` and wait out its in-flight copies,
    /// so a subsequent chunk sweep cannot be resurrected by a straggler.
    fn cancel_file(&self, file: FileId) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.retain(|j| {
            if j.file != file {
                return true;
            }
            // A dropped restore job must release its slice of the
            // `under_replicated` gauge, or it would read high forever.
            if matches!(j.work, ReplWork::Restore { .. }) {
                self.shared.restore_pending.fetch_sub(1, Ordering::Relaxed);
            }
            false
        });
        while q.in_flight.contains_key(&file) {
            q = self.shared.drained.wait(q).unwrap();
        }
    }

    /// Drop queued cache promotions for `file` and wait out its
    /// in-flight jobs, leaving queued replica copies untouched. Used
    /// when the file's pin state changes: a promotion carrying a
    /// stale `Pinned` class must not land after the fan-out completed,
    /// or nothing would ever unpin it.
    fn cancel_promotes(&self, file: FileId) {
        let mut q = self.shared.queue.lock().unwrap();
        q.jobs.retain(|j| {
            j.file != file
                || matches!(j.work, ReplWork::Copy { .. } | ReplWork::Restore { .. })
        });
        while q.in_flight.contains_key(&file) {
            q = self.shared.drained.wait(q).unwrap();
        }
    }

    /// Queued + in-flight copy jobs (diagnostics).
    fn pending(&self) -> usize {
        let q = self.shared.queue.lock().unwrap();
        q.jobs.len() + q.in_flight.values().sum::<usize>()
    }

    /// Any queued or in-flight background job for `file`? The heat
    /// trim path checks this so it never removes a replica whose
    /// widening copy is still landing.
    fn has_pending(&self, file: FileId) -> bool {
        let q = self.shared.queue.lock().unwrap();
        q.in_flight.contains_key(&file) || q.jobs.iter().any(|j| j.file == file)
    }
}

impl Drop for ReplPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker body: drain jobs (even after shutdown flips — shutdown means
/// "no new work", not "drop queued replicas"), then exit.
fn worker_loop(shared: &ReplShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    *q.in_flight.entry(job.file).or_insert(0) += 1;
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        // A slot just freed: wake any writer blocked on backpressure
        // (flush/cancel waiters re-check their conditions and re-sleep).
        shared.drained.notify_all();
        let key = (job.file, job.chunk);
        match &job.work {
            ReplWork::Copy { payload, targets } => {
                // All targets go down as one I/O batch: with
                // `io_workers >= 2` the fan-out's puts land
                // concurrently. A backend write failure (disk tier)
                // leaves that replica missing — optimistic semantics
                // never promised it, and reads fall back to holders
                // that materialized the chunk.
                let _slots: Vec<LoadSlot<'_>> = targets
                    .iter()
                    .map(|&t| shared.loads[t.0].begin())
                    .collect();
                let puts = targets
                    .iter()
                    .map(|&target| {
                        let stores = Arc::clone(&shared.stores);
                        let payload = Arc::clone(payload);
                        move || stores[target.0].put(key, payload.as_ref()).is_ok()
                    })
                    .collect::<Vec<_>>();
                for ok in shared.io.run_batch(puts) {
                    if ok {
                        shared.copied.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            ReplWork::Promote {
                sources,
                target,
                class,
            } => {
                // Re-check residency at execution time: a concurrent
                // read may have cached the chunk since the job was
                // queued — promoting again would fetch and copy for
                // nothing.
                if let Some(cache) = &shared.cache {
                    if !cache.contains(*target, key) {
                        // Fetch from the first holder that has
                        // materialized the chunk — its cache first (a
                        // dirty cache-only chunk lives nowhere else,
                        // and cache-before-backend is the race-free
                        // probe order under concurrent dirty
                        // write-backs), then its backend (read through
                        // the I/O pool); a file deleted mid-flight
                        // simply has no source left and the job
                        // becomes a no-op. A holder whose read fails
                        // is treated as having no copy (the backend
                        // counts the fault) and the next source is
                        // tried.
                        let bytes = sources.iter().find_map(|&s| {
                            cache.peek(s, key).or_else(|| {
                                let stores = Arc::clone(&shared.stores);
                                shared.io.run(move || stores[s.0].get(key).ok().flatten())
                            })
                        });
                        if let Some(bytes) = bytes {
                            if cache.insert(*target, key, bytes, *class) {
                                cache.prefetched.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
            ReplWork::Restore { sources, target } => {
                // Skip if a racing job (or the node itself) already
                // materialized the chunk; otherwise fetch from the
                // first surviving holder with readable bytes — cache
                // first for the same race-free probe order Promote
                // uses — and land them on the replacement holder. A
                // source whose read fails is treated as having no copy
                // (its backend counts the fault) and the next source
                // is tried; when no source or the put fails, the chunk
                // simply stays under-replicated on that holder and
                // reads keep failing over.
                if !shared.stores[target.0].contains(key) {
                    let bytes = sources.iter().find_map(|&s| {
                        shared
                            .cache
                            .as_ref()
                            .and_then(|c| c.peek(s, key))
                            .or_else(|| {
                                let stores = Arc::clone(&shared.stores);
                                shared.io.run(move || stores[s.0].get(key).ok().flatten())
                            })
                    });
                    if let Some(bytes) = bytes {
                        let target = *target;
                        let len = bytes.len() as u64;
                        let stores = Arc::clone(&shared.stores);
                        let _slot = shared.loads[target.0].begin();
                        if shared.io.run(move || stores[target.0].put(key, &bytes).is_ok()) {
                            shared.restored_chunks.fetch_add(1, Ordering::Relaxed);
                            shared.restored_bytes.fetch_add(len, Ordering::Relaxed);
                        }
                    }
                }
                shared.restore_pending.fetch_sub(1, Ordering::Relaxed);
            }
        }
        let mut q = shared.queue.lock().unwrap();
        if let Some(n) = q.in_flight.get_mut(&job.file) {
            *n -= 1;
            if *n == 0 {
                q.in_flight.remove(&job.file);
            }
        }
        drop(q);
        shared.drained.notify_all();
    }
}

thread_local! {
    /// Set inside [`io_worker_loop`]: a pooled job that submits to the
    /// pool again must execute inline rather than enqueue-and-wait —
    /// if every worker blocked on a sibling slot at once, nothing
    /// would be left to drain the queue.
    static IS_IO_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// One queued I/O submission (the closure owns everything it needs and
/// delivers its result through a completion slot).
type IoJob = Box<dyn FnOnce() + Send>;

/// Queue state guarded by the I/O pool mutex.
struct IoQueue {
    jobs: VecDeque<IoJob>,
    /// Submissions currently executing — pooled *and* inline (see
    /// [`IoPool::run`]) — so [`IoPool::pending`] and [`IoPool::flush`]
    /// cover serial (`io_workers=1`) operation too.
    running: usize,
    shutdown: bool,
}

/// State shared between submitters and the I/O workers.
struct IoShared {
    queue: Mutex<IoQueue>,
    /// Signaled when work arrives or shutdown flips.
    work: Condvar,
    /// Signaled when a submission completes (flush waiters re-check).
    drained: Condvar,
}

/// RAII decrement of [`IoQueue::running`] + drained notify — held
/// across the job body so the gauge and the flush barrier stay honest
/// even if the job panics.
struct RunningGuard<'a>(&'a IoShared);

impl Drop for RunningGuard<'_> {
    fn drop(&mut self) {
        let mut q = self.0.queue.lock().unwrap();
        q.running -= 1;
        drop(q);
        self.0.drained.notify_all();
    }
}

/// The bounded I/O submission/completion worker pool
/// ([`LiveTuning::io_workers`]). Background disk work — dirty-entry
/// spills, optimistic replica copies, prefetch promote reads, churn
/// restore copies — drains through here instead of running on whatever
/// thread happened to trigger it, so independent disk operations can
/// overlap when the pool has more than one worker.
///
/// Submission is synchronous for the submitter ([`IoPool::run`]
/// returns the job's result), which bounds the pool by construction:
/// there can never be more queued jobs than blocked submitting
/// threads. With `io_workers == 1` no worker threads are spawned at
/// all and every submission runs inline on the submitting thread —
/// byte-for-byte the pre-pool serial data path. [`IoPool::run_batch`]
/// is the fan-out form: it enqueues a set of independent submissions
/// at once (a multi-target replica copy) and waits for all of them.
struct IoPool {
    shared: Arc<IoShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl IoPool {
    fn new(workers: usize) -> Self {
        let shared = Arc::new(IoShared {
            queue: Mutex::new(IoQueue {
                jobs: VecDeque::new(),
                running: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            drained: Condvar::new(),
        });
        // One worker means serial: run inline on the submitter and
        // spawn nothing, reproducing the pre-pool behavior exactly.
        let handles = if workers.max(1) < 2 {
            Vec::new()
        } else {
            (0..workers)
                .map(|i| {
                    let shared = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("woss-io-{i}"))
                        .spawn(move || io_worker_loop(&shared))
                        .expect("spawn io worker")
                })
                .collect()
        };
        IoPool {
            shared,
            workers: handles,
        }
    }

    /// Execute `f` through the pool and return its result. Inline on
    /// the submitting thread when the pool is serial (no workers) or
    /// when the submitter *is* a pool worker (a nested submission must
    /// not wait on a sibling slot); otherwise enqueued and awaited.
    /// A panic inside `f` resurfaces on the submitting thread either
    /// way.
    fn run<R: Send + 'static>(&self, f: impl FnOnce() -> R + Send + 'static) -> R {
        if self.workers.is_empty() || IS_IO_WORKER.with(std::cell::Cell::get) {
            {
                let mut q = self.shared.queue.lock().unwrap();
                q.running += 1;
            }
            let _guard = RunningGuard(&self.shared);
            return f();
        }
        let mut results = self.run_batch(vec![f]);
        results.pop().expect("one submission, one result")
    }

    /// Enqueue a set of independent submissions at once and wait for
    /// all of them, returning their results in order. This is where
    /// `io_workers >= 2` buys real overlap: a replica fan-out's puts
    /// land concurrently instead of one after another.
    fn run_batch<R, F>(&self, fs: Vec<F>) -> Vec<R>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        if self.workers.is_empty() || IS_IO_WORKER.with(std::cell::Cell::get) {
            return fs
                .into_iter()
                .map(|f| {
                    {
                        let mut q = self.shared.queue.lock().unwrap();
                        q.running += 1;
                    }
                    let _guard = RunningGuard(&self.shared);
                    f()
                })
                .collect();
        }
        type Slot<R> = Arc<(Mutex<Option<std::thread::Result<R>>>, Condvar)>;
        let slots: Vec<Slot<R>> = (0..fs.len())
            .map(|_| Arc::new((Mutex::new(None), Condvar::new())))
            .collect();
        {
            let mut q = self.shared.queue.lock().unwrap();
            for (f, slot) in fs.into_iter().zip(&slots) {
                let slot = Arc::clone(slot);
                q.jobs.push_back(Box::new(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    let (lock, cv) = &*slot;
                    *lock.lock().unwrap() = Some(r);
                    cv.notify_all();
                }));
            }
        }
        self.shared.work.notify_all();
        slots
            .into_iter()
            .map(|slot| {
                let (lock, cv) = &*slot;
                let mut held = lock.lock().unwrap();
                loop {
                    if let Some(r) = held.take() {
                        break match r {
                            Ok(r) => r,
                            Err(panic) => std::panic::resume_unwind(panic),
                        };
                    }
                    held = cv.wait(held).unwrap();
                }
            })
            .collect()
    }

    /// Queued + executing submissions — the `io_queue=` gauge
    /// `system_status` reports.
    fn pending(&self) -> usize {
        let q = self.shared.queue.lock().unwrap();
        q.jobs.len() + q.running
    }

    /// Block until every queued and executing submission completes.
    fn flush(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !(q.jobs.is_empty() && q.running == 0) {
            q = self.shared.drained.wait(q).unwrap();
        }
    }

    /// [`IoPool::flush`] with a give-up point: `true` when drained,
    /// `false` when `deadline` passed first.
    fn flush_deadline(&self, deadline: Instant) -> bool {
        let mut q = self.shared.queue.lock().unwrap();
        while !(q.jobs.is_empty() && q.running == 0) {
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            if left.is_zero() {
                return false;
            }
            q = self.shared.drained.wait_timeout(q, left).unwrap().0;
        }
        true
    }
}

impl Drop for IoPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// I/O worker body: drain jobs (even after shutdown flips — every
/// queued job has a submitter blocked on its completion slot), then
/// exit.
fn io_worker_loop(shared: &IoShared) {
    IS_IO_WORKER.with(|w| w.set(true));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    q.running += 1;
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        let _guard = RunningGuard(shared);
        job();
    }
}

/// The result of a bottom-up [`LiveStore::audit`]: does the namespace
/// (what files claim), the placement core (what accounting believes),
/// and the chunk backends (what is physically stored) all agree? The
/// scenario harness ends every hostile workload with one of these;
/// `clean()` is the pass/fail verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreAudit {
    /// Files in the namespace.
    pub files: usize,
    /// Chunk replicas the namespace claims (one per chunk per holder).
    pub replicas_claimed: usize,
    /// Logical bytes the namespace claims on each node.
    pub claimed_bytes: Vec<u64>,
    /// Bytes the placement core's usage accounting carries per node.
    pub accounted_bytes: Vec<u64>,
    /// Bytes each node's chunk backend physically holds.
    pub backend_bytes: Vec<u64>,
    /// Backend chunks no surviving file claims from that node (leaks:
    /// a failed node's unswept copies count here until
    /// [`LiveStore::join_node`] sweeps them).
    pub stray_chunks: usize,
    /// Claimed replicas whose bytes exist neither in the holder's
    /// backend nor as a dirty cache entry (lost data).
    pub missing_chunks: usize,
}

impl StoreAudit {
    /// Namespace claims and placement accounting agree byte-for-byte.
    pub fn usage_exact(&self) -> bool {
        self.claimed_bytes == self.accounted_bytes
    }

    /// No drift anywhere: usage exact, zero strays, zero missing.
    pub fn clean(&self) -> bool {
        self.usage_exact() && self.stray_chunks == 0 && self.missing_chunks == 0
    }
}

/// Wrap every node backend in a [`FaultBackend`] sharing `control`,
/// each drawing its schedule from `spec` mixed with the node index.
fn wrap_with_faults(
    backends: Vec<Box<dyn ChunkBackend>>,
    spec: FaultSpec,
    control: &Arc<FaultControl>,
) -> Vec<Box<dyn ChunkBackend>> {
    backends
        .into_iter()
        .enumerate()
        .map(|(i, b)| {
            Box::new(FaultBackend::new(b, spec.for_node(i), Arc::clone(control)))
                as Box<dyn ChunkBackend>
        })
        .collect()
}

/// A locked namespace stripe plus the [`lockscope`] token that lets
/// the debug-only guard catch backend I/O issued while the lock is
/// held (see [`CacheGuard`]). Field order matters: the mutex guard
/// drops before the token.
struct StripeGuard<'a> {
    stripe: std::sync::MutexGuard<'a, NamespaceShard>,
    _token: lockscope::Token,
}

impl std::ops::Deref for StripeGuard<'_> {
    type Target = NamespaceShard;
    fn deref(&self) -> &NamespaceShard {
        &self.stripe
    }
}

impl std::ops::DerefMut for StripeGuard<'_> {
    fn deref_mut(&mut self) -> &mut NamespaceShard {
        &mut self.stripe
    }
}

/// The placement core, locked and lock-scope-tracked (see
/// [`StripeGuard`]).
struct CoreGuard<'a> {
    core: std::sync::MutexGuard<'a, PlacementCore>,
    _token: lockscope::Token,
}

impl std::ops::Deref for CoreGuard<'_> {
    type Target = PlacementCore;
    fn deref(&self) -> &PlacementCore {
        &self.core
    }
}

impl std::ops::DerefMut for CoreGuard<'_> {
    fn deref_mut(&mut self) -> &mut PlacementCore {
        &mut self.core
    }
}

/// Hook by which churn crosses the process boundary. In socket mode
/// the cluster supervisor (`live::rpc::Cluster`) implements this:
/// [`LiveStore::fail_node`] reports the kill so the supervisor SIGKILLs
/// the actual `woss noded` process, and [`LiveStore::join_node`] asks
/// it to respawn the daemon (`noded --reopen` on persistent backends)
/// before the node is re-admitted to placement. The in-process default
/// attaches no supervisor and behaves exactly as before.
pub trait NodeSupervisor: Send + Sync {
    /// The manager declared `node` dead; take its process down.
    fn node_down(&self, node: usize);
    /// The manager wants `node` back; bring its process up (blocking
    /// until it serves) or say why it cannot come back.
    fn node_up(&self, node: usize) -> Result<(), String>;
}

/// What varies between the store constructors — fresh
/// ([`LiveStore::try_with_tuning`]), restart ([`LiveStore::reopen`]),
/// caller-supplied backends ([`LiveStore::with_backends`]). The shared
/// assembly tail wires the identical pool/cache/counter plumbing
/// around these.
struct StoreParts {
    registry: Registry,
    n_nodes: usize,
    capacity: u64,
    backends: Vec<Box<dyn ChunkBackend>>,
    backend_kind: BackendKind,
    data_root: Option<PathBuf>,
    journal: Option<Mutex<AppendLog>>,
    dir_guard: Option<DirGuard>,
    /// Rebuilt namespace stripes (reopen) or `None` for fresh.
    stripes: Option<Vec<NamespaceShard>>,
    /// Rebuilt node states with recovered usage, or `None` for fresh.
    nodes: Option<Vec<NodeState>>,
    next_id: u64,
    recovered_ids: HashSet<FileId>,
    recovery: Option<RecoveryReport>,
}

/// The live object store.
pub struct LiveStore {
    registry: Registry,
    stripes: Vec<Mutex<NamespaceShard>>,
    core: Mutex<PlacementCore>,
    stores: Arc<Vec<Box<dyn ChunkBackend>>>,
    /// Which [`ChunkBackend`] the per-node stores run on (reported by
    /// the `cache_state` attribute's `tier=` field).
    backend_kind: BackendKind,
    /// Root of the disk backend's per-node directories (disk backend
    /// only).
    data_root: Option<PathBuf>,
    /// Hot-chunk cache tier ([`LiveTuning::cache_bytes`]); absent by
    /// default.
    cache: Option<Arc<CacheTier>>,
    /// Enforce scratch-lifetime reclamation and broadcast pinning.
    lifetime_on: bool,
    next_id: AtomicU64,
    repl: ReplPool,
    /// The bounded I/O submission/completion pool
    /// ([`LiveTuning::io_workers`]) shared with the cache tier and the
    /// replication workers. Declared after `repl`: the replication
    /// workers join (and release their pool handle) before the pool's
    /// own drop joins the I/O workers.
    io: Arc<IoPool>,
    /// Foreground per-chunk put latencies, µs ([`CacheStats::put_p50_us`]).
    put_samples: Mutex<Reservoir>,
    /// Foreground per-chunk read latencies, µs ([`CacheStats::get_p50_us`]).
    get_samples: Mutex<Reservoir>,
    /// Per-node live load signals (EWMA latencies, in-flight depth,
    /// cache hit rate) — the feedback plane adaptive placement and
    /// read scheduling consume. Always collected; only *decisions*
    /// are gated on `adaptive`.
    loads: Arc<Vec<NodeLoad>>,
    /// Per-file read-popularity tracker behind the reserved `heat=`
    /// attribute and the adaptive replica widen/trim loop.
    heat: HeatTracker,
    /// Files currently holding an extra heat replica (guards the
    /// widen/trim loop against double-widening and no-op trims).
    widened: Mutex<HashSet<FileId>>,
    /// Consume the load plane in placement/read/churn decisions
    /// ([`LiveTuning::adaptive`]). Off reproduces static behavior.
    adaptive: bool,
    /// Files granted an extra replica because their read heat crossed
    /// [`HEAT_WIDEN`].
    heat_widened: AtomicU64,
    /// Widened files whose extra replica was trimmed after decay below
    /// [`HEAT_TRIM`].
    heat_trimmed: AtomicU64,
    /// Bytes written through [`LiveStore::write_file`] (lock-free counter).
    pub bytes_written: AtomicU64,
    /// Bytes returned by [`LiveStore::read_file`].
    pub bytes_read: AtomicU64,
    /// Chunk reads served from the reader's own node store.
    pub local_reads: AtomicU64,
    /// Chunk reads that had to fetch from another node's store.
    pub remote_reads: AtomicU64,
    /// `set-attribute` operations (top-down channel traffic).
    pub setattr_ops: AtomicU64,
    /// `get-attribute` operations (bottom-up channel traffic).
    pub getattr_ops: AtomicU64,
    /// Replica chunk copies handed to the background pool (optimistic
    /// `RepSmntc` writes).
    pub replicas_deferred: AtomicU64,
    /// Scratch files auto-reclaimed after their last declared consumer
    /// read (lifetime enforcement).
    pub files_reclaimed: AtomicU64,
    /// Logical bytes freed by auto-reclamation.
    pub bytes_reclaimed: AtomicU64,
    /// Failure injection: nodes marked dead serve nothing.
    dead: RwLock<Vec<bool>>,
    /// Append handle on the namespace journal (disk backend only):
    /// `create` records land under the namespace stripe lock, `del`
    /// records from the sweep paths.
    journal: Option<Mutex<AppendLog>>,
    /// Set while a `CLEAN` marker written by [`LiveStore::shutdown`]
    /// is on disk; the first namespace mutation afterwards clears the
    /// flag and unlinks the marker, invalidating the now-stale
    /// snapshots so a later crash falls back to journal salvage.
    clean_marker: AtomicBool,
    /// Files that came back through [`LiveStore::reopen`] — the
    /// `recovered=` field on `cache_state` reads this. Pruned when the
    /// file is deleted or reclaimed, so the `system_status` count
    /// never outlives the files it describes.
    recovered_ids: RwLock<HashSet<FileId>>,
    /// Shared fault-injection control when [`LiveTuning::fault`] is
    /// set (`None` on an undecorated store).
    faults: Option<Arc<FaultControl>>,
    /// Process supervisor for the node tier, attached in socket mode
    /// ([`LiveStore::attach_supervisor`]): [`LiveStore::fail_node`]
    /// reports the kill so the supervisor can take the real daemon
    /// down, and [`LiveStore::join_node`] asks it to bring the daemon
    /// back before re-admitting the node. `None` — the in-process
    /// default — keeps churn purely internal, exactly as before.
    supervisor: RwLock<Option<Arc<dyn NodeSupervisor>>>,
    /// Barrier deadline derived from [`LiveTuning::flush_timeout_ms`].
    flush_deadline: Option<Duration>,
    /// Flush barriers that hit the deadline before the pools drained.
    flush_timeouts: AtomicU64,
    /// Per-node capacity as configured — what [`LiveStore::join_node`]
    /// restores after [`LiveStore::fail_node`] zeroed the node out of
    /// placement.
    node_capacity: u64,
    /// What the last [`LiveStore::reopen`] rebuilt (`None` on a fresh
    /// store).
    recovery: Option<RecoveryReport>,
    /// Cleanup for an auto-created disk-backend directory. Declared
    /// last (after `repl`): struct fields drop in declaration order,
    /// so the replication workers are joined before the directory is
    /// removed — a worker can never write into a deleted tree.
    _dir_guard: Option<DirGuard>,
}

impl LiveStore {
    /// A deployment over `n_nodes` stores with `capacity` bytes each and
    /// default [`LiveTuning`].
    pub fn new(registry: Registry, n_nodes: usize, capacity: u64) -> Self {
        LiveStore::with_tuning(registry, n_nodes, capacity, LiveTuning::default())
    }

    /// A deployment with explicit concurrency tuning. Panics when the
    /// disk backend cannot create its data directories — use
    /// [`LiveStore::try_with_tuning`] to handle that at a CLI boundary.
    pub fn with_tuning(
        registry: Registry,
        n_nodes: usize,
        capacity: u64,
        tuning: LiveTuning,
    ) -> Self {
        LiveStore::try_with_tuning(registry, n_nodes, capacity, tuning)
            .expect("build live store backend")
    }

    /// A deployment with explicit concurrency tuning; errors when the
    /// chunk backend cannot be brought up (e.g. the disk backend's
    /// `data_dir` is not creatable).
    pub fn try_with_tuning(
        registry: Registry,
        n_nodes: usize,
        capacity: u64,
        tuning: LiveTuning,
    ) -> Result<Self, StorageError> {
        let (backends, data_root, dir_guard, journal) = match tuning.backend {
            BackendKind::Memory => {
                let backends: Vec<Box<dyn ChunkBackend>> = (0..n_nodes)
                    .map(|_| Box::new(MemoryBackend::default()) as Box<dyn ChunkBackend>)
                    .collect();
                (backends, None, None, None)
            }
            BackendKind::Disk | BackendKind::Seg => {
                // A user-supplied directory persists across the store's
                // lifetime; an auto-created one is owned (removed when
                // the store drops, after the replication workers join).
                let (root, guard) = match &tuning.data_dir {
                    Some(dir) => (dir.clone(), None),
                    None => {
                        let dir = auto_data_dir();
                        (dir.clone(), Some(DirGuard { path: dir }))
                    }
                };
                // A fresh store must never be built over a previous
                // store's data: that silently orphans every durable
                // file the old instance promised to keep. Re-opening
                // is an explicit, recovering operation.
                if root.join(STORE_META).exists() || root.join(NAMESPACE_LOG).exists() {
                    return Err(StorageError::Invalid(format!(
                        "data dir {} already holds a store; reopen it \
                         (LiveStore::reopen / --reopen) or point at an empty directory",
                        root.display()
                    )));
                }
                // store.meta goes down first: once any node manifest
                // exists this directory refuses a fresh open, so the
                // reopen path must already have what it needs — a
                // crash mid-bring-up then recovers (as empty) instead
                // of leaving a directory neither path will accept.
                std::fs::create_dir_all(&root).map_err(|e| {
                    StorageError::Invalid(format!("create data dir {}: {e}", root.display()))
                })?;
                // `hints=` records whether the *creating* registry
                // interpreted tags: a store that treated
                // `Lifetime=scratch` as transient must discard scratch
                // at recovery even if the reopening process passes a
                // different registry, and a DSS store (tags inert)
                // must keep those same files — they were ordinary
                // durable data to it.
                // `backend=` records the on-disk chunk layout so
                // reopen dispatches to the right replay path; PR 5-era
                // stores lack the field and are file-per-chunk.
                write_durable(
                    &root.join(STORE_META),
                    &format!(
                        "nodes={n_nodes} capacity={capacity} hints={} backend={}\n",
                        u8::from(registry.hints_enabled()),
                        tuning.backend.label()
                    ),
                )?;
                let mut backends: Vec<Box<dyn ChunkBackend>> = Vec::with_capacity(n_nodes);
                for i in 0..n_nodes {
                    let node_dir = root.join(format!("node{i}"));
                    backends.push(match tuning.backend {
                        BackendKind::Seg => Box::new(SegBackend::new(&node_dir)?) as Box<dyn ChunkBackend>,
                        _ => Box::new(FileBackend::new(&node_dir)?) as Box<dyn ChunkBackend>,
                    });
                }
                let journal = std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(root.join(NAMESPACE_LOG))
                    .map_err(|e| {
                        StorageError::Invalid(format!("create namespace journal: {e}"))
                    })?;
                (
                    backends,
                    Some(root),
                    guard,
                    Some(Mutex::new(AppendLog::new(journal))),
                )
            }
        };
        Ok(LiveStore::assemble(
            StoreParts {
                registry,
                n_nodes,
                capacity,
                backends,
                backend_kind: tuning.backend,
                data_root,
                journal,
                dir_guard,
                stripes: None,
                nodes: None,
                next_id: 1,
                recovered_ids: HashSet::new(),
                recovery: None,
            },
            &tuning,
        ))
    }

    /// A deployment over caller-supplied chunk backends — the
    /// `managerd` path, where each element is a remote proxy speaking
    /// the node wire protocol to a `woss noded` daemon
    /// ([`super::rpc::RemoteBackend`]). The manager keeps no local
    /// data directory or namespace journal: durability lives behind
    /// the supplied backends. Every other tuning knob applies exactly
    /// as for a local store; `tuning.backend` is ignored in favor of
    /// `backend_kind`, the layout the daemons themselves report.
    pub fn with_backends(
        registry: Registry,
        backends: Vec<Box<dyn ChunkBackend>>,
        backend_kind: BackendKind,
        capacity: u64,
        tuning: LiveTuning,
    ) -> Self {
        let n_nodes = backends.len();
        LiveStore::assemble(
            StoreParts {
                registry,
                n_nodes,
                capacity,
                backends,
                backend_kind,
                data_root: None,
                journal: None,
                dir_guard: None,
                stripes: None,
                nodes: None,
                next_id: 1,
                recovered_ids: HashSet::new(),
                recovery: None,
            },
            &tuning,
        )
    }

    /// The shared constructor tail: fault decoration, the I/O and
    /// replication pools, the cache tier, the load plane, and every
    /// counter — identical no matter where the backends came from.
    fn assemble(parts: StoreParts, tuning: &LiveTuning) -> Self {
        let StoreParts {
            registry,
            n_nodes,
            capacity,
            backends,
            backend_kind,
            data_root,
            journal,
            dir_guard,
            stripes,
            nodes,
            next_id,
            recovered_ids,
            recovery,
        } = parts;
        let faults = tuning.fault.as_ref().map(|_| FaultControl::armed());
        let backends = match (&tuning.fault, &faults) {
            (Some(spec), Some(ctl)) => wrap_with_faults(backends, *spec, ctl),
            _ => backends,
        };
        let stores: Arc<Vec<Box<dyn ChunkBackend>>> = Arc::new(backends);
        let n_stripes = tuning.stripes.max(1);
        let io = Arc::new(IoPool::new(tuning.io_workers));
        let loads: Arc<Vec<NodeLoad>> =
            Arc::new((0..n_nodes).map(|_| NodeLoad::default()).collect());
        let cache = tuning.cache_bytes.map(|budget| {
            Arc::new(CacheTier::new(
                n_nodes,
                budget,
                tuning.cache_policy,
                Some(Arc::clone(&stores)),
                Arc::clone(&io),
                Arc::clone(&loads),
            ))
        });
        let stripes = stripes
            .unwrap_or_else(|| (0..n_stripes).map(|_| NamespaceShard::default()).collect());
        let nodes = nodes.unwrap_or_else(|| {
            (0..n_nodes)
                .map(|i| NodeState {
                    node: NodeId(i),
                    capacity,
                    used: 0,
                })
                .collect()
        });
        LiveStore {
            registry,
            stripes: stripes.into_iter().map(Mutex::new).collect(),
            core: Mutex::new(PlacementCore {
                nodes,
                placement: ShardedPlacementState::new(n_stripes),
            }),
            stores: Arc::clone(&stores),
            backend_kind,
            data_root,
            cache: cache.clone(),
            lifetime_on: tuning.lifetime,
            next_id: AtomicU64::new(next_id),
            repl: ReplPool::new(
                stores,
                cache,
                Arc::clone(&io),
                Arc::clone(&loads),
                tuning.repl_workers,
            ),
            io,
            put_samples: Mutex::new(Reservoir::default()),
            get_samples: Mutex::new(Reservoir::default()),
            loads,
            heat: HeatTracker::new(),
            widened: Mutex::new(HashSet::new()),
            adaptive: tuning.adaptive,
            heat_widened: AtomicU64::new(0),
            heat_trimmed: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            local_reads: AtomicU64::new(0),
            remote_reads: AtomicU64::new(0),
            setattr_ops: AtomicU64::new(0),
            getattr_ops: AtomicU64::new(0),
            replicas_deferred: AtomicU64::new(0),
            files_reclaimed: AtomicU64::new(0),
            bytes_reclaimed: AtomicU64::new(0),
            dead: RwLock::new(vec![false; n_nodes]),
            journal,
            clean_marker: AtomicBool::new(false),
            recovered_ids: RwLock::new(recovered_ids),
            faults,
            supervisor: RwLock::new(None),
            flush_deadline: tuning.flush_timeout_ms.map(Duration::from_millis),
            flush_timeouts: AtomicU64::new(0),
            node_capacity: capacity,
            recovery,
            _dir_guard: dir_guard,
        }
    }

    /// Re-open a disk-backed store left in `data_dir` by a previous
    /// process, with default [`LiveTuning`] — the restart path. See
    /// [`LiveStore::reopen_with`].
    pub fn reopen(registry: Registry, data_dir: &Path) -> Result<Self, StorageError> {
        LiveStore::reopen_with(registry, data_dir, LiveTuning::default())
    }

    /// Re-open a persistent store with explicit tuning (the backend
    /// kind comes from the store's own `store.meta` — `tuning.backend`
    /// is ignored, so a `disk` store reopens as `disk` and a `seg`
    /// store as `seg` no matter what the caller passes — and
    /// `tuning.data_dir` is overridden by `data_dir`; node count and
    /// capacity likewise come from `store.meta`).
    ///
    /// Recovery is bottom-up: per-node chunk manifests or segment
    /// logs are replayed and every surviving chunk verified against
    /// its recorded length and checksum ([`FileBackend::open_existing`]
    /// / [`SegBackend::open_existing`]); the
    /// namespace comes from the clean-shutdown snapshots when the
    /// `CLEAN` marker is present, else from journal salvage. A file
    /// survives only if every chunk verified on at least one holder
    /// (lost holders are pruned from its block map); scratch files and
    /// unclaimed chunks are discarded. [`LiveStore::recovery_report`]
    /// says what happened.
    pub fn reopen_with(
        registry: Registry,
        data_dir: &Path,
        tuning: LiveTuning,
    ) -> Result<Self, StorageError> {
        let meta_raw = std::fs::read_to_string(data_dir.join(STORE_META)).map_err(|e| {
            StorageError::Invalid(format!(
                "no store to reopen under {} (store.meta: {e})",
                data_dir.display()
            ))
        })?;
        let mut n_nodes = 0usize;
        let mut capacity = 0u64;
        let mut creator_hints: Option<bool> = None;
        // PR 5-era stores predate the `backend=` field; they are all
        // file-per-chunk.
        let mut backend_kind = BackendKind::Disk;
        for field in meta_raw.split_whitespace() {
            if let Some(v) = field.strip_prefix("nodes=") {
                n_nodes = v
                    .parse()
                    .map_err(|e| StorageError::Invalid(format!("store.meta nodes: {e}")))?;
            } else if let Some(v) = field.strip_prefix("capacity=") {
                capacity = v
                    .parse()
                    .map_err(|e| StorageError::Invalid(format!("store.meta capacity: {e}")))?;
            } else if let Some(v) = field.strip_prefix("hints=") {
                creator_hints = Some(v != "0");
            } else if let Some(v) = field.strip_prefix("backend=") {
                backend_kind = v
                    .parse()
                    .map_err(|e| StorageError::Invalid(format!("store.meta backend: {e}")))?;
            }
        }
        if n_nodes == 0 {
            return Err(StorageError::Invalid(format!(
                "store.meta under {} names no nodes",
                data_dir.display()
            )));
        }

        // Bottom layer first: replay + verify every node's chunks. A
        // node directory that never made it to disk (the store crashed
        // during bring-up, after store.meta but before every backend
        // constructor ran) is an empty node, not an error — the
        // directory must stay reopenable at every point of its life.
        let mut file_backends: Vec<Box<dyn ChunkBackend>> = Vec::with_capacity(n_nodes);
        let mut node_recs = Vec::with_capacity(n_nodes);
        for i in 0..n_nodes {
            let node_dir = data_dir.join(format!("node{i}"));
            let (b, rec): (Box<dyn ChunkBackend>, NodeRecovery) = match backend_kind {
                BackendKind::Seg => {
                    if node_dir.is_dir() {
                        let (b, rec) = SegBackend::open_existing(&node_dir)?;
                        (Box::new(b), rec)
                    } else {
                        (Box::new(SegBackend::new(&node_dir)?), NodeRecovery::default())
                    }
                }
                _ => {
                    if node_dir.is_dir() {
                        let (b, rec) = FileBackend::open_existing(&node_dir)?;
                        (Box::new(b), rec)
                    } else {
                        (Box::new(FileBackend::new(&node_dir)?), NodeRecovery::default())
                    }
                }
            };
            file_backends.push(b);
            node_recs.push(rec);
        }
        let backend_rec = NodeRecovery::merged(&node_recs);

        // Namespace candidates: snapshots on a clean shutdown, journal
        // salvage after a crash.
        let clean_stripes = std::fs::read_to_string(data_dir.join(CLEAN_MARKER))
            .ok()
            .and_then(|s| s.trim().strip_prefix("stripes=")?.parse::<usize>().ok());
        let mut max_id = 0u64;
        // Snapshot path: trusted only when every snapshot the marker
        // vouches for reads back. A CLEAN marker over a missing or
        // unreadable snapshot (e.g. power loss between renames on a
        // file system that reordered them) must not brick the store —
        // the journal + manifests still hold everything, so fall back
        // to salvage instead of erroring.
        let snapshot_candidates: Option<Vec<(String, FileMeta)>> = clean_stripes.and_then(|k| {
            let mut out = Vec::new();
            for s in 0..k {
                let snap =
                    std::fs::read_to_string(data_dir.join(format!("ns-stripe{s}.snap"))).ok()?;
                for line in snap.lines() {
                    out.push(decode_create(line)?);
                }
            }
            Some(out)
        });
        let mut report = RecoveryReport {
            clean: snapshot_candidates.is_some(),
            ..RecoveryReport::default()
        };
        let mut candidates: Vec<(String, FileMeta)> = Vec::new();
        if let Some(snap) = snapshot_candidates {
            candidates = snap;
        } else {
            // Journal replay: creates insert, dels remove, the first
            // torn or garbled record (and everything after it —
            // append order is trust order) is discarded. A journal that
            // does not exist is a store that crashed before its journal
            // became durable — legitimately empty; any other read
            // failure aborts the reopen, because salvaging "nothing"
            // would drop every file and sweep every chunk on disk.
            let raw = match std::fs::read(data_dir.join(NAMESPACE_LOG)) {
                Ok(raw) => raw,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
                Err(e) => {
                    return Err(StorageError::Invalid(format!(
                        "read namespace journal under {}: {e}",
                        data_dir.display()
                    )));
                }
            };
            let text = String::from_utf8_lossy(&raw);
            let mut by_id: HashMap<u64, usize> = HashMap::new();
            let mut ordered: Vec<Option<(String, FileMeta)>> = Vec::new();
            for line in text.split_inclusive('\n') {
                let Some(body) = line.strip_suffix('\n') else {
                    continue; // torn tail: that record alone is lost
                };
                if let Some(id) = body.strip_prefix("del\t").and_then(|v| v.parse::<u64>().ok()) {
                    if let Some(slot) = by_id.remove(&id) {
                        ordered[slot] = None;
                    }
                } else if let Some((path, meta)) = decode_create(body) {
                    by_id.insert(meta.id.0, ordered.len());
                    ordered.push(Some((path, meta)));
                }
                // A terminated-but-garbled line is one damaged record
                // (a failed append the next one newline-terminated):
                // skip it, keep the rest — every candidate is verified
                // against the chunk manifests below anyway.
            }
            candidates.extend(ordered.into_iter().flatten());
        }
        // The store is live again the moment recovery starts: a stale
        // snapshot must not be trusted after new writes land.
        remove_durable(&data_dir.join(CLEAN_MARKER));

        // Verify each candidate against the recovered chunk stores.
        // Scratch discard follows the *creating* store's registry (the
        // `hints=` field store.meta records): if that store treated
        // `Lifetime=scratch` as transient, reopening with a different
        // registry (`--no-hints`) must not resurrect those files — and
        // a DSS-created store's scratch tags were inert, so its files
        // are ordinary durable data and are kept.
        let hints_on = creator_hints.unwrap_or_else(|| registry.hints_enabled());
        let mut kept: Vec<(String, FileMeta)> = Vec::new();
        for (path, mut meta) in candidates {
            max_id = max_id.max(meta.id.0);
            if hints_on && meta.tags.lifetime() == Lifetime::Scratch {
                report.scratch_discarded += 1;
                continue;
            }
            let mut whole = true;
            for (idx, chunk) in meta.chunks.iter_mut().enumerate() {
                let key = (meta.id, idx as u64);
                chunk
                    .replicas
                    .retain(|h| h.0 < n_nodes && file_backends[h.0].contains(key));
                if chunk.replicas.is_empty() {
                    whole = false;
                    break;
                }
            }
            if whole {
                report.files_recovered += 1;
                report.bytes_recovered += meta.size;
                kept.push((path, meta));
            } else {
                report.files_dropped += 1;
            }
        }

        // Sweep chunks no surviving file claims (scratch remnants,
        // dropped files, chunks of pruned holders nothing references).
        let mut claimed: Vec<HashSet<(FileId, u64)>> = vec![HashSet::new(); n_nodes];
        for (_, meta) in &kept {
            for (idx, chunk) in meta.chunks.iter().enumerate() {
                for holder in &chunk.replicas {
                    claimed[holder.0].insert((meta.id, idx as u64));
                }
            }
        }
        let mut unclaimed = 0usize;
        for (i, b) in file_backends.iter().enumerate() {
            for key in b.chunk_keys() {
                max_id = max_id.max(key.0 .0);
                if !claimed[i].contains(&key) {
                    b.delete(key);
                    unclaimed += 1;
                }
            }
        }
        report.chunks_recovered = backend_rec.chunks_recovered - unclaimed;
        report.chunks_dropped = backend_rec.torn_records
            + backend_rec.corrupt_chunks
            + backend_rec.orphan_files
            + unclaimed;

        // Compact the journal to the surviving truth, so dels and torn
        // tails reset here and the next crash replays clean.
        let mut compacted = String::new();
        for (path, meta) in &kept {
            compacted.push_str(&encode_create(path, meta));
            compacted.push('\n');
        }
        write_durable(&data_dir.join(NAMESPACE_LOG), &compacted)?;
        let journal = std::fs::OpenOptions::new()
            .append(true)
            .open(data_dir.join(NAMESPACE_LOG))
            .map_err(|e| StorageError::Invalid(format!("reopen namespace journal: {e}")))?;

        // Rebuild the live structures around the recovered state. The
        // fault decorator (if any) wraps inside `assemble`, *after*
        // bottom-up verification — which must see the honest disk.
        let n_stripes = tuning.stripes.max(1);
        let mut nodes: Vec<NodeState> = (0..n_nodes)
            .map(|i| NodeState {
                node: NodeId(i),
                capacity,
                used: 0,
            })
            .collect();
        let mut stripes: Vec<NamespaceShard> =
            (0..n_stripes).map(|_| NamespaceShard::default()).collect();
        let mut recovered_ids = HashSet::new();
        for (path, meta) in kept {
            for (idx, chunk) in meta.chunks.iter().enumerate() {
                let bytes = meta.chunk_bytes(idx as u64);
                for holder in &chunk.replicas {
                    nodes[holder.0].used += bytes;
                }
            }
            recovered_ids.insert(meta.id);
            stripes[shard_for_path(&path, n_stripes)]
                .files
                .insert(path, meta);
        }

        Ok(LiveStore::assemble(
            StoreParts {
                registry,
                n_nodes,
                capacity,
                backends: file_backends,
                backend_kind,
                data_root: Some(data_dir.to_path_buf()),
                journal: Some(Mutex::new(AppendLog::new(journal))),
                dir_guard: None,
                stripes: Some(stripes),
                nodes: Some(nodes),
                next_id: max_id + 1,
                recovered_ids,
                recovery: Some(report),
            },
            &tuning,
        ))
    }

    /// Clean shutdown: drain background replication, then persist the
    /// namespace — a per-stripe snapshot (`ns-stripe<k>.snap`) plus the
    /// `CLEAN` marker [`LiveStore::reopen`] trusts. Unlike the journal
    /// (create-time records), the snapshot captures the namespace *as
    /// it is now*: post-create `set_xattr`s and consumer countdowns
    /// included. Intended as the store's last act before drop — any
    /// later namespace mutation invalidates the marker and the next
    /// reopen falls back to journal salvage. No-op on the memory
    /// backend.
    pub fn shutdown(&self) {
        self.flush_replication();
        let Some(root) = &self.data_root else { return };
        if self.journal.is_none() {
            return;
        }
        // Freeze the namespace for the whole snapshot + marker write:
        // every stripe lock is held at once, so a concurrent create or
        // delete cannot land in an already-snapshotted stripe and then
        // be vouched for by a CLEAN marker that never saw it (the next
        // snapshot-path reopen would silently lose that durable file).
        // Writers simply block on their stripe until shutdown is done;
        // the marker flag is set before the locks drop, so the first
        // post-shutdown mutation invalidates the marker.
        let guards: Vec<_> = (0..self.stripes.len()).map(|k| self.lock_stripe(k)).collect();
        for (k, stripe) in guards.iter().enumerate() {
            let mut snap = String::new();
            for (path, meta) in &stripe.files {
                snap.push_str(&encode_create(path, meta));
                snap.push('\n');
            }
            if write_durable(&root.join(format!("ns-stripe{k}.snap")), &snap).is_err() {
                return; // no marker ⇒ reopen uses journal salvage
            }
        }
        if write_durable(
            &root.join(CLEAN_MARKER),
            &format!("stripes={}\n", guards.len()),
        )
        .is_ok()
        {
            self.clean_marker.store(true, Ordering::Release);
        }
    }

    /// What the reopen that built this store recovered (`None` for a
    /// fresh store).
    pub fn recovery_report(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// Did `path` survive a restart into this store instance? (The
    /// per-file half of the `recovered=` bottom-up field.)
    pub fn was_recovered(&self, path: &str) -> bool {
        let stripe = self.lock_stripe(self.stripe_of(path));
        stripe
            .files
            .get(path)
            .is_some_and(|m| self.recovered_ids.read().unwrap().contains(&m.id))
    }

    /// Append one namespace-journal record (and, first, invalidate any
    /// clean-shutdown marker — the snapshots are stale the moment the
    /// namespace mutates). `sync` forces the record to disk before
    /// returning: the durability point of a `create`'s publish.
    fn journal_append(&self, record: &str, sync: bool) -> Result<(), StorageError> {
        let Some(journal) = &self.journal else {
            return Ok(());
        };
        self.invalidate_clean();
        let mut j = journal.lock().unwrap();
        j.append(&format!("{record}\n"), sync)
            .map_err(|e| StorageError::Invalid(format!("namespace journal: {e}")))
    }

    /// Invalidate any clean-shutdown marker: the snapshots it vouches
    /// for are stale the moment the namespace mutates — creates and
    /// deletes (via the journal), but also bare tag mutations, which
    /// the journal does not record and only a snapshot could restore.
    fn invalidate_clean(&self) {
        if self.clean_marker.swap(false, Ordering::AcqRel) {
            if let Some(root) = &self.data_root {
                remove_durable(&root.join(CLEAN_MARKER));
            }
        }
    }

    /// WOSS deployment (full hint registry, default tuning).
    pub fn woss(n_nodes: usize) -> Self {
        LiveStore::new(Registry::woss(), n_nodes, u64::MAX / 2)
    }

    /// WOSS deployment with explicit stripe / worker counts.
    pub fn woss_tuned(n_nodes: usize, stripes: usize, repl_workers: usize) -> Self {
        LiveStore::with_tuning(
            Registry::woss(),
            n_nodes,
            u64::MAX / 2,
            LiveTuning {
                stripes,
                repl_workers,
                ..LiveTuning::default()
            },
        )
    }

    /// WOSS deployment with full [`LiveTuning`] (cache tier, lifetime
    /// enforcement) over effectively unbounded node capacity.
    pub fn woss_with(n_nodes: usize, tuning: LiveTuning) -> Self {
        LiveStore::with_tuning(Registry::woss(), n_nodes, u64::MAX / 2, tuning)
    }

    /// DSS baseline deployment (default tuning).
    pub fn dss(n_nodes: usize) -> Self {
        LiveStore::new(Registry::baseline(), n_nodes, u64::MAX / 2)
    }

    /// DSS baseline deployment with explicit stripe / worker counts.
    pub fn dss_tuned(n_nodes: usize, stripes: usize, repl_workers: usize) -> Self {
        LiveStore::with_tuning(
            Registry::baseline(),
            n_nodes,
            u64::MAX / 2,
            LiveTuning {
                stripes,
                repl_workers,
                ..LiveTuning::default()
            },
        )
    }

    /// Number of storage nodes.
    pub fn n_nodes(&self) -> usize {
        self.stores.len()
    }

    /// Which chunk backend this deployment runs on.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend_kind
    }

    /// Root of the disk backend's per-node directories (`None` on the
    /// memory backend).
    pub fn data_dir(&self) -> Option<&Path> {
        self.data_root.as_deref()
    }

    /// Bytes held by each node's chunk backend (authoritative tier
    /// only — cache-resident dirty chunks are not backend bytes).
    pub fn backend_used_bytes(&self) -> Vec<u64> {
        self.stores.iter().map(|s| s.used_bytes()).collect()
    }

    /// Chunks held by each node's chunk backend.
    pub fn backend_chunk_counts(&self) -> Vec<usize> {
        self.stores.iter().map(|s| s.chunk_count()).collect()
    }

    /// Bottom-up consistency audit: cross-reference the namespace's
    /// claims against the placement core's usage accounting and each
    /// backend's physical contents. Flushes background replication
    /// first (a queued copy is not drift), then freezes the namespace
    /// for a consistent snapshot. Dirty cache-resident chunks (scratch
    /// that skipped the spill) count as present on their holder.
    pub fn audit(&self) -> StoreAudit {
        self.flush_replication();
        let guards: Vec<_> = (0..self.stripes.len()).map(|k| self.lock_stripe(k)).collect();
        let n = self.stores.len();
        let mut files = 0usize;
        let mut replicas_claimed = 0usize;
        let mut claimed_bytes = vec![0u64; n];
        let mut claimed_keys: Vec<HashSet<ChunkKey>> = vec![HashSet::new(); n];
        for stripe in &guards {
            for meta in stripe.files.values() {
                files += 1;
                for (idx, chunk) in meta.chunks.iter().enumerate() {
                    let bytes = meta.chunk_bytes(idx as u64);
                    for holder in &chunk.replicas {
                        replicas_claimed += 1;
                        claimed_bytes[holder.0] += bytes;
                        claimed_keys[holder.0].insert((meta.id, idx as u64));
                    }
                }
            }
        }
        let accounted_bytes: Vec<u64> = {
            let core = self.lock_core();
            core.nodes.iter().map(|n| n.used).collect()
        };
        let mut backend_bytes = vec![0u64; n];
        let mut stray_chunks = 0usize;
        let mut missing_chunks = 0usize;
        for (i, store) in self.stores.iter().enumerate() {
            backend_bytes[i] = store.used_bytes();
            let present: HashSet<ChunkKey> = store.chunk_keys().into_iter().collect();
            stray_chunks += present.difference(&claimed_keys[i]).count();
            for key in claimed_keys[i].difference(&present) {
                let dirty = self
                    .cache
                    .as_ref()
                    .is_some_and(|c| c.contains_dirty(NodeId(i), *key));
                if !dirty {
                    missing_chunks += 1;
                }
            }
        }
        drop(guards);
        StoreAudit {
            files,
            replicas_claimed,
            claimed_bytes,
            accounted_bytes,
            backend_bytes,
            stray_chunks,
            missing_chunks,
        }
    }

    /// Number of namespace lock stripes.
    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    fn stripe_of(&self, path: &str) -> usize {
        shard_for_path(path, self.stripes.len())
    }

    /// Lock namespace stripe `idx`, registering the hold with the
    /// debug lock-scope guard — every stripe acquisition goes through
    /// here, so any code path that reaches backend chunk I/O with a
    /// namespace lock held trips [`lockscope::assert_unlocked`] in
    /// debug builds instead of shipping the stall.
    fn lock_stripe(&self, idx: usize) -> StripeGuard<'_> {
        let token = lockscope::token();
        StripeGuard {
            stripe: self.stripes[idx].lock().unwrap(),
            _token: token,
        }
    }

    /// Lock the placement core with lock-scope tracking (see
    /// [`Self::lock_stripe`]).
    fn lock_core(&self) -> CoreGuard<'_> {
        let token = lockscope::token();
        CoreGuard {
            core: self.core.lock().unwrap(),
            _token: token,
        }
    }

    /// Failure injection: mark a node dead. Chunks it held are only
    /// recoverable through replicas on surviving nodes — the
    /// reliability rationale behind the lazy-chained replication policy.
    pub fn kill_node(&self, node: NodeId) {
        self.dead.write().unwrap()[node.0] = true;
    }

    /// Revive a node (its chunk store contents survive the outage).
    pub fn revive_node(&self, node: NodeId) {
        self.dead.write().unwrap()[node.0] = false;
    }

    /// Is the node currently alive?
    pub fn is_alive(&self, node: NodeId) -> bool {
        !self.dead.read().unwrap()[node.0]
    }

    /// Take `node` out of service **live** — the churn half the
    /// reliability story was missing: until this PR, lost holders were
    /// only pruned at reopen. `fail_node` marks the node dead, zeroes
    /// its placement capacity (so no new chunk lands there), prunes it
    /// from every chunk's holder list, and queues background
    /// re-replication of each pruned chunk from a surviving holder
    /// onto a replacement target — all without a reopen. The
    /// [`LiveStore::under_replicated`] gauge counts chunks whose
    /// restore has not landed yet; [`LiveStore::flush_replication`] is
    /// the barrier that drains it to zero.
    ///
    /// A chunk whose *only* holder is the failed node keeps its claim:
    /// there is no surviving source to copy from, so the store treats
    /// the node as in outage (reads fail until
    /// [`LiveStore::join_node`] brings it back) rather than silently
    /// dropping the file.
    ///
    /// Returns the number of restore jobs queued.
    pub fn fail_node(&self, node: NodeId) -> usize {
        self.kill_node(node);
        // Socket mode: the kill is real — tell the supervisor to take
        // the actual daemon process down before re-replication starts
        // copying from the survivors.
        if let Some(sup) = self.supervisor.read().unwrap().clone() {
            sup.node_down(node.0);
        }
        {
            let mut core = self.lock_core();
            core.nodes[node.0].capacity = 0;
        }
        let mut jobs: Vec<ReplJob> = Vec::new();
        for k in 0..self.stripes.len() {
            let mut stripe = self.lock_stripe(k);
            // Stripe → core is the store-wide lock order (write_file's
            // placement path); `dead` nests innermost everywhere.
            let mut core = self.lock_core();
            let dead = self.dead.read().unwrap();
            for meta in stripe.files.values_mut() {
                let file = meta.id;
                let sizes: Vec<u64> = (0..meta.chunks.len())
                    .map(|i| meta.chunk_bytes(i as u64))
                    .collect();
                for (idx, chunk) in meta.chunks.iter_mut().enumerate() {
                    let Some(pos) = chunk.replicas.iter().position(|&h| h == node) else {
                        continue;
                    };
                    if chunk.replicas.len() == 1 {
                        continue; // sole holder: outage, not loss
                    }
                    let bytes = sizes[idx];
                    chunk.replicas.remove(pos);
                    if let Some(n) = core.nodes.iter_mut().find(|n| n.node == node) {
                        n.used = n.used.saturating_sub(bytes);
                    }
                    // Replacement holder: live, not already holding
                    // this chunk, with room. Static mode takes
                    // least-loaded by bytes; adaptive prices the
                    // candidates with the same write-cost formula
                    // placement uses, so repair traffic also steers
                    // around slow or queue-deep nodes.
                    let candidates: Vec<&NodeState> = core
                        .nodes
                        .iter()
                        .filter(|n| {
                            !dead[n.node.0]
                                && !chunk.replicas.contains(&n.node)
                                && n.used + bytes <= n.capacity
                        })
                        .collect();
                    let target = if self.adaptive {
                        candidates
                            .iter()
                            .copied()
                            .min_by(|&a, &b| {
                                let ca = write_cost(
                                    a,
                                    &self.loads[a.node.0],
                                    self.stores[a.node.0].io_depth(),
                                );
                                let cb = write_cost(
                                    b,
                                    &self.loads[b.node.0],
                                    self.stores[b.node.0].io_depth(),
                                );
                                ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal)
                            })
                            .map(|n| n.node)
                    } else {
                        candidates.iter().min_by_key(|n| n.used).map(|n| n.node)
                    };
                    let Some(target) = target else {
                        continue; // no room anywhere: stay degraded
                    };
                    if let Some(n) = core.nodes.iter_mut().find(|n| n.node == target) {
                        n.used += bytes;
                    }
                    let sources = chunk.replicas.clone();
                    chunk.replicas.push(target);
                    jobs.push(ReplJob {
                        file,
                        chunk: idx as u64,
                        work: ReplWork::Restore { sources, target },
                    });
                }
            }
        }
        // Holder lists changed: any clean-shutdown snapshot is stale.
        self.invalidate_clean();
        let queued = jobs.len();
        self.repl
            .shared
            .restore_pending
            .fetch_add(queued as u64, Ordering::Relaxed);
        // Enqueue outside every namespace lock — enqueue blocks on
        // backpressure, and a worker draining the queue may need locks
        // of its own.
        for job in jobs {
            self.repl.enqueue(job);
        }
        queued
    }

    /// Bring a failed node back into service: sweep chunks it still
    /// physically holds that no surviving file claims from it (they
    /// were re-replicated elsewhere, or their file died, while the node
    /// was gone), restore its placement capacity, and mark it alive.
    /// Returns the number of stale chunks swept.
    pub fn join_node(&self, node: NodeId) -> usize {
        // Socket mode: the daemon must actually be serving again
        // before the node re-enters placement — respawn it (with
        // `--reopen` salvage on persistent backends) and wait for its
        // readiness probe. If the process cannot come back, the node
        // stays dead rather than re-admitting a black hole.
        if let Some(sup) = self.supervisor.read().unwrap().clone() {
            if let Err(why) = sup.node_up(node.0) {
                eprintln!("join_node(n{}): supervisor could not restart: {why}", node.0);
                return 0;
            }
        }
        // Freeze the namespace so no create can claim the node (its
        // capacity is still zero, but collocation anchors bypass
        // capacity) while the stale sweep decides what to unlink.
        // Only the *decision* runs under the freeze; the unlinks are
        // disk I/O and run after the guards drop. That is safe: a
        // stale key can never be re-claimed in the gap — FileIds are
        // never reused, and a file created after the freeze places
        // fresh keys, not these.
        let stale: Vec<ChunkKey> = {
            let guards: Vec<_> = (0..self.stripes.len()).map(|k| self.lock_stripe(k)).collect();
            let mut claimed: HashSet<ChunkKey> = HashSet::new();
            for stripe in &guards {
                for meta in stripe.files.values() {
                    for (idx, chunk) in meta.chunks.iter().enumerate() {
                        if chunk.replicas.contains(&node) {
                            claimed.insert((meta.id, idx as u64));
                        }
                    }
                }
            }
            let stale = self.stores[node.0]
                .chunk_keys()
                .into_iter()
                .filter(|key| !claimed.contains(key))
                .collect();
            {
                let mut core = self.lock_core();
                core.nodes[node.0].capacity = self.node_capacity;
            }
            stale
        };
        let swept = stale.len();
        for key in stale {
            self.stores[node.0].delete(key);
        }
        // The sweep may have turned most of the node's segments into
        // garbage; compact before the node serves again.
        self.maintain_backends(std::iter::once(node.0));
        self.revive_node(node);
        swept
    }

    /// Chunks currently below their replica count while churn
    /// re-replication drains — the store-wide gauge `system_status`
    /// reports as ` under_replicated=<n>`. Zero after
    /// [`LiveStore::flush_replication`] (absent further churn).
    pub fn under_replicated(&self) -> u64 {
        self.repl.shared.restore_pending.load(Ordering::Relaxed)
    }

    /// Bytes copied onto replacement holders by churn re-replication
    /// ([`LiveStore::fail_node`]) so far.
    pub fn bytes_rereplicated(&self) -> u64 {
        self.repl.shared.restored_bytes.load(Ordering::Relaxed)
    }

    /// Chunks copied onto replacement holders by churn re-replication.
    pub fn chunks_rereplicated(&self) -> u64 {
        self.repl.shared.restored_chunks.load(Ordering::Relaxed)
    }

    /// The shared fault-injection control block, when this store was
    /// built with [`LiveTuning::fault`] — scenarios flip it off before
    /// their final audit and read the injected-fault counters from it.
    pub fn fault_control(&self) -> Option<Arc<FaultControl>> {
        self.faults.clone()
    }

    /// Barrier over **both** background pools: block until every
    /// queued replication job has landed, then until every I/O-pool
    /// submission (spills, copy/restore puts, promote reads) has
    /// completed. Replication first — its workers are the ones that
    /// submit to the I/O pool, so draining them before the I/O flush
    /// means no new submissions arrive behind the barrier. After this
    /// returns (and absent concurrent writes), every file holds its
    /// full replica count — the determinism hook tests and shutdown
    /// paths rely on.
    /// With [`LiveTuning::flush_timeout_ms`] set, the barrier gives up
    /// at the deadline instead of waiting forever — a wedged worker or
    /// dead remote peer can no longer hang a client on the barrier.
    /// The miss is counted in [`LiveStore::flush_timeouts`].
    pub fn flush_replication(&self) {
        match self.flush_deadline {
            None => {
                self.repl.flush();
                self.io.flush();
            }
            Some(limit) => {
                if !self.try_flush_replication(limit) {
                    self.flush_timeouts.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// [`LiveStore::flush_replication`] with an explicit deadline:
    /// both pools are drained against the same budget. Returns `true`
    /// when everything landed, `false` on a deadline miss (the store
    /// stays consistent — jobs keep draining in the background, the
    /// barrier just stops waiting). Does **not** bump the
    /// [`LiveStore::flush_timeouts`] counter; callers decide what a
    /// miss means.
    pub fn try_flush_replication(&self, limit: Duration) -> bool {
        let deadline = Instant::now() + limit;
        self.repl.flush_deadline(deadline) && self.io.flush_deadline(deadline)
    }

    /// Flush barriers that hit their [`LiveTuning::flush_timeout_ms`]
    /// deadline before the background pools drained.
    pub fn flush_timeouts(&self) -> u64 {
        self.flush_timeouts.load(Ordering::Relaxed)
    }

    /// Attach the process supervisor for socket mode: from now on
    /// [`LiveStore::fail_node`] takes the real daemon down and
    /// [`LiveStore::join_node`] respawns it before re-admitting the
    /// node.
    pub fn attach_supervisor(&self, sup: Arc<dyn NodeSupervisor>) {
        *self.supervisor.write().unwrap() = Some(sup);
    }

    /// Queued + executing submissions on the I/O pool right now — the
    /// ` io_queue=<depth>` gauge `system_status` serves bottom-up.
    pub fn io_queue_depth(&self) -> usize {
        self.io.pending()
    }

    /// Replica chunk copies completed by the background pool so far.
    pub fn background_copies(&self) -> u64 {
        self.repl.shared.copied.load(Ordering::Relaxed)
    }

    /// Queued + in-flight background replication jobs (diagnostics).
    pub fn pending_replication(&self) -> usize {
        self.repl.pending()
    }

    /// Does every replica holder of every chunk of `path` hold the
    /// chunk's bytes right now? (`false` while optimistic replication
    /// is still draining; always `true` after [`Self::flush_replication`].)
    pub fn fully_replicated(&self, path: &str) -> Result<bool, StorageError> {
        let meta = {
            let stripe = self.lock_stripe(self.stripe_of(path));
            stripe
                .files
                .get(path)
                .cloned()
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            for holder in &chunk.replicas {
                let key = (meta.id, idx as u64);
                // A dirty cache entry is the holder's copy for a
                // scratch chunk that skipped the spill — it counts.
                // Cache first: a dirty victim stays resident in
                // `Spilling` state until its write-back lands (the
                // spiller drops the mutex across the disk write but
                // only removes the entry afterwards), so a cache miss
                // means any spill has already landed in the backend
                // (backend-first would transiently report false
                // mid-eviction).
                let present = self
                    .cache
                    .as_ref()
                    .is_some_and(|c| c.contains_dirty(*holder, key))
                    || self.stores[holder.0].contains(key);
                if !present {
                    return Ok(false);
                }
            }
        }
        Ok(true)
    }

    /// Set an extended attribute (top-down channel). Works before the
    /// file exists — the runtime tags outputs ahead of execution.
    pub fn set_xattr(&self, path: &str, key: &str, value: &str) {
        self.setattr_ops.fetch_add(1, Ordering::Relaxed);
        {
            let mut stripe = self.lock_stripe(self.stripe_of(path));
            if let Some(meta) = stripe.files.get_mut(path) {
                meta.tags.set(key, value);
            } else {
                stripe
                    .pending_tags
                    .entry(path.to_string())
                    .or_default()
                    .set(key, value);
            }
        }
        // Tag mutations are namespace mutations the journal does not
        // record — only a snapshot could restore them, so a snapshot
        // written before this mutation must stop being trusted.
        // Invalidating *after* the mutation (and after the stripe lock,
        // which a concurrent shutdown holds across its marker write)
        // guarantees one of: the snapshot saw the mutation, or the
        // marker it wrote is removed here.
        self.invalidate_clean();
    }

    /// Get an extended attribute (bottom-up channel): system-reserved
    /// attributes are served by the registry's providers. Plain user
    /// tags never touch the shared placement core, so getattr traffic
    /// on unrelated files scales with the stripes.
    ///
    /// The reserved `cache_state` attribute is served directly by the
    /// store (node-local cache residency is live-deployment state the
    /// manager-side providers cannot see): its value is
    /// `tier=<mem|disk|seg>;chunks=<copies>;bytes=<n>;pinned=<copies>;recovered=<0|1>`
    /// — the chunk backend uncached bytes live on, the file's cache
    /// residency summed over every node's cache, and whether this file
    /// survived a [`LiveStore::reopen`] into the current instance. The
    /// live store also extends the registry-served `system_status`
    /// with a store-wide ` recovered=<n>` count, so a scheduler can see
    /// how much of the namespace outlived a restart without walking
    /// it, and an ` under_replicated=<n>` gauge — chunks still waiting
    /// on churn re-replication ([`LiveStore::fail_node`]); `0` means
    /// every surviving file holds its full replica count again. A
    /// third gauge, ` io_queue=<d>`, reports submissions queued or
    /// executing on the I/O pool right now
    /// ([`LiveStore::io_queue_depth`]) — `0` means the disk data path
    /// is idle.
    pub fn get_xattr(&self, path: &str, key: &str) -> Option<String> {
        self.getattr_ops.fetch_add(1, Ordering::Relaxed);
        let stripe = self.lock_stripe(self.stripe_of(path));
        let meta = stripe.files.get(path)?;
        if self.registry.hints_enabled() && key == crate::hints::CACHE_STATE_ATTR {
            let (chunks, bytes, pinned) = match &self.cache {
                Some(cache) => cache.file_state(meta.id),
                None => (0, 0, 0),
            };
            let tier = self.backend_kind.label();
            let recovered = u8::from(self.recovered_ids.read().unwrap().contains(&meta.id));
            return Some(format!(
                "tier={tier};chunks={chunks};bytes={bytes};pinned={pinned};recovered={recovered}"
            ));
        }
        // Reserved `heat`: the file's decayed read-popularity score —
        // live deployment state only the store can see (like
        // `cache_state`), served bottom-up so an application can watch
        // the signal that drives adaptive replica widening.
        if self.registry.hints_enabled() && key == crate::hints::HEAT_ATTR {
            return Some(format!("{:.2}", self.heat.peek(path)));
        }
        if self.registry.serves_attr(key) {
            let core = self.lock_core();
            if let Some(value) = self.registry.get_system_attr(key, meta, &core.nodes) {
                if key == crate::hints::SYSTEM_STATUS_ATTR {
                    let mut value = format!(
                        "{value} recovered={} under_replicated={} io_queue={}",
                        self.recovered_ids.read().unwrap().len(),
                        self.under_replicated(),
                        self.io_queue_depth()
                    );
                    // Adaptive only: per-node write-cost scores
                    // (`load=<node>:<score>,...`) so a scheduler can
                    // see the same cost surface placement minimizes.
                    // Gated so the off mode's value stays byte-stable.
                    if self.adaptive {
                        let scores: Vec<String> = core
                            .nodes
                            .iter()
                            .enumerate()
                            .map(|(i, n)| {
                                format!(
                                    "{i}:{:.3}",
                                    write_cost(n, &self.loads[i], self.stores[i].io_depth())
                                )
                            })
                            .collect();
                        value.push_str(&format!(" load={}", scores.join(",")));
                    }
                    return Some(value);
                }
                return Some(value);
            }
        }
        meta.tags.get(key).map(str::to_string)
    }

    /// Replica holders (decision-time view for the scheduler).
    pub fn locations(&self, path: &str) -> Vec<NodeId> {
        if !self.registry.hints_enabled() {
            return Vec::new();
        }
        let stripe = self.lock_stripe(self.stripe_of(path));
        stripe
            .files
            .get(path)
            .map(|m| m.holders())
            .unwrap_or_default()
    }

    /// Stored size of a file.
    pub fn file_size(&self, path: &str) -> Option<u64> {
        let stripe = self.lock_stripe(self.stripe_of(path));
        stripe.files.get(path).map(|m| m.size)
    }

    /// Create + write a file from `client`, dispatching placement
    /// through the registry (pending tags merge in). Returns once the
    /// file is durable per its `RepSmntc` semantics: pessimistic waits
    /// for every replica, optimistic (the default) for the primary copy.
    pub fn write_file(
        &self,
        client: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> Result<(), StorageError> {
        let stripe_idx = self.stripe_of(path);
        let mut stripe = self.lock_stripe(stripe_idx);
        if stripe.files.contains_key(path) {
            return Err(StorageError::AlreadyExists(path.to_string()));
        }
        let mut all_tags = stripe.pending_tags.remove(path).unwrap_or_default();
        for (k, v) in tags.iter() {
            all_tags.set(k, v);
        }
        let size = data.len() as u64;
        let chunk_size = all_tags.block_size().unwrap_or(LIVE_CHUNK);
        let n_chunks = FileMeta::chunk_count(size, chunk_size);
        let factor = self.registry.replication_factor(&all_tags);
        let blocking = factor > 1 && self.registry.replication().blocking(&all_tags);

        // Placement decisions: a short critical section on the shared
        // core (node usage + cursors); the stripe keeps its own
        // round-robin cursor, collocation anchors stay global.
        let chunks = {
            let mut core = self.lock_core();
            let PlacementCore { nodes, placement } = &mut *core;
            let registry = &self.registry;
            let loads = &self.loads;
            let stores = &self.stores;
            let adaptive = self.adaptive;
            placement.with_view(stripe_idx, |state| {
                let mut chunks: Vec<ChunkMeta> = Vec::with_capacity(n_chunks as usize);
                let failed = 'place: {
                    for idx in 0..n_chunks {
                        let (lo, hi) = FileMeta::chunk_span(size, chunk_size, idx);
                        let bytes = hi - lo;
                        let primary = {
                            let mut ctx = PlacementCtx {
                                client,
                                tags: &all_tags,
                                nodes: &*nodes,
                                state: &mut *state,
                            };
                            // Hint policies keep priority in both
                            // modes; adaptive replaces only the
                            // *default* layout — cost-based over the
                            // live load plane instead of blind
                            // round-robin. Costs are recomputed per
                            // chunk: earlier chunks of this very file
                            // shift `used` (and soon the EWMAs), and
                            // the decision should see that.
                            // `io_depth()` under the core lock is
                            // safe: backends serve it from their own
                            // in-flight slot set without touching
                            // store locks or doing I/O.
                            let placed = if adaptive {
                                registry
                                    .place_hinted(&mut ctx, idx, bytes)
                                    .or_else(|| {
                                        let costs: Vec<f64> = ctx
                                            .nodes
                                            .iter()
                                            .enumerate()
                                            .map(|(i, n)| {
                                                write_cost(n, &loads[i], stores[i].io_depth())
                                            })
                                            .collect();
                                        place_cost_based(ctx.nodes, &costs, bytes)
                                    })
                                    .or_else(|| ctx.next_rr(bytes))
                            } else {
                                registry.place_chunk(&mut ctx, idx, bytes)
                            };
                            match placed {
                                Some(node) => node,
                                None => break 'place Some(StorageError::NoSpace(bytes)),
                            }
                        };
                        let replicas = if factor > 1 {
                            let mut rctx = PlacementCtx {
                                client,
                                tags: &all_tags,
                                nodes: &*nodes,
                                state: &mut *state,
                            };
                            registry
                                .replication()
                                .replica_targets(&mut rctx, primary, factor, bytes)
                        } else {
                            Vec::new()
                        };
                        let mut all = vec![primary];
                        all.extend(replicas);
                        for holder in &all {
                            if let Some(n) = nodes.iter_mut().find(|n| n.node == *holder) {
                                n.used += bytes;
                            }
                        }
                        chunks.push(ChunkMeta { replicas: all });
                    }
                    None
                };
                if let Some(err) = failed {
                    // Roll back usage committed by already-placed chunks
                    // so a failed create leaks no capacity.
                    for (idx, chunk) in chunks.iter().enumerate() {
                        let (lo, hi) = FileMeta::chunk_span(size, chunk_size, idx as u64);
                        for holder in &chunk.replicas {
                            if let Some(n) = nodes.iter_mut().find(|n| n.node == *holder) {
                                n.used = n.used.saturating_sub(hi - lo);
                            }
                        }
                    }
                    return Err(err);
                }
                Ok(chunks)
            })?
        };

        let meta = FileMeta {
            id: FileId(self.next_id.fetch_add(1, Ordering::Relaxed)),
            size,
            chunk_size,
            tags: all_tags,
            chunks,
            creator: client,
        };
        stripe.files.insert(path.to_string(), meta.clone());
        // Namespace publish record (disk backend): journaled under the
        // stripe lock so a racing delete's `del` record can only land
        // after it. Not yet fsynced — the sync below is the create's
        // durability point. A create that cannot be journaled cannot
        // keep the durability promise, so it unwinds.
        if self.journal.is_some() {
            if let Err(e) = self.journal_append(&encode_create(path, &meta), false) {
                stripe.files.remove(path);
                drop(stripe);
                self.sweep_file(&meta);
                return Err(e);
            }
        }
        drop(stripe);

        // Data path outside every manager lock: the primary copy lands
        // synchronously; replicas follow per the file's semantics.
        //
        // `Lifetime=scratch` chunks (disk backend, cache tier + lifetime
        // enforcement on) skip the spill: the primary copy goes into the
        // primary node's cache as a *dirty* entry and only reaches the
        // disk if eviction pressure forces a write-back — the hint
        // declares the file dies before durability matters, and the
        // dirty flag keeps it correct when the hint lies.
        let skip_spill = self.scratch_skips_spill(&meta);
        let mut data_err: Option<StorageError> = None;
        'data: for (idx, chunk) in meta.chunks.iter().enumerate() {
            let idx = idx as u64;
            let (lo, hi) = FileMeta::chunk_span(meta.size, meta.chunk_size, idx);
            let payload = &data[lo as usize..hi as usize];
            let key = (meta.id, idx);
            let primary = chunk.primary();
            let mut cached_only = false;
            let started = std::time::Instant::now();
            let load_slot = self.loads[primary.0].begin();
            if skip_spill {
                if let Some(cache) = &self.cache {
                    cached_only = cache.insert_dirty(
                        primary,
                        key,
                        payload.to_vec(),
                        self.cache_class(&meta),
                    );
                }
            }
            if !cached_only {
                if let Err(e) = self.stores[primary.0].put(key, payload) {
                    data_err = Some(e);
                    break 'data;
                }
            }
            // Per-chunk primary-landing latency (µs) — the p50/p95/p99
            // `put_*` percentiles [`LiveStore::cache_stats`] reports,
            // and the per-node put EWMA adaptive placement prices in.
            drop(load_slot);
            let us = started.elapsed().as_secs_f64() * 1e6;
            self.loads[primary.0].observe_put(us);
            self.put_samples.lock().unwrap().record(us);
            let replicas = &chunk.replicas[1..];
            if replicas.is_empty() {
                continue;
            }
            if blocking {
                for holder in replicas {
                    let _slot = self.loads[holder.0].begin();
                    if let Err(e) = self.stores[holder.0].put(key, payload) {
                        data_err = Some(e);
                        break 'data;
                    }
                }
            } else {
                self.replicas_deferred
                    .fetch_add(replicas.len() as u64, Ordering::Relaxed);
                self.repl.enqueue(ReplJob {
                    file: meta.id,
                    chunk: idx,
                    work: ReplWork::Copy {
                        payload: Arc::new(payload.to_vec()),
                        targets: replicas.to_vec(),
                    },
                });
            }
        }
        if let Some(err) = data_err {
            // A backend write failed (disk tier): unwind the create so
            // the failure is atomic — no namespace entry, no capacity,
            // no partial chunks. If a racing delete already removed the
            // entry it also swept, so only the owner frees capacity.
            let ours = {
                let mut stripe = self.lock_stripe(stripe_idx);
                match stripe.files.get(path) {
                    Some(m) if m.id == meta.id => {
                        stripe.files.remove(path);
                        true
                    }
                    _ => false,
                }
            };
            if ours {
                self.sweep_file(&meta);
            } else {
                self.sweep_bytes(&meta);
            }
            return Err(err);
        }
        // A delete racing this create could have removed the meta while
        // the copies above were still landing — it would have found no
        // queued jobs to cancel. Re-check and sweep our own bytes so the
        // race cannot orphan chunks (an id check, so a file re-created
        // at this path after the delete is left untouched).
        let raced_delete = {
            let stripe = self.lock_stripe(stripe_idx);
            stripe.files.get(path).map(|m| m.id) != Some(meta.id)
        };
        if raced_delete {
            self.sweep_bytes(&meta);
        } else {
            // Durability point: the primary copy (and, pessimistic, all
            // replicas) is on its backend with its manifest record
            // fsynced; now the namespace record follows it down. After
            // this line a crash cannot un-create the file.
            self.journal_sync();
        }
        self.bytes_written.fetch_add(size, Ordering::Relaxed);
        Ok(())
    }

    /// Flush the namespace journal to disk (best-effort — a failed
    /// fsync narrows durability, it does not invalidate the in-memory
    /// store).
    fn journal_sync(&self) {
        if let Some(journal) = &self.journal {
            let _ = journal.lock().unwrap().sync();
        }
    }

    /// Read a whole file into a buffer from `client`'s perspective
    /// (locality counted per chunk). Prefers the reader's own store,
    /// then the reader's cache tier, then any live holder that has
    /// materialized the chunk — so reads stay correct while optimistic
    /// replication is still draining. Remote chunks populate the
    /// reader's cache (when the tier is enabled), and a completed read
    /// counts against the file's declared consumers (when lifetime
    /// enforcement is on) — the last declared read reclaims a scratch
    /// file.
    pub fn read_file(&self, client: NodeId, path: &str) -> Result<Vec<u8>, StorageError> {
        let meta = {
            let stripe = self.lock_stripe(self.stripe_of(path));
            stripe
                .files
                .get(path)
                .cloned()
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        let client_alive = self.is_alive(client);
        let mut out = Vec::with_capacity(meta.size as usize);
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            let key = (meta.id, idx as u64);
            let started = std::time::Instant::now();
            // Fail over to a live replica; error only when every holder
            // of the chunk is down.
            let mut live: Vec<NodeId> = chunk
                .replicas
                .iter()
                .copied()
                .filter(|&n| self.is_alive(n))
                .collect();
            // Dedupe, order preserved: a duplicated holder entry (a
            // hand-edited or damaged journal can smuggle one through
            // recovery) must be probed once — probing it twice
            // double-counts `read_errors` on a corrupt source.
            let mut seen = vec![false; self.stores.len()];
            live.retain(|n| !std::mem::replace(&mut seen[n.0], true));
            if live.is_empty() {
                return Err(StorageError::Invalid(format!(
                    "all {} replicas of chunk {idx} of {path} are on dead nodes",
                    chunk.replicas.len()
                )));
            }
            let mut served = false;
            // Which node ended up serving this chunk — its get EWMA
            // absorbs the latency sample below.
            let mut served_by = client;
            // 1. The reader's own backend (authoritative copy).
            if live.contains(&client) {
                if let Some(bytes) = self.backend_read(client, key) {
                    out.extend_from_slice(&bytes);
                    self.local_reads.fetch_add(1, Ordering::Relaxed);
                    self.loads[client.0].record_miss();
                    served = true;
                }
            }
            // 2. The reader's cache tier (still node-local; on the disk
            //    backend this is the hit that skips the disk read, and
            //    where a holder's dirty spill-skipped chunks live).
            if !served && client_alive {
                if let Some(cache) = &self.cache {
                    if let Some(bytes) = cache.get(client, key) {
                        out.extend_from_slice(&bytes);
                        self.local_reads.fetch_add(1, Ordering::Relaxed);
                        self.loads[client.0].record_hit();
                        served = true;
                    }
                }
            }
            // 3. Any live holder that materialized the chunk — its
            //    cache first (a dirty cache-only chunk exists nowhere
            //    else, and a resident chunk served from cache skips the
            //    disk), then its backend. This order is race-free even
            //    though the spiller drops the cache mutex across the
            //    disk write: a dirty victim stays resident (and
            //    readable) in `Spilling` state until its write-back
            //    lands, and is only removed afterwards — so a cache
            //    miss means any spill has already reached the backend.
            //    (Backend-first would open a window where an eviction
            //    lands between the two probes and both miss.) Fill the
            //    reader's cache on the way so the next read is local —
            //    unless the reader is itself a (still-draining) holder,
            //    whose authoritative copy is about to arrive anyway.
            if !served {
                let mut order: Vec<NodeId> =
                    live.iter().copied().filter(|&n| n != client).collect();
                if self.adaptive {
                    // Cheapest live holder first, by read-cost score —
                    // a holder mid-spill or mid-compaction (deep
                    // queue, hot EWMA) stops absorbing reads it is
                    // slow to serve. Stable sort: equal scores keep
                    // the static holder order, so adaptive-off stays
                    // trace-identical and ties stay deterministic.
                    order.sort_by(|&a, &b| {
                        let ca = read_cost(&self.loads[a.0], self.stores[a.0].io_depth());
                        let cb = read_cost(&self.loads[b.0], self.stores[b.0].io_depth());
                        ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal)
                    });
                }
                for source in order {
                    let got = self
                        .cache
                        .as_ref()
                        .and_then(|c| c.peek(source, key))
                        .map(|bytes| (bytes, true))
                        .or_else(|| self.backend_read(source, key).map(|bytes| (bytes, false)));
                    if let Some((bytes, from_cache)) = got {
                        out.extend_from_slice(&bytes);
                        self.remote_reads.fetch_add(1, Ordering::Relaxed);
                        if from_cache {
                            self.loads[source.0].record_hit();
                        } else {
                            self.loads[source.0].record_miss();
                        }
                        served_by = source;
                        if client_alive && !live.contains(&client) {
                            self.cache_insert_current(client, path, key, bytes);
                        }
                        served = true;
                        break;
                    }
                }
            }
            // 4. Re-check the reader's own backend: a holder's dirty
            //    (cache-only) chunk can be spilled by a concurrent
            //    eviction between step 1 (backend miss, not yet
            //    spilled) and step 2 (cache miss, already evicted) —
            //    the entry is only removed once its write-back has
            //    landed, so the bytes are here now.
            if !served && live.contains(&client) {
                if let Some(bytes) = self.backend_read(client, key) {
                    out.extend_from_slice(&bytes);
                    self.local_reads.fetch_add(1, Ordering::Relaxed);
                    self.loads[client.0].record_miss();
                    served = true;
                }
            }
            if !served {
                return Err(StorageError::Invalid(format!(
                    "missing chunk {idx} of {path}"
                )));
            }
            // Per-chunk serve latency (µs) — the p50/p95/p99 `get_*`
            // percentiles [`LiveStore::cache_stats`] reports, and the
            // serving node's get EWMA the read scheduler prices in.
            let us = started.elapsed().as_secs_f64() * 1e6;
            self.loads[served_by.0].observe_get(us);
            self.get_samples.lock().unwrap().record(us);
        }
        self.bytes_read
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        // Popularity: one tracked read. Recording is unconditional —
        // it is cheap and feeds the reserved `heat=` attribute — but
        // *acting* on it (automatic replica widening/trim, the
        // dynamically-derived broadcast hint) is the adaptive plane's
        // call alone.
        let heat = self.heat.record(path);
        if self.adaptive {
            if heat >= HEAT_WIDEN {
                self.maybe_widen(path, &meta);
            } else if heat <= HEAT_TRIM {
                self.maybe_trim(path, &meta);
            }
        }
        if self.lifetime_on
            && self.registry.hints_enabled()
            && meta.tags.consumers().is_some()
        {
            self.consume_one(path, meta.id);
        }
        Ok(out)
    }

    /// Read a chunk from `node`'s backend with the absent/failed
    /// distinction collapsed for the failover path: a failed read
    /// means this holder's copy is lost (the backend counted the fault
    /// — see [`CacheStats::read_errors`]), so the caller moves on to
    /// the next holder exactly as if the chunk were absent. What must
    /// *not* happen is the pre-fix behaviour: the error vanishing
    /// entirely, leaving a disk fault indistinguishable from routine
    /// remote traffic.
    fn backend_read(&self, node: NodeId, key: (FileId, u64)) -> Option<Vec<u8>> {
        self.stores[node.0].get(key).ok().flatten()
    }

    /// Grant `path` one extra replica per chunk: its read heat crossed
    /// [`HEAT_WIDEN`] — the paper's `broadcast` hint, derived
    /// dynamically when the application didn't say it. Targets are the
    /// cheapest live non-holders by write cost; the bytes move through
    /// the same `ReplWork::Restore` machinery churn repair uses, so
    /// backpressure, the `under_replicated` gauge, and
    /// [`LiveStore::flush_replication`] all apply unchanged.
    fn maybe_widen(&self, path: &str, snapshot: &FileMeta) {
        // Claim the file first: concurrent hot readers must not widen
        // twice. The claim is dropped again below if nothing widened.
        if !self.widened.lock().unwrap().insert(snapshot.id) {
            return;
        }
        let mut jobs: Vec<ReplJob> = Vec::new();
        {
            let mut stripe = self.lock_stripe(self.stripe_of(path));
            // The id check skips files re-created at this path since
            // our caller cloned its snapshot.
            if let Some(meta) = stripe.files.get_mut(path).filter(|m| m.id == snapshot.id) {
                let file = meta.id;
                let sizes: Vec<u64> = (0..meta.chunks.len())
                    .map(|i| meta.chunk_bytes(i as u64))
                    .collect();
                // Stripe → core → dead: the store-wide lock order.
                let mut core = self.lock_core();
                let dead = self.dead.read().unwrap();
                for (idx, chunk) in meta.chunks.iter_mut().enumerate() {
                    let bytes = sizes[idx];
                    let target = core
                        .nodes
                        .iter()
                        .filter(|n| {
                            !dead[n.node.0]
                                && !chunk.replicas.contains(&n.node)
                                && n.used + bytes <= n.capacity
                                && n.capacity > 0
                        })
                        .min_by(|a, b| {
                            let ca =
                                write_cost(a, &self.loads[a.node.0], self.stores[a.node.0].io_depth());
                            let cb =
                                write_cost(b, &self.loads[b.node.0], self.stores[b.node.0].io_depth());
                            ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal)
                        })
                        .map(|n| n.node);
                    let Some(target) = target else {
                        continue; // pool full, or every live node already holds it
                    };
                    if let Some(n) = core.nodes.iter_mut().find(|n| n.node == target) {
                        n.used += bytes;
                    }
                    let sources = chunk.replicas.clone();
                    chunk.replicas.push(target);
                    jobs.push(ReplJob {
                        file,
                        chunk: idx as u64,
                        work: ReplWork::Restore { sources, target },
                    });
                }
            }
        }
        if jobs.is_empty() {
            // File gone, re-created, or no node had room: drop the
            // claim so a later heat crossing retries.
            self.widened.lock().unwrap().remove(&snapshot.id);
            return;
        }
        // Holder lists changed: same bookkeeping as churn repair —
        // stale snapshots invalidated, gauge raised *before* the
        // enqueue (the worker always decrements), jobs enqueued
        // outside every namespace lock (enqueue blocks on
        // backpressure).
        self.invalidate_clean();
        self.heat_widened.fetch_add(1, Ordering::Relaxed);
        self.repl
            .shared
            .restore_pending
            .fetch_add(jobs.len() as u64, Ordering::Relaxed);
        for job in jobs {
            self.repl.enqueue(job);
        }
    }

    /// Take back `path`'s extra replica: its heat decayed below
    /// [`HEAT_TRIM`]. Only acts on files [`LiveStore::maybe_widen`]
    /// actually widened, and never while background jobs for the file
    /// are still landing — together with the wide
    /// `HEAT_WIDEN`/`HEAT_TRIM` hysteresis band this keeps the loop
    /// convergent: a replica is removed only once it fully exists and
    /// the file has been cold for a while, so a steady workload's
    /// replica count stabilizes instead of ping-ponging.
    fn maybe_trim(&self, path: &str, snapshot: &FileMeta) {
        if !self.widened.lock().unwrap().contains(&snapshot.id) {
            return;
        }
        if self.repl.has_pending(snapshot.id) {
            return;
        }
        let base = self.registry.replication_factor(&snapshot.tags).max(1) as usize;
        let mut removed: Vec<(NodeId, ChunkKey)> = Vec::new();
        {
            let mut stripe = self.lock_stripe(self.stripe_of(path));
            let Some(meta) = stripe.files.get_mut(path).filter(|m| m.id == snapshot.id) else {
                return;
            };
            let sizes: Vec<u64> = (0..meta.chunks.len())
                .map(|i| meta.chunk_bytes(i as u64))
                .collect();
            let mut core = self.lock_core();
            for (idx, chunk) in meta.chunks.iter_mut().enumerate() {
                while chunk.replicas.len() > base && chunk.replicas.len() > 1 {
                    // The heat replica was pushed last; popping keeps
                    // the primary and the original holders intact.
                    let victim = chunk.replicas.pop().expect("len checked above");
                    if let Some(n) = core.nodes.iter_mut().find(|n| n.node == victim) {
                        n.used = n.used.saturating_sub(sizes[idx]);
                    }
                    removed.push((victim, (meta.id, idx as u64)));
                }
            }
        }
        self.widened.lock().unwrap().remove(&snapshot.id);
        if removed.is_empty() {
            return;
        }
        self.invalidate_clean();
        self.heat_trimmed.fetch_add(1, Ordering::Relaxed);
        // Physical deletes outside every namespace lock; nudge the
        // packed-log backends to compact what just became garbage.
        for (node, key) in &removed {
            self.stores[node.0].delete(*key);
        }
        self.maintain_backends(removed.iter().map(|(n, _)| n.0));
    }

    /// Eviction class for chunks of this file, per its tags. A DSS
    /// baseline (hints disabled) never interprets tags, so everything
    /// is plain durable there — in particular it must never pin, since
    /// the only unpin path (the consumer countdown in
    /// [`Self::consume_one`]) also requires hints. Broadcast pinning
    /// additionally requires lifetime enforcement, which drives the
    /// countdown that releases the pin.
    fn cache_class(&self, meta: &FileMeta) -> CacheClass {
        if !self.registry.hints_enabled() {
            return CacheClass::Durable;
        }
        if self.lifetime_on
            && meta.tags.pattern() == Some(AccessPattern::Broadcast)
            && meta.tags.consumers().is_some()
        {
            return CacheClass::Pinned;
        }
        if meta.tags.lifetime() == Lifetime::Scratch {
            return CacheClass::Scratch;
        }
        CacheClass::Durable
    }

    /// Does this file's primary copy skip the backend spill and live
    /// cache-only (dirty) until reclaimed? Only on a persistent
    /// backend (disk or seg) — the memory backend *is* memory, there
    /// is no spill to skip — and only while the whole scratch contract
    /// is active: a cache to live in, lifetime enforcement driving
    /// reclamation, and a registry that interprets the `Lifetime` tag
    /// at all (a DSS baseline never does).
    fn scratch_skips_spill(&self, meta: &FileMeta) -> bool {
        self.backend_kind.is_persistent()
            && self.cache.is_some()
            && self.lifetime_on
            && self.registry.hints_enabled()
            && meta.tags.lifetime() == Lifetime::Scratch
    }

    /// Cache-fill with the class derived from the file's *current*
    /// metadata. The admission itself runs with **no stripe lock
    /// held** — it can spill a dirty victim to disk, and no store lock
    /// may be held across backend I/O — so instead of deriving the
    /// class atomically with the consumer countdown (the old
    /// stripe-lock-across-insert design), this derives it just before
    /// the insert and re-validates just after, converging on whatever
    /// raced in between: a file deleted or re-created mid-insert has
    /// its entry purged again, and a `Pinned` class that landed after
    /// the last consumer's `unpin_file` pass is demoted so the pin
    /// cannot outlive the fan-out.
    fn cache_insert_current(&self, client: NodeId, path: &str, key: (FileId, u64), bytes: Vec<u8>) {
        let Some(cache) = &self.cache else { return };
        let class = {
            let stripe = self.lock_stripe(self.stripe_of(path));
            let Some(meta) = stripe.files.get(path) else {
                return;
            };
            if meta.id != key.0 {
                return;
            }
            self.cache_class(meta)
        };
        if !cache.insert(client, key, bytes, class) {
            return;
        }
        enum Stale {
            Purge,
            Unpin,
        }
        let stale = {
            let stripe = self.lock_stripe(self.stripe_of(path));
            match stripe.files.get(path) {
                Some(meta) if meta.id == key.0 => (class == CacheClass::Pinned
                    && self.cache_class(meta) != CacheClass::Pinned)
                    .then_some(Stale::Unpin),
                _ => Some(Stale::Purge),
            }
        };
        match stale {
            // The file died (or was re-created) while we inserted: the
            // sweep's purge ran before our entry existed, so remove it
            // ourselves. The entry is clean — nothing else to undo.
            Some(Stale::Purge) => cache.purge_file(key.0),
            Some(Stale::Unpin) => cache.unpin_file(key.0),
            None => {}
        }
    }

    /// One declared consumer read of `path` completed. Decrements the
    /// remaining count (kept in the file's own `Consumers` tag, so the
    /// bottom-up `consumers_left` attribute always reflects it); the
    /// last read reclaims a scratch file entirely and releases a
    /// durable broadcast file's cache pins.
    fn consume_one(&self, path: &str, id: FileId) {
        enum Outcome {
            Reclaim(FileMeta),
            FanOutDone(FileId),
            Pending,
        }
        let outcome = {
            let mut stripe = self.lock_stripe(self.stripe_of(path));
            let info = match stripe.files.get(path) {
                // The id check skips files re-created at this path
                // after a delete raced the read.
                Some(meta) if meta.id == id => Some((meta.tags.consumers(), meta.tags.lifetime())),
                _ => None,
            };
            match info {
                Some((Some(1), Lifetime::Scratch)) => match stripe.files.remove(path) {
                    Some(meta) => Outcome::Reclaim(meta),
                    None => Outcome::Pending,
                },
                Some((Some(n), _)) => {
                    if let Some(meta) = stripe.files.get_mut(path) {
                        let left = n - 1;
                        meta.tags
                            .set(crate::hints::keys::CONSUMERS, &left.to_string());
                        if left == 0 {
                            // Durable broadcast: fan-out complete,
                            // release the cache pins.
                            Outcome::FanOutDone(meta.id)
                        } else {
                            Outcome::Pending
                        }
                    } else {
                        Outcome::Pending
                    }
                }
                _ => Outcome::Pending,
            }
        };
        // The countdown rewrote the file's Consumers tag (or removed
        // the file) — a namespace mutation no journal `create` record
        // captures, so a snapshot written before it is stale. After
        // the stripe lock, same ordering argument as `set_xattr`.
        self.invalidate_clean();
        match outcome {
            Outcome::Reclaim(meta) => {
                self.heat.forget(path);
                self.widened.lock().unwrap().remove(&meta.id);
                self.sweep_file(&meta);
                self.files_reclaimed.fetch_add(1, Ordering::Relaxed);
                self.bytes_reclaimed.fetch_add(meta.size, Ordering::Relaxed);
            }
            Outcome::FanOutDone(file) => {
                if let Some(cache) = &self.cache {
                    // Queued/in-flight promotions still carry the
                    // enqueue-time `Pinned` class; drain them first so
                    // none can land after the unpin pass and stay
                    // pinned forever.
                    self.repl.cancel_promotes(file);
                    cache.unpin_file(file);
                }
            }
            Outcome::Pending => {}
        }
    }

    /// Free `meta`'s capacity, cancel its background jobs, and sweep
    /// its chunks from every store and cache. The caller has already
    /// removed the namespace entry.
    fn sweep_file(&self, meta: &FileMeta) {
        {
            let mut core = self.lock_core();
            for (idx, chunk) in meta.chunks.iter().enumerate() {
                let bytes = meta.chunk_bytes(idx as u64);
                for holder in &chunk.replicas {
                    if let Some(n) = core.nodes.iter_mut().find(|n| n.node == *holder) {
                        n.used = n.used.saturating_sub(bytes);
                    }
                }
            }
        }
        self.sweep_bytes(meta);
    }

    /// Remove every physical trace of `meta`'s chunks: cancel its
    /// queued/in-flight background jobs, purge its cache entries, and
    /// delete its backend chunks. Shared by [`Self::sweep_file`] and
    /// the `write_file` unwind paths, so the ordering below lives in
    /// exactly one place.
    ///
    /// The cache purge MUST precede the backend deletes: a concurrent
    /// eviction could otherwise write a dirty (never-spilled) chunk of
    /// this dying file back to the backend after its delete ran,
    /// orphaning an on-disk file forever. With the entries gone first,
    /// nothing can re-materialize a chunk through the cache, and an
    /// in-flight spill whose `Spilling` entry this purge removed
    /// detects the removal when it completes and deletes its own
    /// backend copy (see `CacheTier::insert_entry`) — so the backend
    /// deletes below are final. Dirty entries are simply dropped: the
    /// file is dead, its bytes owe nothing to the disk.
    fn sweep_bytes(&self, meta: &FileMeta) {
        // Journal the namespace removal first (fsynced): a deleted or
        // reclaimed durable file must not resurrect after a crash.
        // Duplicate `del` records from racing sweeps replay as no-ops.
        // Scratch files under an interpreting registry skip the record
        // entirely — recovery discards them on principle, and the
        // reclamation that triggers most scratch sweeps runs inside
        // `read_file`, where a synchronous journal fsync per reclaimed
        // file would tax exactly the hot path the hint exists to help.
        let scratch_never_replays =
            self.registry.hints_enabled() && meta.tags.lifetime() == Lifetime::Scratch;
        if self.journal.is_some() && !scratch_never_replays {
            let _ = self.journal_append(&format!("del\t{}", meta.id.0), true);
        }
        // A deleted or reclaimed file no longer counts as recovered:
        // `system_status`'s `recovered=` must describe files that
        // still exist, not every file the last reopen ever salvaged.
        self.recovered_ids.write().unwrap().remove(&meta.id);
        self.repl.cancel_file(meta.id);
        if let Some(cache) = &self.cache {
            cache.purge_file(meta.id);
        }
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            for holder in &chunk.replicas {
                // On the disk backend this unlinks the chunk's file —
                // a swept file leaves nothing in the data directory.
                self.stores[holder.0].delete((meta.id, idx as u64));
            }
        }
        self.maintain_backends(
            meta.chunks
                .iter()
                .flat_map(|c| c.replicas.iter().map(|h| h.0)),
        );
    }

    /// Nudge backend maintenance for `nodes` after a sweep freed
    /// bytes: a packed-log backend only returns dead space by
    /// compacting segments, and its threshold check is a cheap atomic
    /// read when nothing is owed (the file-per-chunk and memory
    /// backends are no-ops). Runs through the I/O pool so a real
    /// compaction executes on an I/O worker — off every store lock,
    /// counted in the `io_queue` gauge — and completes before the
    /// sweep returns, so "deleted" means "space reclaimable" to the
    /// caller.
    fn maintain_backends(&self, nodes: impl IntoIterator<Item = usize>) {
        let mut seen = HashSet::new();
        for n in nodes {
            if !seen.insert(n) {
                continue;
            }
            let stores = Arc::clone(&self.stores);
            self.io.run(move || {
                stores[n].maintain();
            });
        }
    }

    /// Promote `path`'s chunks into `client`'s cache off-thread — the
    /// `Pattern=pipeline` optimization: the workflow runtime knows
    /// which node will consume a stage's output next and warms that
    /// node's cache through the background worker pool. Chunks already
    /// resident on the client (holder or cached) are skipped. Returns
    /// the number of promotions queued; `0` when the cache tier is
    /// disabled or hints are off (DSS baseline).
    /// [`LiveStore::flush_replication`] is the barrier that makes the
    /// promotions visible deterministically.
    pub fn prefetch(&self, client: NodeId, path: &str) -> Result<usize, StorageError> {
        let Some(cache) = &self.cache else {
            return Ok(0);
        };
        if !self.registry.hints_enabled() {
            return Ok(0);
        }
        let meta = {
            let stripe = self.lock_stripe(self.stripe_of(path));
            stripe
                .files
                .get(path)
                .cloned()
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        let class = self.cache_class(&meta);
        let mut queued = 0;
        for (idx, chunk) in meta.chunks.iter().enumerate() {
            let key = (meta.id, idx as u64);
            if chunk.replicas.contains(&client) || cache.contains(client, key) {
                continue;
            }
            let sources: Vec<NodeId> = chunk
                .replicas
                .iter()
                .copied()
                .filter(|&n| self.is_alive(n))
                .collect();
            if sources.is_empty() {
                continue;
            }
            self.repl.enqueue(ReplJob {
                file: meta.id,
                chunk: idx as u64,
                work: ReplWork::Promote {
                    sources,
                    target: client,
                    class,
                },
            });
            queued += 1;
        }
        Ok(queued)
    }

    /// Is the hot-chunk cache tier configured?
    pub fn cache_enabled(&self) -> bool {
        self.cache.is_some()
    }

    /// Is scratch-lifetime enforcement on?
    pub fn lifetime_enabled(&self) -> bool {
        self.lifetime_on
    }

    /// Snapshot of the cache tier's counters (all zeros when the tier
    /// is disabled) plus the reclamation counters.
    pub fn cache_stats(&self) -> CacheStats {
        let mut stats = CacheStats::default();
        match &self.cache {
            Some(cache) => cache.fill_stats(&mut stats),
            None => stats.resident = vec![0; self.stores.len()],
        }
        stats.files_reclaimed = self.files_reclaimed.load(Ordering::Relaxed);
        stats.bytes_reclaimed = self.bytes_reclaimed.load(Ordering::Relaxed);
        stats.read_errors = self.stores.iter().map(|s| s.read_errors()).sum();
        (stats.put_p50_us, stats.put_p95_us, stats.put_p99_us) =
            latency_percentiles(&self.put_samples);
        (stats.get_p50_us, stats.get_p95_us, stats.get_p99_us) =
            latency_percentiles(&self.get_samples);
        stats
    }

    /// Is the adaptive load-feedback plane driving placement/read
    /// decisions ([`LiveTuning::adaptive`])?
    pub fn adaptive(&self) -> bool {
        self.adaptive
    }

    /// Live load signals of `node` — the lock-free [`NodeLoad`]
    /// snapshot the adaptive plane reads (EWMA latencies, in-flight
    /// depth, cache hit rate). Always collected, even with adaptive
    /// off.
    pub fn node_load(&self, node: NodeId) -> &NodeLoad {
        &self.loads[node.0]
    }

    /// Current write-cost score of `node` (lower = cheaper placement
    /// target; `inf` for a failed node) — the exact value adaptive
    /// placement minimizes and `system_status` serves as `load=`.
    pub fn node_write_cost(&self, node: NodeId) -> f64 {
        let core = self.lock_core();
        write_cost(
            &core.nodes[node.0],
            &self.loads[node.0],
            self.stores[node.0].io_depth(),
        )
    }

    /// Current read-cost score of `node` (lower = cheaper to serve a
    /// chunk) — the score the adaptive read scheduler sorts live
    /// holders by.
    pub fn node_read_cost(&self, node: NodeId) -> f64 {
        read_cost(&self.loads[node.0], self.stores[node.0].io_depth())
    }

    /// Current decayed read heat of `path` (`0.0` for unknown files) —
    /// the value behind the reserved `heat=` attribute.
    pub fn heat_of(&self, path: &str) -> f64 {
        self.heat.peek(path)
    }

    /// Files granted an automatic extra replica because their read
    /// heat crossed the widen threshold.
    pub fn heat_widened(&self) -> u64 {
        self.heat_widened.load(Ordering::Relaxed)
    }

    /// Widened files whose extra replica was trimmed back after their
    /// heat decayed.
    pub fn heat_trimmed(&self) -> u64 {
        self.heat_trimmed.load(Ordering::Relaxed)
    }

    /// Drop every foreground put/get (and cache spill) latency sample
    /// collected so far. The experiment sweeps call this between
    /// configurations so each row's percentiles describe that row
    /// alone — not the whole run up to it. Counters and EWMAs are
    /// untouched; only the percentile reservoirs reset.
    pub fn reset_latency_samples(&self) {
        self.put_samples.lock().unwrap().reset();
        self.get_samples.lock().unwrap().reset();
        if let Some(cache) = &self.cache {
            cache.spill_samples.lock().unwrap().reset();
        }
    }

    /// Delete a file and free its chunks (including any cached
    /// copies). Queued background copies for the file are cancelled
    /// (and in-flight ones waited out) so a straggler cannot resurrect
    /// swept chunks.
    pub fn delete(&self, path: &str) -> Result<(), StorageError> {
        let meta = {
            let mut stripe = self.lock_stripe(self.stripe_of(path));
            stripe
                .files
                .remove(path)
                .ok_or_else(|| StorageError::NotFound(path.to_string()))?
        };
        // A dead file is cold by definition: a file re-created at this
        // path starts from zero heat, and its widened flag (if any)
        // must not leak onto a future id.
        self.heat.forget(path);
        self.widened.lock().unwrap().remove(&meta.id);
        self.sweep_file(&meta);
        Ok(())
    }

    /// Does the store expose data location?
    pub fn exposes_location(&self) -> bool {
        self.registry.hints_enabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn roundtrip_bytes_exact() {
        let store = LiveStore::woss(4);
        let data: Vec<u8> = (0..600_000u32).map(|i| (i % 251) as u8).collect();
        store
            .write_file(NodeId(1), "/f", &data, &TagSet::new())
            .unwrap();
        let back = store.read_file(NodeId(2), "/f").unwrap();
        assert_eq!(back, data, "bytes must survive the storage path");
        assert_eq!(store.file_size("/f"), Some(600_000));
    }

    #[test]
    fn local_hint_places_all_chunks_on_writer() {
        let store = LiveStore::woss(4);
        let tags = TagSet::from_pairs([("DP", "local")]);
        let data = vec![7u8; 1_000_000];
        store.write_file(NodeId(3), "/local", &data, &tags).unwrap();
        assert_eq!(store.locations("/local"), vec![NodeId(3)]);
        // Reading from the writer is all-local.
        store.read_file(NodeId(3), "/local").unwrap();
        assert!(store.local_reads.load(Ordering::Relaxed) > 0);
        assert_eq!(store.remote_reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn location_attr_via_getxattr() {
        let store = LiveStore::woss(4);
        store.set_xattr("/out", "DP", "local");
        store
            .write_file(NodeId(2), "/out", &[1u8; 1000], &TagSet::new())
            .unwrap();
        let loc = store.get_xattr("/out", "location").unwrap();
        assert_eq!(loc, "n2", "pending tag honored + location exposed");
    }

    #[test]
    fn dss_hides_location_and_ignores_hints() {
        let store = LiveStore::dss(4);
        let tags = TagSet::from_pairs([("DP", "local"), ("Replication", "3")]);
        store
            .write_file(NodeId(1), "/f", &[0u8; 1000], &tags)
            .unwrap();
        assert!(store.locations("/f").is_empty());
        assert_eq!(store.get_xattr("/f", "location"), None);
        assert!(!store.exposes_location());
    }

    #[test]
    fn replication_copies_chunks() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        store
            .write_file(NodeId(0), "/db", &[9u8; 600_000], &tags)
            .unwrap();
        // Optimistic default: replicas drain in the background; the
        // barrier makes the locality assertion deterministic.
        store.flush_replication();
        assert!(store.locations("/db").len() >= 3);
        assert!(store.fully_replicated("/db").unwrap());
        // Replica holders serve a large share of chunk reads locally
        // (replica targets rotate per chunk, so not necessarily all).
        for holder in store.locations("/db") {
            store.read_file(holder, "/db").unwrap();
        }
        let local = store.local_reads.load(Ordering::Relaxed);
        let remote = store.remote_reads.load(Ordering::Relaxed);
        assert!(
            local > remote,
            "replication should localize most reads: {local} local vs {remote} remote"
        );
    }

    #[test]
    fn optimistic_defers_pessimistic_blocks() {
        let store = LiveStore::woss(5);
        let opt = TagSet::from_pairs([("Replication", "3"), ("RepSmntc", "optimistic")]);
        store
            .write_file(NodeId(0), "/opt", &[1u8; 600_000], &opt)
            .unwrap();
        assert!(
            store.replicas_deferred.load(Ordering::Relaxed) > 0,
            "optimistic replicas go through the background pool"
        );
        // Reads are correct even while replication drains: the primary
        // always has the bytes.
        let back = store.read_file(NodeId(4), "/opt").unwrap();
        assert_eq!(back, vec![1u8; 600_000]);
        store.flush_replication();
        assert!(store.fully_replicated("/opt").unwrap());
        assert_eq!(
            store.background_copies(),
            store.replicas_deferred.load(Ordering::Relaxed),
            "flush means every deferred copy landed"
        );

        // Pessimistic: durable on return, nothing deferred.
        let deferred_before = store.replicas_deferred.load(Ordering::Relaxed);
        let pess = TagSet::from_pairs([("Replication", "3"), ("RepSmntc", "pessimistic")]);
        store
            .write_file(NodeId(0), "/pess", &[2u8; 600_000], &pess)
            .unwrap();
        assert!(store.fully_replicated("/pess").unwrap(), "no flush needed");
        assert_eq!(
            store.replicas_deferred.load(Ordering::Relaxed),
            deferred_before,
            "pessimistic writes defer nothing"
        );
    }

    #[test]
    fn stripe_count_one_reproduces_single_lock_store() {
        let store = LiveStore::woss_tuned(4, 1, 1);
        assert_eq!(store.stripe_count(), 1);
        let tags = TagSet::from_pairs([("DP", "local")]);
        let data: Vec<u8> = (0..400_000u32).map(|i| (i % 199) as u8).collect();
        store.write_file(NodeId(2), "/one", &data, &tags).unwrap();
        assert_eq!(store.locations("/one"), vec![NodeId(2)]);
        assert_eq!(store.read_file(NodeId(1), "/one").unwrap(), data);
    }

    #[test]
    fn delete_cancels_background_replication() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        store
            .write_file(NodeId(0), "/gone", &[3u8; 900_000], &tags)
            .unwrap();
        store.delete("/gone").unwrap();
        store.flush_replication();
        // No node backend may hold a chunk of the deleted file: queued
        // jobs were cancelled, in-flight ones waited out before sweep.
        assert_eq!(
            store.backend_chunk_counts().iter().sum::<usize>(),
            0,
            "deleted file left chunks behind"
        );
    }

    #[test]
    fn racing_delete_never_orphans_chunks() {
        // A delete can land between a create's meta publish and its
        // data copies; whichever side sweeps last must leave no bytes
        // behind. Stress the window a few rounds.
        for round in 0..8 {
            let store = Arc::new(LiveStore::woss(4));
            std::thread::scope(|scope| {
                let writer = Arc::clone(&store);
                scope.spawn(move || {
                    let tags = TagSet::from_pairs([("Replication", "3")]);
                    let _ = writer.write_file(NodeId(0), "/r", &[5u8; 700_000], &tags);
                });
                let deleter = Arc::clone(&store);
                scope.spawn(move || loop {
                    match deleter.delete("/r") {
                        Ok(()) => break,
                        Err(_) => std::thread::yield_now(),
                    }
                });
            });
            store.flush_replication();
            assert_eq!(
                store.backend_chunk_counts().iter().sum::<usize>(),
                0,
                "round {round} leaked chunks"
            );
        }
    }

    #[test]
    fn concurrent_writers_and_readers() {
        let store = Arc::new(LiveStore::woss(8));
        let mut handles = Vec::new();
        for w in 0..8usize {
            let st = Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                let data: Vec<u8> = (0..300_000u32)
                    .map(|i| ((i as usize * (w + 1)) % 256) as u8)
                    .collect();
                let tags = TagSet::from_pairs([("DP", "local")]);
                st.write_file(NodeId(w % 8), &format!("/t{w}"), &data, &tags)
                    .unwrap();
                let back = st.read_file(NodeId((w + 1) % 8), &format!("/t{w}")).unwrap();
                assert_eq!(back, data);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.bytes_written.load(Ordering::Relaxed), 8 * 300_000);
    }

    #[test]
    fn failure_injection_replicas_survive() {
        let store = LiveStore::woss(5);
        let tags = TagSet::from_pairs([("Replication", "3")]);
        let data: Vec<u8> = (0..700_000u32).map(|i| (i % 241) as u8).collect();
        store.write_file(NodeId(0), "/db", &data, &tags).unwrap();
        store.flush_replication();
        let holders = store.locations("/db");
        assert!(holders.len() >= 3);
        // Kill one holder: reads must fail over and return exact bytes.
        store.kill_node(holders[0]);
        let back = store.read_file(NodeId(4), "/db").unwrap();
        assert_eq!(back, data, "replica failover must preserve bytes");
        store.revive_node(holders[0]);
    }

    #[test]
    fn failure_injection_unreplicated_file_lost() {
        let store = LiveStore::woss(3);
        store
            .write_file(
                NodeId(1),
                "/single",
                &[7u8; 400_000],
                &TagSet::from_pairs([("DP", "local")]),
            )
            .unwrap();
        store.kill_node(NodeId(1));
        assert!(
            store.read_file(NodeId(0), "/single").is_err(),
            "an unreplicated file on a dead node is unreadable"
        );
        store.revive_node(NodeId(1));
        assert!(
            store.read_file(NodeId(0), "/single").is_ok(),
            "outage, not loss"
        );
    }

    #[test]
    fn delete_frees_chunks() {
        let store = LiveStore::woss(3);
        store
            .write_file(NodeId(0), "/f", &[1u8; 100_000], &TagSet::new())
            .unwrap();
        store.delete("/f").unwrap();
        assert!(store.read_file(NodeId(0), "/f").is_err());
        assert!(store.delete("/f").is_err());
    }

    fn test_loads(n: usize) -> Arc<Vec<NodeLoad>> {
        Arc::new((0..n).map(|_| NodeLoad::default()).collect())
    }

    #[test]
    fn reservoir_bounds_memory_and_resets() {
        let mut r = Reservoir::default();
        for i in 0..(LATENCY_RESERVOIR * 3) {
            r.record(i as f64);
        }
        assert_eq!(r.samples.len(), LATENCY_RESERVOIR, "retention is capped");
        assert_eq!(r.seen, (LATENCY_RESERVOIR * 3) as u64);
        r.reset();
        assert!(r.is_empty());
        assert_eq!(r.seen, 0);
        r.record(7.0);
        assert_eq!(r.samples, vec![7.0], "fills again after reset");
    }

    #[test]
    fn node_load_ewma_inflight_and_hit_rate() {
        let load = NodeLoad::default();
        assert_eq!(load.put_ewma_us(), 0.0);
        load.observe_put(100.0);
        assert_eq!(load.put_ewma_us(), 100.0, "first sample seeds the average");
        load.observe_put(200.0);
        let ewma = load.put_ewma_us();
        assert!(
            ewma > 100.0 && ewma < 200.0,
            "EWMA moves toward the new sample: {ewma}"
        );
        assert_eq!(load.hit_rate(), 0.0, "no serves yet");
        load.record_hit();
        load.record_hit();
        load.record_miss();
        assert!((load.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
        {
            let _slot = load.begin();
            assert_eq!(load.inflight(), 1);
        }
        assert_eq!(load.inflight(), 0, "slot released on drop");
    }

    #[test]
    fn write_cost_prices_pressure_latency_and_depth() {
        let n = NodeState {
            node: NodeId(0),
            capacity: 100,
            used: 50,
        };
        let load = NodeLoad::default();
        let idle = write_cost(&n, &load, 0);
        load.observe_put(2_000.0);
        assert!(write_cost(&n, &load, 0) > idle, "latency raises the cost");
        assert!(
            write_cost(&n, &load, 3) > write_cost(&n, &load, 0),
            "queue depth raises the cost"
        );
        let dead = NodeState {
            node: NodeId(1),
            capacity: 0,
            used: 0,
        };
        assert!(write_cost(&dead, &load, 0).is_infinite());
        let warm = NodeLoad::default();
        warm.record_hit();
        assert!(
            read_cost(&warm, 0) < read_cost(&NodeLoad::default(), 0),
            "a warm cache makes a holder cheaper to read from"
        );
    }

    #[test]
    fn heat_decays_on_the_op_clock_and_forgets() {
        let heat = HeatTracker::new();
        assert_eq!(heat.peek("/f"), 0.0);
        let h1 = heat.record("/f");
        assert!((h1 - 1.0).abs() < 1e-9);
        let h2 = heat.record("/f");
        assert!(h2 > h1, "back-to-back reads accumulate");
        // Unrelated traffic advances the decay clock.
        for i in 0..512 {
            heat.record(&format!("/other{i}"));
        }
        assert!(heat.peek("/f") < h2, "heat decays as other reads tick by");
        heat.forget("/f");
        assert_eq!(heat.peek("/f"), 0.0);
    }

    #[test]
    fn cache_tier_budget_and_eviction_classes() {
        let tier = CacheTier::new(
            2,
            1000,
            CachePolicy::HintAware,
            None,
            Arc::new(IoPool::new(1)),
            test_loads(2),
        );
        let f = FileId(1);
        assert!(tier.insert(NodeId(0), (f, 0), vec![1u8; 400], CacheClass::Durable));
        assert!(tier.insert(NodeId(0), (f, 1), vec![2u8; 400], CacheClass::Scratch));
        // Admitting a third chunk needs room: scratch goes first.
        assert!(tier.insert(NodeId(0), (f, 2), vec![3u8; 400], CacheClass::Durable));
        assert!(tier.get(NodeId(0), (f, 1)).is_none(), "scratch evicted first");
        assert!(tier.get(NodeId(0), (f, 0)).is_some(), "durable survived");
        // A chunk larger than the whole budget is declined outright.
        assert!(!tier.insert(NodeId(0), (f, 3), vec![0u8; 2000], CacheClass::Durable));
        // Pinned entries never evict under the hint-aware policy: the
        // cache declines the newcomer instead.
        let tier = CacheTier::new(
            1,
            500,
            CachePolicy::HintAware,
            None,
            Arc::new(IoPool::new(1)),
            test_loads(1),
        );
        assert!(tier.insert(NodeId(0), (f, 0), vec![1u8; 400], CacheClass::Pinned));
        assert!(!tier.insert(NodeId(0), (f, 1), vec![2u8; 400], CacheClass::Durable));
        assert!(tier.get(NodeId(0), (f, 0)).is_some(), "pin held");
        // Plain LRU is hint-blind: the same pressure evicts the pin.
        let tier = CacheTier::new(
            1,
            500,
            CachePolicy::Lru,
            None,
            Arc::new(IoPool::new(1)),
            test_loads(1),
        );
        assert!(tier.insert(NodeId(0), (f, 0), vec![1u8; 400], CacheClass::Pinned));
        assert!(tier.insert(NodeId(0), (f, 1), vec![2u8; 400], CacheClass::Durable));
        assert!(tier.get(NodeId(0), (f, 0)).is_none(), "LRU ignores pins");
    }

    #[test]
    fn dirty_entries_write_back_on_eviction_and_never_silently_drop() {
        // A tier with a spill target: evicting a dirty entry lands it
        // in the node's backend first.
        let backends: Arc<Vec<Box<dyn ChunkBackend>>> =
            Arc::new(vec![Box::new(MemoryBackend::default())]);
        let tier = CacheTier::new(
            1,
            1000,
            CachePolicy::HintAware,
            Some(Arc::clone(&backends)),
            Arc::new(IoPool::new(1)),
            test_loads(1),
        );
        let f = FileId(7);
        assert!(tier.insert_dirty(NodeId(0), (f, 0), vec![1u8; 600], CacheClass::Scratch));
        assert!(tier.contains_dirty(NodeId(0), (f, 0)));
        assert!(!backends[0].contains((f, 0)), "spill deferred");
        // Pressure evicts the dirty scratch entry: write-back first.
        assert!(tier.insert(NodeId(0), (f, 1), vec![2u8; 600], CacheClass::Durable));
        assert_eq!(
            backends[0].get((f, 0)).unwrap(),
            Some(vec![1u8; 600]),
            "dirty victim written back before eviction"
        );
        assert_eq!(tier.spills.load(Ordering::Relaxed), 1);

        // Without a spill target the tier refuses to evict a dirty
        // entry — the newcomer is declined, the dirty bytes survive.
        let tier = CacheTier::new(
            1,
            1000,
            CachePolicy::HintAware,
            None,
            Arc::new(IoPool::new(1)),
            test_loads(1),
        );
        assert!(tier.insert_dirty(NodeId(0), (f, 0), vec![3u8; 600], CacheClass::Scratch));
        assert!(!tier.insert(NodeId(0), (f, 1), vec![4u8; 600], CacheClass::Durable));
        assert_eq!(tier.peek(NodeId(0), (f, 0)), Some(vec![3u8; 600]));
    }

    use super::super::backend::{chunk_files_under, segment_files_under};

    #[test]
    fn seg_store_packs_reopens_and_reclaims() {
        let dir = std::env::temp_dir().join(format!("woss-store-test-seg-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let data: Vec<u8> = (0..600_000u32).map(|i| (i % 251) as u8).collect();
        {
            let store = LiveStore::with_tuning(
                Registry::woss(),
                3,
                u64::MAX / 2,
                LiveTuning {
                    backend: BackendKind::Seg,
                    data_dir: Some(dir.clone()),
                    ..LiveTuning::default()
                },
            );
            assert_eq!(store.backend_kind(), BackendKind::Seg);
            store
                .write_file(NodeId(1), "/f", &data, &TagSet::from_pairs([("DP", "local")]))
                .unwrap();
            assert_eq!(chunk_files_under(&dir), 0, "no per-chunk files on seg");
            assert!(
                segment_files_under(&dir) >= 1,
                "chunks packed into segment logs"
            );
            assert_eq!(store.read_file(NodeId(2), "/f").unwrap(), data);
            assert_eq!(
                store.get_xattr("/f", "cache_state").unwrap(),
                "tier=seg;chunks=0;bytes=0;pinned=0;recovered=0",
                "no cache tier: bytes live in the segment log"
            );
            // Dirty shutdown: drop without shutdown().
        }
        // store.meta names the backend, so reopen dispatches to
        // segment replay without being told.
        let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
        assert_eq!(store.backend_kind(), BackendKind::Seg);
        assert_eq!(store.read_file(NodeId(0), "/f").unwrap(), data);
        let report = store.recovery_report().unwrap().clone();
        assert_eq!(report.files_recovered, 1);
        assert!(store.was_recovered("/f"));
        store.delete("/f").unwrap();
        assert_eq!(
            store.backend_used_bytes().iter().sum::<u64>(),
            0,
            "delete + segment maintenance returns every byte"
        );
        let audit = store.audit();
        assert!(audit.clean(), "{audit:?}");
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_backend_roundtrips_and_deletes_spilled_files() {
        let dir = std::env::temp_dir().join(format!(
            "woss-store-test-disk-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = LiveStore::with_tuning(
                Registry::woss(),
                3,
                u64::MAX / 2,
                LiveTuning {
                    backend: BackendKind::Disk,
                    data_dir: Some(dir.clone()),
                    ..LiveTuning::default()
                },
            );
            assert_eq!(store.backend_kind(), BackendKind::Disk);
            assert_eq!(store.data_dir(), Some(dir.as_path()));
            let data: Vec<u8> = (0..600_000u32).map(|i| (i % 251) as u8).collect();
            store
                .write_file(NodeId(1), "/f", &data, &TagSet::from_pairs([("DP", "local")]))
                .unwrap();
            assert_eq!(chunk_files_under(&dir), 3, "3 chunks spilled to disk");
            assert_eq!(store.read_file(NodeId(2), "/f").unwrap(), data);
            assert_eq!(
                store.get_xattr("/f", "cache_state").unwrap(),
                "tier=disk;chunks=0;bytes=0;pinned=0;recovered=0",
                "no cache tier: bytes live on disk"
            );
            store.delete("/f").unwrap();
            assert_eq!(chunk_files_under(&dir), 0, "delete unlinks spilled files");
        }
        // The store never deletes a user-supplied data_dir itself.
        assert!(dir.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scratch_skips_the_spill_and_reclaims_without_touching_disk() {
        let dir = std::env::temp_dir().join(format!(
            "woss-store-test-scratch-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = LiveStore::with_tuning(
                Registry::woss(),
                3,
                u64::MAX / 2,
                LiveTuning {
                    backend: BackendKind::Disk,
                    data_dir: Some(dir.clone()),
                    cache_bytes: Some(8 * LIVE_CHUNK),
                    lifetime: true,
                    ..LiveTuning::default()
                },
            );
            let tags = TagSet::from_pairs([
                ("DP", "local"),
                ("Lifetime", "scratch"),
                ("Consumers", "1"),
            ]);
            let data = vec![9u8; 300_000];
            store.write_file(NodeId(0), "/s", &data, &tags).unwrap();
            assert_eq!(
                chunk_files_under(&dir),
                0,
                "scratch chunks live cache-only, no spill"
            );
            assert!(store.fully_replicated("/s").unwrap(), "dirty copy counts");
            // The declared consumer reads the full bytes (remotely,
            // from the primary's cache) and the file dies — the disk
            // was never touched.
            assert_eq!(store.read_file(NodeId(2), "/s").unwrap(), data);
            assert_eq!(store.file_size("/s"), None, "reclaimed after last read");
            assert_eq!(store.cache_stats().files_reclaimed, 1);
            assert_eq!(chunk_files_under(&dir), 0);
            assert_eq!(store.cache_stats().spilled, 0, "no eviction pressure");

            // Under pressure the dirty chunks write back instead of
            // vanishing: a second scratch file plus durable churn
            // overflows the budget, and every byte stays readable.
            let scratch2 = TagSet::from_pairs([("DP", "local"), ("Lifetime", "scratch")]);
            let big = vec![5u8; (6 * LIVE_CHUNK) as usize];
            store.write_file(NodeId(0), "/s2", &big, &scratch2).unwrap();
            let more = vec![6u8; (6 * LIVE_CHUNK) as usize];
            store.write_file(NodeId(0), "/s3", &more, &scratch2).unwrap();
            assert!(
                store.cache_stats().spilled > 0,
                "evicted dirty chunks wrote back to disk"
            );
            assert_eq!(store.read_file(NodeId(1), "/s2").unwrap(), big);
            assert_eq!(store.read_file(NodeId(1), "/s3").unwrap(), more);
            store.delete("/s2").unwrap();
            store.delete("/s3").unwrap();
            assert_eq!(chunk_files_under(&dir), 0, "spilled files removed on delete");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn default_store_has_no_cache_tier() {
        let store = LiveStore::woss(3);
        assert!(!store.cache_enabled());
        assert!(!store.lifetime_enabled());
        let data = vec![1u8; 100_000];
        store
            .write_file(NodeId(0), "/f", &data, &TagSet::from_pairs([("DP", "local")]))
            .unwrap();
        store.read_file(NodeId(1), "/f").unwrap();
        store.read_file(NodeId(1), "/f").unwrap();
        assert_eq!(
            store.remote_reads.load(Ordering::Relaxed),
            2,
            "no cache tier: repeat reads stay remote, exactly as before"
        );
        let stats = store.cache_stats();
        assert_eq!(stats.hits, 0);
        assert!(stats.resident.iter().all(|&r| r == 0));
        assert_eq!(store.prefetch(NodeId(1), "/f").unwrap(), 0);
    }

    #[test]
    fn recovered_count_prunes_on_delete() {
        let dir = std::env::temp_dir().join(format!(
            "woss-store-test-recprune-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = LiveStore::with_tuning(
                Registry::woss(),
                3,
                u64::MAX / 2,
                LiveTuning {
                    backend: BackendKind::Disk,
                    data_dir: Some(dir.clone()),
                    ..LiveTuning::default()
                },
            );
            store
                .write_file(NodeId(0), "/keep", &[1u8; 10_000], &TagSet::new())
                .unwrap();
            store
                .write_file(NodeId(1), "/drop", &[2u8; 10_000], &TagSet::new())
                .unwrap();
            store.flush_replication();
            // Dirty shutdown: no snapshot, both files replay from the
            // journal and count as recovered.
        }
        let store = LiveStore::reopen(Registry::woss(), &dir).unwrap();
        assert!(store.was_recovered("/drop"));
        let status = store.get_xattr("/keep", "system_status").unwrap();
        assert!(status.contains("recovered=2 "), "both salvaged: {status}");

        store.delete("/drop").unwrap();
        // The gauge describes files that still exist, not everything
        // the reopen ever salvaged: the deleted id is pruned.
        let status = store.get_xattr("/keep", "system_status").unwrap();
        assert!(status.contains("recovered=1 "), "pruned on delete: {status}");
        assert!(!store.was_recovered("/drop"));
        // The survivor's per-file flag is untouched.
        assert!(store
            .get_xattr("/keep", "cache_state")
            .unwrap()
            .ends_with(";recovered=1"));
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fail_node_rereplicates_live_without_reopen() {
        let store = LiveStore::woss(4);
        let tags = TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")]);
        let mut expected = Vec::new();
        for f in 0..6u32 {
            let data: Vec<u8> = (0..300_000u32).map(|i| ((i + f) % 251) as u8).collect();
            let path = format!("/r/{f}");
            store
                .write_file(NodeId(f as usize % 4), &path, &data, &tags)
                .unwrap();
            expected.push((path, data));
        }
        store.flush_replication();

        let victim = store.locations("/r/0")[0];
        let queued = store.fail_node(victim);
        assert!(queued > 0, "the victim held replicas to restore");
        // The same barrier the replication pool always had drains the
        // restores — at no point does anything reopen.
        store.flush_replication();
        assert_eq!(store.under_replicated(), 0, "gauge drains to zero");
        assert!(store.bytes_rereplicated() > 0);
        assert_eq!(store.chunks_rereplicated() as usize, queued);

        let reader = (0..4).map(NodeId).find(|&n| store.is_alive(n)).unwrap();
        for (path, data) in &expected {
            assert_eq!(&store.read_file(reader, path).unwrap(), data);
            assert!(store.fully_replicated(path).unwrap(), "{path} restored");
            assert!(
                !store.locations(path).contains(&victim),
                "{path} no longer claims the dead node"
            );
        }

        // Rejoin: the node's now-unclaimed copies are swept, and the
        // bottom-up audit closes with nothing stray or missing.
        let swept = store.join_node(victim);
        assert!(swept > 0, "stale copies swept on rejoin");
        let audit = store.audit();
        assert!(audit.clean(), "audit after churn: {audit:?}");
    }

    #[test]
    fn sole_holder_chunk_survives_outage_and_rejoin() {
        let store = LiveStore::woss(3);
        let data = vec![5u8; 100_000];
        store
            .write_file(NodeId(1), "/solo", &data, &TagSet::from_pairs([("DP", "local")]))
            .unwrap();
        // No surviving source: the claim is kept and nothing is queued
        // — an outage, not data loss.
        assert_eq!(store.fail_node(NodeId(1)), 0);
        assert!(store.read_file(NodeId(0), "/solo").is_err());
        assert_eq!(store.file_size("/solo"), Some(100_000));
        // Rejoining sweeps nothing (the copy is still claimed) and
        // restores service with a clean audit.
        assert_eq!(store.join_node(NodeId(1)), 0);
        assert_eq!(store.read_file(NodeId(0), "/solo").unwrap(), data);
        let audit = store.audit();
        assert!(audit.clean(), "{audit:?}");
    }

    #[test]
    fn fault_tuning_wraps_backends_and_disabling_restores_service() {
        let store = LiveStore::woss_with(
            3,
            LiveTuning {
                fault: Some(FaultSpec {
                    seed: 11,
                    read_error_permille: 1000,
                    ..FaultSpec::default()
                }),
                ..LiveTuning::default()
            },
        );
        let ctl = store.fault_control().expect("fault control wired through");
        let data = vec![9u8; 10_000];
        store
            .write_file(NodeId(0), "/f", &data, &TagSet::from_pairs([("Replication", "2")]))
            .unwrap();
        store.flush_replication();
        // Every backend read fails while injection is armed, so the
        // read exhausts its holders and surfaces the fault.
        assert!(store.read_file(NodeId(2), "/f").is_err());
        assert!(ctl.read_errors() >= 1, "injected errors are counted");
        // Disabling injection restores service: the bytes underneath
        // were stored intact all along.
        ctl.set_enabled(false);
        assert_eq!(store.read_file(NodeId(2), "/f").unwrap(), data);
    }

    #[test]
    fn flush_deadline_bounds_the_barrier_and_counts_misses() {
        // Injected latency makes every backend op sleep 30 ms; a 1 ms
        // barrier budget must give up (and count the miss) instead of
        // hanging, while a generous explicit deadline still drains.
        let store = LiveStore::woss_with(
            3,
            LiveTuning {
                fault: Some(FaultSpec {
                    seed: 5,
                    delay_permille: 1000,
                    delay_us: 30_000,
                    ..FaultSpec::default()
                }),
                flush_timeout_ms: Some(1),
                ..LiveTuning::default()
            },
        );
        let data = vec![7u8; 200_000];
        store
            .write_file(
                NodeId(0),
                "/slow",
                &data,
                &TagSet::from_pairs([("Replication", "2"), ("RepSmntc", "optimistic")]),
            )
            .unwrap();
        // The optimistic replica copy is queued behind a 30 ms sleep;
        // the tuned barrier stops waiting at its deadline.
        store.flush_replication();
        assert!(store.flush_timeouts() >= 1, "deadline miss is counted");
        // The miss left the store consistent — the job kept draining
        // in the background and a generous deadline sees it land.
        assert!(store.try_flush_replication(Duration::from_secs(30)));
        let misses = store.flush_timeouts();
        assert!(store.fully_replicated("/slow").unwrap());
        assert_eq!(store.flush_timeouts(), misses, "try_ variant never counts");
        let audit = store.audit();
        assert!(audit.clean(), "{audit:?}");
    }
}
