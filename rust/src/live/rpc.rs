//! Socket transport for the [`super::proto`] service boundary: real
//! `woss managerd` / `woss noded` daemons over TCP or Unix sockets.
//!
//! Three pieces live here:
//!
//! * **Servers** — [`serve_node`] / [`serve_manager`] accept
//!   connections on an [`RpcAddr`] and speak the framed protocol,
//!   thread-per-connection. A hostile frame gets a typed `Malformed`
//!   reply and the connection is closed; the daemon never panics,
//!   never hangs on a half-open peer (mid-frame reads run under a
//!   deadline), and never leaks the connection.
//! * **Clients** — [`RemoteBackend`] is a [`ChunkBackend`] whose node
//!   lives in another process: every response's `io_depth` trailer
//!   updates the local load signal, so adaptive placement sees remote
//!   queues without extra round-trips. [`RemoteStore`] is the
//!   manager-side client the engine drives through
//!   [`super::proto::ManagerService`].
//! * **[`Cluster`]** — the process supervisor: spawns `woss noded`
//!   daemons, probes them ready, and implements
//!   [`NodeSupervisor`] so [`LiveStore::fail_node`] SIGKILLs the real
//!   process and [`LiveStore::join_node`] brings it back with
//!   `--reopen` salvage on persistent backends.

use super::backend::{BackendKind, ChunkBackend, ChunkKey};
use super::proto::{
    read_at_boundary, read_frame, read_frame_rest, write_frame, ManagerInfo, ManagerRequest,
    ManagerResponse, ManagerService, NodeRequest, NodeResponse, NodeService, ProtoError,
    StoreCounters,
};
use super::store::{CacheStats, LiveStore, NodeSupervisor};
use crate::hints::TagSet;
use crate::storage::types::{NodeId, StorageError};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Deadline for the *rest* of a frame once its first bytes arrived — a
/// peer that goes silent mid-frame is treated as truncated, not waited
/// on forever.
const MID_FRAME_TIMEOUT: Duration = Duration::from_secs(5);

/// Deadline for one client round-trip's response read. Generous: a
/// manager `Flush` barrier legitimately takes a while.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// How long [`Cluster::spawn`] / restart waits for a daemon's Ping.
const READY_TIMEOUT: Duration = Duration::from_secs(10);

/// A daemon endpoint: `unix:/path/to.sock` or `tcp:host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RpcAddr {
    /// A Unix-domain socket path.
    Unix(PathBuf),
    /// A TCP `host:port`.
    Tcp(String),
}

impl FromStr for RpcAddr {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err("empty unix socket path".into());
            }
            Ok(RpcAddr::Unix(PathBuf::from(path)))
        } else if let Some(hp) = s.strip_prefix("tcp:") {
            if !hp.contains(':') {
                return Err(format!("tcp address '{hp}' is not host:port"));
            }
            Ok(RpcAddr::Tcp(hp.to_string()))
        } else {
            Err(format!(
                "address '{s}' must be unix:<path> or tcp:<host>:<port>"
            ))
        }
    }
}

impl std::fmt::Display for RpcAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcAddr::Unix(p) => write!(f, "unix:{}", p.display()),
            RpcAddr::Tcp(hp) => write!(f, "tcp:{hp}"),
        }
    }
}

/// One connected socket of either family, with uniform deadline
/// control.
enum Stream {
    /// Unix-domain connection.
    Unix(UnixStream),
    /// TCP connection (`NODELAY` — frames are latency-bound).
    Tcp(TcpStream),
}

impl Stream {
    fn connect(addr: &RpcAddr) -> std::io::Result<Stream> {
        match addr {
            RpcAddr::Unix(path) => Ok(Stream::Unix(UnixStream::connect(path)?)),
            RpcAddr::Tcp(hp) => {
                let s = TcpStream::connect(hp.as_str())?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) {
        match self {
            Stream::Unix(s) => {
                let _ = s.set_read_timeout(t);
            }
            Stream::Tcp(s) => {
                let _ = s.set_read_timeout(t);
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn bind(addr: &RpcAddr) -> std::io::Result<Listener> {
        match addr {
            RpcAddr::Unix(path) => {
                // A previous daemon's socket file would make the bind
                // fail; it names nothing alive (connects would have
                // found it) so replace it.
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                l.set_nonblocking(true)?;
                Ok(Listener::Unix(l))
            }
            RpcAddr::Tcp(hp) => {
                let l = TcpListener::bind(hp.as_str())?;
                l.set_nonblocking(true)?;
                Ok(Listener::Tcp(l))
            }
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Stream::Unix(s))
            }
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
        }
    }
}

/// A running daemon server. Dropping it (or calling
/// [`Server::wait`] after a Shutdown request) stops the accept loop;
/// in-flight connections finish their current frame.
pub struct Server {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    addr: RpcAddr,
}

impl Server {
    /// The address this server listens on.
    pub fn addr(&self) -> &RpcAddr {
        &self.addr
    }

    /// Ask the accept loop to stop (in-flight connections drain).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop exits — i.e. until something sets
    /// the stop flag: [`Server::stop`], drop, or a `Shutdown` request
    /// from a client. This is a daemon main loop's last line.
    pub fn wait(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let RpcAddr::Unix(path) = &self.addr {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// One server read: block at the frame boundary, then finish the frame
/// under [`MID_FRAME_TIMEOUT`].
fn server_read_frame(stream: &mut Stream) -> Result<Vec<u8>, ProtoError> {
    stream.set_read_timeout(None);
    let mut len_bytes = [0u8; 4];
    read_at_boundary(stream, &mut len_bytes)?;
    stream.set_read_timeout(Some(MID_FRAME_TIMEOUT));
    read_frame_rest(stream, len_bytes)
}

/// One connection's reply to one inbound event: the encoded reply
/// frame plus whether to close the connection after sending it. The
/// handler receives framing errors too (`Err` input) so each dialect
/// encodes its *own* `Malformed` variant — the node and manager enums
/// are distinct on the wire.
type ConnReply = (Vec<u8>, bool);

fn serve_loop<H>(addr: RpcAddr, stop: Arc<AtomicBool>, handler: Arc<H>) -> std::io::Result<Server>
where
    H: Fn(Result<Vec<u8>, ProtoError>, &Arc<AtomicBool>) -> ConnReply + Send + Sync + 'static,
{
    let listener = Listener::bind(&addr)?;
    let stop_accept = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("woss-rpc-accept".into())
        .spawn(move || {
            while !stop_accept.load(Ordering::SeqCst) {
                let mut stream = match listener.accept() {
                    Ok(s) => s,
                    Err(_) => {
                        // WouldBlock (nothing pending) or a transient
                        // accept error: poll the stop flag and retry.
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                let handler = Arc::clone(&handler);
                let stop = Arc::clone(&stop_accept);
                // Thread-per-connection; the thread owns the stream
                // and exits on the first framing error or disconnect,
                // so a hostile client costs one closed socket, nothing
                // more.
                let _ = std::thread::Builder::new()
                    .name("woss-rpc-conn".into())
                    .spawn(move || {
                        while !stop.load(Ordering::SeqCst) {
                            let event = match server_read_frame(&mut stream) {
                                Err(ProtoError::Disconnected) => return,
                                other => other,
                            };
                            let was_err = event.is_err();
                            let (payload, close) = handler(event, &stop);
                            if was_err {
                                // Typed error back to the peer (best
                                // effort), then drop the connection —
                                // a malformed stream has no
                                // recoverable framing.
                                let _ = write_frame(&mut stream, &payload);
                                return;
                            }
                            if write_frame(&mut stream, &payload).is_err() || close {
                                return;
                            }
                        }
                    });
            }
        })?;
    Ok(Server {
        stop,
        handle: Some(handle),
        addr,
    })
}

/// Serve a [`NodeService`] on `addr`. Returns once the listener is
/// bound; the accept loop runs until [`Server::stop`] or a client's
/// `Shutdown` request.
pub fn serve_node(addr: RpcAddr, svc: Arc<dyn NodeService>) -> std::io::Result<Server> {
    let stop = Arc::new(AtomicBool::new(false));
    let handler = Arc::new(
        move |event: Result<Vec<u8>, ProtoError>, stop: &Arc<AtomicBool>| {
            let req = match event.and_then(|p| NodeRequest::decode(&p)) {
                Ok(req) => req,
                Err(err) => {
                    return (NodeResponse::Malformed(err).encode(svc.io_depth()), true);
                }
            };
            let shutdown = req == NodeRequest::Shutdown;
            if shutdown {
                stop.store(true, Ordering::SeqCst);
            }
            let resp = svc.handle(req);
            (resp.encode(svc.io_depth()), shutdown)
        },
    );
    serve_loop(addr, stop, handler)
}

/// Serve a [`ManagerService`] on `addr`. A client `Shutdown` request
/// runs the store's clean shutdown, replies `Ok`, and stops the
/// server.
pub fn serve_manager(addr: RpcAddr, svc: Arc<dyn ManagerService>) -> std::io::Result<Server> {
    let stop = Arc::new(AtomicBool::new(false));
    let handler = Arc::new(
        move |event: Result<Vec<u8>, ProtoError>, stop: &Arc<AtomicBool>| {
            let req = match event.and_then(|p| ManagerRequest::decode(&p)) {
                Ok(req) => req,
                Err(err) => return (ManagerResponse::Malformed(err).encode(), true),
            };
            let shutdown = req == ManagerRequest::Shutdown;
            if shutdown {
                stop.store(true, Ordering::SeqCst);
            }
            let resp = super::proto::dispatch_manager(svc.as_ref(), req);
            (resp.encode(), shutdown)
        },
    );
    serve_loop(addr, stop, handler)
}

/// A small pool of connected streams to one daemon. Concurrent callers
/// each pop (or dial) their own connection and return it on success;
/// a failed call's connection is dropped, not pooled.
struct ConnPool {
    addr: RpcAddr,
    idle: Mutex<Vec<Stream>>,
}

impl ConnPool {
    fn new(addr: RpcAddr) -> Self {
        ConnPool {
            addr,
            idle: Mutex::new(Vec::new()),
        }
    }

    /// One framed round-trip. A stale pooled connection (the peer
    /// restarted since it was pooled) fails the first attempt; one
    /// reconnect-and-retry covers it — every request in both dialects
    /// is idempotent, so the retry is safe even if the first attempt's
    /// request landed.
    fn call(&self, request: &[u8]) -> Result<Vec<u8>, ProtoError> {
        let pooled = self.idle.lock().unwrap().pop();
        let retry_budget = if pooled.is_some() { 2 } else { 1 };
        let mut stream = pooled;
        let mut last_err = ProtoError::Disconnected;
        for _ in 0..retry_budget {
            let mut s = match stream.take() {
                Some(s) => s,
                None => match Stream::connect(&self.addr) {
                    Ok(s) => s,
                    Err(e) => return Err(ProtoError::Io(e.to_string())),
                },
            };
            s.set_read_timeout(Some(CLIENT_READ_TIMEOUT));
            match write_frame(&mut s, request).and_then(|()| read_frame(&mut s)) {
                Ok(reply) => {
                    self.idle.lock().unwrap().push(s);
                    return Ok(reply);
                }
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Drop every pooled connection (the peer is known dead).
    fn clear(&self) {
        self.idle.lock().unwrap().clear();
    }
}

/// A [`ChunkBackend`] whose node lives in another process, behind a
/// `woss noded` daemon. Every reply's `io_depth` trailer refreshes the
/// locally cached load signal, so the adaptive plane reads remote
/// queue depth for free. When the daemon is dead (a real
/// `fail_node`), operations degrade the way the churn machinery
/// expects: reads fail over, metadata queries report empty, deletes
/// are deferred to the rejoin sweep.
pub struct RemoteBackend {
    pool: ConnPool,
    /// Last `io_depth` trailer seen from this node.
    last_depth: AtomicU64,
    /// Round-trips that failed against a present daemon — folded into
    /// [`ChunkBackend::read_errors`] alongside what the daemon itself
    /// reports.
    local_errors: AtomicU64,
}

impl RemoteBackend {
    /// A proxy speaking to the node daemon at `addr`.
    pub fn connect(addr: RpcAddr) -> Self {
        RemoteBackend {
            pool: ConnPool::new(addr),
            last_depth: AtomicU64::new(0),
            local_errors: AtomicU64::new(0),
        }
    }

    /// Drop pooled connections (the daemon was killed or restarted).
    pub fn reset_connections(&self) {
        self.pool.clear();
    }

    fn call(&self, req: &NodeRequest) -> Result<NodeResponse, ProtoError> {
        let reply = self.pool.call(&req.encode())?;
        let (resp, depth) = NodeResponse::decode(&reply)?;
        self.last_depth.store(depth, Ordering::Relaxed);
        Ok(resp)
    }
}

impl ChunkBackend for RemoteBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        match self.call(&NodeRequest::Put {
            key,
            bytes: bytes.to_vec(),
        }) {
            Ok(NodeResponse::Ok) => Ok(()),
            Ok(NodeResponse::Err(e)) => Err(e),
            Ok(other) => Err(StorageError::Invalid(format!(
                "unexpected put reply: {other:?}"
            ))),
            Err(e) => Err(StorageError::Invalid(format!("node unreachable: {e}"))),
        }
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        match self.call(&NodeRequest::Get { key }) {
            Ok(NodeResponse::Chunk(c)) => Ok(c),
            Ok(NodeResponse::Err(e)) => Err(e),
            Ok(other) => Err(StorageError::Invalid(format!(
                "unexpected get reply: {other:?}"
            ))),
            Err(e) => {
                // A dead daemon's copy is *lost*, not absent: the read
                // path must fail over to another holder, exactly as for
                // a local disk fault.
                self.local_errors.fetch_add(1, Ordering::Relaxed);
                Err(StorageError::Invalid(format!("node unreachable: {e}")))
            }
        }
    }

    fn delete(&self, key: ChunkKey) {
        // Best effort: a dead daemon's stale chunks are swept by the
        // join_node reconciliation after it restarts.
        let _ = self.call(&NodeRequest::Delete { key });
    }

    fn contains(&self, key: ChunkKey) -> bool {
        matches!(
            self.call(&NodeRequest::Contains { key }),
            Ok(NodeResponse::Bool(true))
        )
    }

    fn used_bytes(&self) -> u64 {
        match self.call(&NodeRequest::Stat) {
            Ok(NodeResponse::Stat { used_bytes, .. }) => used_bytes,
            _ => 0,
        }
    }

    fn chunk_count(&self) -> usize {
        match self.call(&NodeRequest::Stat) {
            Ok(NodeResponse::Stat { chunk_count, .. }) => chunk_count as usize,
            _ => 0,
        }
    }

    fn read_errors(&self) -> u64 {
        let remote = match self.call(&NodeRequest::Stat) {
            Ok(NodeResponse::Stat { read_errors, .. }) => read_errors,
            _ => 0,
        };
        remote + self.local_errors.load(Ordering::Relaxed)
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        match self.call(&NodeRequest::ChunkKeys) {
            Ok(NodeResponse::Keys(keys)) => keys,
            _ => Vec::new(),
        }
    }

    fn maintain(&self) -> bool {
        matches!(
            self.call(&NodeRequest::Maintain),
            Ok(NodeResponse::Bool(true))
        )
    }

    fn io_depth(&self) -> u64 {
        // No round-trip: the trailer on every reply keeps this fresh.
        self.last_depth.load(Ordering::Relaxed)
    }
}

/// The manager-side client: a [`ManagerService`] implementation that
/// frames each call to a `woss managerd` daemon. The engine drives it
/// through [`super::engine::StoreHandle`] exactly as it drives an
/// in-process [`LiveStore`].
pub struct RemoteStore {
    pool: ConnPool,
    info: ManagerInfo,
}

impl RemoteStore {
    /// Connect to `addr` and complete the `Hello` handshake (the
    /// static deployment facts are cached — they never change).
    pub fn connect(addr: RpcAddr) -> Result<Self, StorageError> {
        let pool = ConnPool::new(addr);
        let reply = pool
            .call(&ManagerRequest::Hello.encode())
            .map_err(|e| StorageError::Invalid(format!("manager unreachable: {e}")))?;
        let info = match ManagerResponse::decode(&reply) {
            Ok(ManagerResponse::Info(info)) => info,
            Ok(other) => {
                return Err(StorageError::Invalid(format!(
                    "unexpected hello reply: {other:?}"
                )))
            }
            Err(e) => return Err(StorageError::Invalid(format!("hello failed: {e}"))),
        };
        Ok(RemoteStore { pool, info })
    }

    fn call(&self, req: &ManagerRequest) -> ManagerResponse {
        match self.pool.call(&req.encode()) {
            Ok(reply) => match ManagerResponse::decode(&reply) {
                Ok(resp) => resp,
                Err(e) => ManagerResponse::Err(StorageError::Invalid(format!(
                    "undecodable manager reply: {e}"
                ))),
            },
            Err(e) => {
                ManagerResponse::Err(StorageError::Invalid(format!("manager unreachable: {e}")))
            }
        }
    }

    fn expect_err(resp: ManagerResponse, what: &str) -> StorageError {
        match resp {
            ManagerResponse::Err(e) => e,
            other => StorageError::Invalid(format!("unexpected {what} reply: {other:?}")),
        }
    }
}

impl ManagerService for RemoteStore {
    fn hello(&self) -> ManagerInfo {
        self.info
    }

    fn write_file(
        &self,
        client: NodeId,
        path: &str,
        data: &[u8],
        tags: &TagSet,
    ) -> Result<(), StorageError> {
        match self.call(&ManagerRequest::WriteFile {
            client: client.0 as u64,
            path: path.to_string(),
            tags: tags.clone(),
            data: data.to_vec(),
        }) {
            ManagerResponse::Ok => Ok(()),
            other => Err(Self::expect_err(other, "write")),
        }
    }

    fn read_file(&self, client: NodeId, path: &str) -> Result<Vec<u8>, StorageError> {
        match self.call(&ManagerRequest::ReadFile {
            client: client.0 as u64,
            path: path.to_string(),
        }) {
            ManagerResponse::Bytes(b) => Ok(b),
            other => Err(Self::expect_err(other, "read")),
        }
    }

    fn delete_file(&self, path: &str) -> Result<(), StorageError> {
        match self.call(&ManagerRequest::Delete {
            path: path.to_string(),
        }) {
            ManagerResponse::Ok => Ok(()),
            other => Err(Self::expect_err(other, "delete")),
        }
    }

    fn set_attr(&self, path: &str, key: &str, value: &str) {
        let _ = self.call(&ManagerRequest::SetAttr {
            path: path.to_string(),
            key: key.to_string(),
            value: value.to_string(),
        });
    }

    fn get_attr(&self, path: &str, key: &str) -> Option<String> {
        match self.call(&ManagerRequest::GetAttr {
            path: path.to_string(),
            key: key.to_string(),
        }) {
            ManagerResponse::Attr(a) => a,
            _ => None,
        }
    }

    fn file_size(&self, path: &str) -> Option<u64> {
        match self.call(&ManagerRequest::FileSize {
            path: path.to_string(),
        }) {
            ManagerResponse::Size(s) => s,
            _ => None,
        }
    }

    fn locations(&self, path: &str) -> Vec<NodeId> {
        match self.call(&ManagerRequest::Locations {
            path: path.to_string(),
        }) {
            ManagerResponse::Nodes(ns) => ns.into_iter().map(|n| NodeId(n as usize)).collect(),
            _ => Vec::new(),
        }
    }

    fn prefetch(&self, client: NodeId, path: &str) -> Result<usize, StorageError> {
        match self.call(&ManagerRequest::Prefetch {
            client: client.0 as u64,
            path: path.to_string(),
        }) {
            ManagerResponse::Count(n) => Ok(n as usize),
            other => Err(Self::expect_err(other, "prefetch")),
        }
    }

    fn node_read_cost(&self, node: NodeId) -> f64 {
        match self.call(&ManagerRequest::NodeReadCost {
            node: node.0 as u64,
        }) {
            ManagerResponse::F64(v) => v,
            _ => f64::INFINITY,
        }
    }

    fn flush(&self) {
        let _ = self.call(&ManagerRequest::Flush);
    }

    fn cache_stats(&self) -> CacheStats {
        match self.call(&ManagerRequest::CacheStats) {
            ManagerResponse::Stats(s) => s,
            _ => CacheStats::default(),
        }
    }

    fn counters(&self) -> StoreCounters {
        match self.call(&ManagerRequest::Counters) {
            ManagerResponse::Counters(c) => c,
            _ => StoreCounters::default(),
        }
    }

    fn fail_node(&self, node: NodeId) -> usize {
        match self.call(&ManagerRequest::FailNode {
            node: node.0 as u64,
        }) {
            ManagerResponse::Count(n) => n as usize,
            _ => 0,
        }
    }

    fn join_node(&self, node: NodeId) -> usize {
        match self.call(&ManagerRequest::JoinNode {
            node: node.0 as u64,
        }) {
            ManagerResponse::Count(n) => n as usize,
            _ => 0,
        }
    }

    fn is_alive(&self, node: NodeId) -> bool {
        matches!(
            self.call(&ManagerRequest::IsAlive {
                node: node.0 as u64,
            }),
            ManagerResponse::Bool(true)
        )
    }

    fn backend_used_bytes(&self) -> Vec<u64> {
        match self.call(&ManagerRequest::BackendUsedBytes) {
            ManagerResponse::U64s(v) => v,
            _ => Vec::new(),
        }
    }

    fn shutdown_store(&self) {
        let _ = self.call(&ManagerRequest::Shutdown);
    }
}

/// Probe `addr` with `Ping` until the daemon answers or `deadline`
/// passes.
pub fn wait_ready(addr: &RpcAddr, deadline: Instant) -> Result<(), String> {
    loop {
        if let Ok(mut s) = Stream::connect(addr) {
            s.set_read_timeout(Some(Duration::from_secs(2)));
            let ping = NodeRequest::Ping.encode();
            if write_frame(&mut s, &ping).is_ok() {
                if let Ok(reply) = read_frame(&mut s) {
                    if matches!(NodeResponse::decode(&reply), Ok((NodeResponse::Ok, _))) {
                        return Ok(());
                    }
                }
            }
        }
        if Instant::now() >= deadline {
            return Err(format!("daemon at {addr} not ready in time"));
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Remove-on-drop directory (the cluster's sockets, and its data tree
/// when the caller did not supply one).
struct RmDir(PathBuf);

impl Drop for RmDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

static CLUSTER_SEQ: AtomicU64 = AtomicU64::new(0);

/// One spawned node daemon and what it needs to come back.
struct NodeProc {
    addr: RpcAddr,
    data_dir: Option<PathBuf>,
    child: Option<std::process::Child>,
}

/// The node-tier process supervisor: spawns one `woss noded` per node
/// over Unix sockets, probes them ready, and (as the store's
/// [`NodeSupervisor`]) turns `fail_node` into a real SIGKILL and
/// `join_node` into a respawn — with `--reopen` salvage on persistent
/// backends, exercising the exact recovery path a crashed node takes.
pub struct Cluster {
    nodes: Mutex<Vec<NodeProc>>,
    backend: BackendKind,
    bin: PathBuf,
    sock_dir: RmDir,
    /// Cluster-owned data tree guard (when the caller supplied none);
    /// held only for its Drop.
    owned_data: Option<RmDir>,
}

impl Cluster {
    /// Spawn `n` node daemons on backend `backend`. `data_root`, when
    /// given, hosts one `rnode<i>/` per daemon and survives the
    /// cluster; `None` uses a cluster-owned tempdir (persistent
    /// backends only — the memory backend needs no disk either way).
    /// The daemon binary is `$WOSS_BIN` when set (integration tests
    /// point it at the cargo-built binary), else the current
    /// executable.
    pub fn spawn(
        n: usize,
        backend: BackendKind,
        data_root: Option<&Path>,
    ) -> Result<Arc<Cluster>, String> {
        let bin = match std::env::var_os("WOSS_BIN") {
            Some(p) => PathBuf::from(p),
            None => std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?,
        };
        let seq = CLUSTER_SEQ.fetch_add(1, Ordering::Relaxed);
        let sock_dir = std::env::temp_dir().join(format!(
            "woss-cluster-{}-{seq}",
            std::process::id()
        ));
        std::fs::create_dir_all(&sock_dir).map_err(|e| format!("create {sock_dir:?}: {e}"))?;
        let sock_dir = RmDir(sock_dir);
        let (data_root_path, owned_data) = if backend.is_persistent() {
            match data_root {
                Some(p) => {
                    std::fs::create_dir_all(p).map_err(|e| format!("create {p:?}: {e}"))?;
                    (Some(p.to_path_buf()), None)
                }
                None => {
                    let d = std::env::temp_dir().join(format!(
                        "woss-cluster-data-{}-{seq}",
                        std::process::id()
                    ));
                    std::fs::create_dir_all(&d).map_err(|e| format!("create {d:?}: {e}"))?;
                    (Some(d.clone()), Some(RmDir(d)))
                }
            }
        } else {
            (None, None)
        };
        let cluster = Cluster {
            nodes: Mutex::new(Vec::with_capacity(n)),
            backend,
            bin,
            sock_dir,
            owned_data,
        };
        {
            let mut nodes = cluster.nodes.lock().unwrap();
            for i in 0..n {
                let addr = RpcAddr::Unix(cluster.sock_dir.0.join(format!("node{i}.sock")));
                let data_dir = data_root_path.as_ref().map(|r| r.join(format!("rnode{i}")));
                let child = cluster.launch(&addr, data_dir.as_deref(), false)?;
                nodes.push(NodeProc {
                    addr,
                    data_dir,
                    child: Some(child),
                });
            }
            let deadline = Instant::now() + READY_TIMEOUT;
            for p in nodes.iter() {
                wait_ready(&p.addr, deadline)?;
            }
        }
        Ok(Arc::new(cluster))
    }

    fn launch(
        &self,
        addr: &RpcAddr,
        data_dir: Option<&Path>,
        reopen: bool,
    ) -> Result<std::process::Child, String> {
        let mut cmd = std::process::Command::new(&self.bin);
        cmd.arg("noded")
            .arg("--listen")
            .arg(addr.to_string())
            .arg("--backend")
            .arg(self.backend.label());
        if let Some(d) = data_dir {
            cmd.arg("--data-dir").arg(d);
        }
        if reopen {
            cmd.arg("--reopen");
        }
        cmd.stdin(std::process::Stdio::null());
        cmd.spawn().map_err(|e| format!("spawn noded: {e}"))
    }

    /// Node daemon addresses, in node order.
    pub fn addrs(&self) -> Vec<RpcAddr> {
        self.nodes
            .lock()
            .unwrap()
            .iter()
            .map(|p| p.addr.clone())
            .collect()
    }

    /// The cluster-owned data tree, when [`Cluster::spawn`] created
    /// one (removed when the cluster drops).
    pub fn owned_data_root(&self) -> Option<&Path> {
        self.owned_data.as_ref().map(|d| d.0.as_path())
    }

    /// A [`RemoteBackend`] per node, ready to hand to
    /// [`LiveStore::with_backends`].
    pub fn backends(&self) -> Vec<Box<dyn ChunkBackend>> {
        self.addrs()
            .into_iter()
            .map(|a| Box::new(RemoteBackend::connect(a)) as Box<dyn ChunkBackend>)
            .collect()
    }

    /// The chunk layout the daemons run.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend
    }

    /// The daemon's OS pid, `None` after a kill.
    pub fn pid(&self, node: usize) -> Option<u32> {
        self.nodes.lock().unwrap()[node]
            .child
            .as_ref()
            .map(|c| c.id())
    }

    /// SIGKILL node `i`'s daemon and reap it — a real process death,
    /// not a simulation.
    pub fn kill(&self, node: usize) {
        let mut nodes = self.nodes.lock().unwrap();
        if let Some(mut child) = nodes[node].child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Respawn node `i`'s daemon. Persistent backends come back with
    /// `--reopen` — the manifest/segment salvage path — because their
    /// first launch already created a store in the data dir; the
    /// memory backend restarts empty. Blocks until the daemon answers
    /// its readiness probe.
    pub fn restart(&self, node: usize) -> Result<(), String> {
        let (addr, data_dir) = {
            let mut nodes = self.nodes.lock().unwrap();
            if let Some(mut child) = nodes[node].child.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
            (nodes[node].addr.clone(), nodes[node].data_dir.clone())
        };
        let reopen = self.backend.is_persistent();
        let child = self.launch(&addr, data_dir.as_deref(), reopen)?;
        wait_ready(&addr, Instant::now() + READY_TIMEOUT)?;
        self.nodes.lock().unwrap()[node].child = Some(child);
        Ok(())
    }
}

impl NodeSupervisor for Cluster {
    fn node_down(&self, node: usize) {
        self.kill(node);
    }

    fn node_up(&self, node: usize) -> Result<(), String> {
        self.restart(node)
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        let mut nodes = self.nodes.lock().unwrap();
        for p in nodes.iter_mut() {
            if let Some(mut child) = p.child.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// Everything `woss managerd` needs to stand up: connect to every node
/// daemon, ask one for the backend kind, and build the store over
/// remote backends. Returns the store plus the layout the node tier
/// reported.
pub fn connect_node_tier(
    addrs: &[RpcAddr],
) -> Result<(Vec<Box<dyn ChunkBackend>>, BackendKind), String> {
    if addrs.is_empty() {
        return Err("managerd needs at least one node address".into());
    }
    let deadline = Instant::now() + READY_TIMEOUT;
    for addr in addrs {
        wait_ready(addr, deadline)?;
    }
    // The node tier's layout comes from the daemons themselves: probe
    // the first one's Info.
    let probe = RemoteBackend::connect(addrs[0].clone());
    let kind = match probe.call(&NodeRequest::Info) {
        Ok(NodeResponse::Info { backend, .. }) => backend,
        other => return Err(format!("node info probe failed: {other:?}")),
    };
    let backends = addrs
        .iter()
        .map(|a| Box::new(RemoteBackend::connect(a.clone())) as Box<dyn ChunkBackend>)
        .collect();
    Ok((backends, kind))
}

/// Build a [`super::proto::NodeHost`] for `woss noded`: a fresh
/// backend of `kind` (memory, or a new store under `data_dir`), or —
/// with `reopen` — the salvage path over what a previous daemon (or a
/// SIGKILLed one) left behind.
pub fn open_node_host(
    kind: BackendKind,
    data_dir: Option<&Path>,
    reopen: bool,
) -> Result<super::proto::NodeHost, StorageError> {
    use super::backend::{FileBackend, MemoryBackend, NodeRecovery, SegBackend};
    let host = match kind {
        BackendKind::Memory => super::proto::NodeHost::new(
            Box::new(MemoryBackend::default()),
            kind,
            if reopen {
                // A memory node has nothing to salvage; it restarts
                // empty (its chunks re-replicate from the survivors).
                Some(NodeRecovery::default())
            } else {
                None
            },
        ),
        BackendKind::Disk | BackendKind::Seg => {
            let dir = data_dir.ok_or_else(|| {
                StorageError::Invalid(format!(
                    "noded --backend {} needs --data-dir",
                    kind.label()
                ))
            })?;
            if reopen {
                let (backend, rec): (Box<dyn ChunkBackend>, _) = match kind {
                    BackendKind::Seg => {
                        let (b, rec) = SegBackend::open_existing(dir)?;
                        (Box::new(b), rec)
                    }
                    _ => {
                        let (b, rec) = FileBackend::open_existing(dir)?;
                        (Box::new(b), rec)
                    }
                };
                super::proto::NodeHost::new(backend, kind, Some(rec))
            } else {
                let backend: Box<dyn ChunkBackend> = match kind {
                    BackendKind::Seg => Box::new(SegBackend::new(dir)?),
                    _ => Box::new(FileBackend::new(dir)?),
                };
                super::proto::NodeHost::new(backend, kind, None)
            }
        }
    };
    Ok(host)
}

/// Convenience for `woss managerd` and the scenario harness: a
/// [`LiveStore`] over a remote node tier, with the cluster (when one
/// is supervising) attached so churn crosses the process boundary.
pub fn store_over_cluster(
    registry: crate::dispatch::Registry,
    cluster: &Arc<Cluster>,
    capacity: u64,
    tuning: super::store::LiveTuning,
) -> LiveStore {
    let store = LiveStore::with_backends(
        registry,
        cluster.backends(),
        cluster.backend_kind(),
        capacity,
        tuning,
    );
    store.attach_supervisor(Arc::clone(cluster) as Arc<dyn NodeSupervisor>);
    store
}
