//! The live execution engine: real bytes, real threads, real compute.
//!
//! The simulator (`crate::sim`) reproduces the paper's figures; this
//! module is the proof that the three-layer stack *composes*: an
//! in-process WOSS deployment ([`store::LiveStore`]) holds actual chunk
//! bytes across per-node stores, the same dispatcher registry routes
//! placement/location decisions, and workflow tasks execute on a std
//! worker pool calling the compute kernels through the runtime
//! (`crate::runtime`). `examples/montage_e2e.rs` drives it on a
//! real workload and verifies data integrity end to end with the
//! checksum kernel.
//!
//! The store's hot path is built to scale with cores: the namespace is
//! lock-striped ([`store::LiveTuning::stripes`]), per-node chunk stores
//! take shared read locks, and optimistic replication drains through a
//! background worker pool behind the
//! [`store::LiveStore::flush_replication`] barrier. The
//! `live_throughput` bench sweeps reader/writer thread counts against
//! stripe counts.
//!
//! On top sits the hint-driven **lifetime & cache tier**
//! ([`store::LiveTuning::cache_bytes`] / [`store::LiveTuning::lifetime`]):
//! a per-node, capacity-bounded hot-chunk cache with hint-aware
//! eviction, automatic reclamation of `Lifetime=scratch` intermediates
//! after their last declared consumer read, and `Pattern=pipeline`
//! prefetch into the consumer node's cache — the first feature where
//! the top-down and bottom-up channels interact on the same file (the
//! runtime tags lifetimes down, and verifies `consumers_left` /
//! `cache_state` back up). The `live_cache` bench sweeps cache size ×
//! eviction policy.
//!
//! The per-node chunk stores themselves are pluggable
//! ([`backend::ChunkBackend`], [`store::LiveTuning::backend`]): the
//! default [`backend::MemoryBackend`] keeps chunks in RAM exactly as
//! before, while [`backend::FileBackend`] spills each chunk to a file
//! under `--data-dir` (temp-file + fsync + rename), turning the cache
//! tier into a true memory-over-disk hot tier and lifting the store's
//! capacity past RAM. [`backend::SegBackend`] replaces file-per-chunk
//! with a few packed append-only segment logs per node
//! (length+checksum-framed records, group commit, online compaction) —
//! the layout that survives millions of tiny chunks without exhausting
//! inodes or fsyncing once per chunk. The `live_throughput` and
//! `live_cache` benches sweep all three backends.
//!
//! The disk tier is **crash-consistent and re-openable**: every chunk
//! publish is recorded in a per-node append-only manifest (length +
//! checksum, fsynced), the namespace is journaled at create time and
//! snapshotted per stripe on clean shutdown
//! ([`store::LiveStore::shutdown`]), and
//! [`store::LiveStore::reopen`] rebuilds a store from a `--data-dir`
//! left by a dead process — verifying every surviving chunk bottom-up
//! and reporting what made it through
//! ([`store::RecoveryReport`], the reserved `recovered=` field on
//! `cache_state`/`system_status`, and the `live_recovery`
//! experiment).
//!
//! The data path is **pipelined**: no lock is held across disk I/O at
//! any layer. [`backend::FileBackend`] mutations reserve a per-key
//! in-flight slot and run the write/fsync/rename unlocked, dirty
//! cache victims write back through an explicit `Spilling` entry
//! state with the node's cache mutex dropped (the entry stays
//! readable mid-spill), and all background byte movement — spills,
//! replica copies, prefetch promotions, churn repair — funnels
//! through a bounded I/O pool ([`store::LiveTuning::io_workers`],
//! default 1 = the serial inline path; the pool changes scheduling,
//! never semantics). The queue depth is served bottom-up as
//! ` io_queue=<d>` on `system_status`,
//! [`store::LiveStore::flush_replication`] barriers both pools, and
//! foreground put/get/spill latency percentiles land in
//! [`store::CacheStats`] / [`engine::LiveReport`]. Debug builds assert
//! the invariant directly (`backend.rs`'s `lockscope` tracker), and
//! `tests/live_overlap.rs` pins it behaviourally under injected
//! latency spikes.
//!
//! Hostility is injectable on demand: [`fault::FaultBackend`] wraps any
//! chunk backend with a deterministic, seed-driven fault schedule (put
//! errors, torn renames, read corruption, latency spikes —
//! [`store::LiveTuning::fault`]), and the store survives **live node
//! churn**: [`store::LiveStore::fail_node`] re-replicates every chunk
//! the lost node held through the background worker pool (no reopen
//! needed), [`store::LiveStore::join_node`] sweeps the returning
//! node's stale copies before it serves again, and
//! [`store::LiveStore::audit`] proves bottom-up that namespace, usage
//! accounting, and backend contents agree. The scenario harness
//! (`crate::scenario`) drives all of it through named hostile
//! workloads.
//!
//! Since PR 10 the store is carved along a **typed service boundary**
//! ([`proto`]): every manager- and node-tier operation is an entry in
//! an exhaustive request/response enum behind the
//! [`proto::ManagerService`] / [`proto::NodeService`] traits, framed
//! on the wire as length-prefixed, FNV-1a-checksummed records (the
//! seg-log idiom applied to sockets). The in-process transport — plain
//! method calls on [`store::LiveStore`] — stays the default and is
//! trace-equivalent to the monolith; [`rpc`] adds the real one: `woss
//! noded` chunk daemons and a `woss managerd` metadata daemon over
//! Unix or TCP sockets, with [`rpc::RemoteBackend`] /
//! [`rpc::RemoteStore`] as the client halves and [`rpc::Cluster`]
//! supervising daemon processes so `fail_node` is a real SIGKILL and
//! `join_node` a real restart through the salvage path. The PR 9 load
//! plane rides in a response trailer (`io_depth` on every node reply),
//! so adaptive placement works unchanged across the process split.

pub mod backend;
pub mod engine;
pub mod fault;
pub mod proto;
pub mod rpc;
pub mod store;

pub use backend::{
    chunk_crc, chunk_files_under, segment_files_under, BackendKind, ChunkBackend, FileBackend,
    MemoryBackend, NodeRecovery, SegBackend, SegConfig,
};
pub use engine::{EngineOptions, LiveEngine, LiveReport, StoreHandle};
pub use fault::{FaultBackend, FaultControl, FaultSpec};
pub use proto::{
    dispatch_manager, read_frame, write_frame, ManagerInfo, ManagerRequest, ManagerResponse,
    ManagerService, NodeHost, NodeRequest, NodeResponse, NodeService, ProtoError, StoreCounters,
};
pub use rpc::{
    connect_node_tier, open_node_host, serve_manager, serve_node, store_over_cluster, Cluster,
    RemoteBackend, RemoteStore, RpcAddr, Server,
};
pub use store::{
    CachePolicy, CacheStats, LiveStore, LiveTuning, NodeLoad, NodeSupervisor, RecoveryReport,
    StoreAudit,
};
