//! Pluggable per-node chunk storage for the live store.
//!
//! PR 3 left the live store an in-memory toy: every chunk was a
//! `Vec<u8>` in a per-node `HashMap`, so a workload whose intermediate
//! footprint exceeds RAM was simply impossible. This module extracts
//! that storage behind the object-safe [`ChunkBackend`] trait and adds
//! a second implementation:
//!
//! * [`MemoryBackend`] — the PR 3 `HashMap` store, byte for byte. The
//!   default, so existing deployments reproduce exactly.
//! * [`FileBackend`] — a file-backed **disk tier**: one file per chunk
//!   under a per-node directory, written via temp-file + fsync +
//!   rename so a chunk is never observable half-written *and* survives
//!   a machine crash once published. Deleting or reclaiming a chunk
//!   removes its on-disk file.
//! * [`SegBackend`] — a **packed segment log**: chunks appended into a
//!   few large `seg-<n>.log` files per node (length + FNV-1a framed
//!   records, group-commit fsync) with a compact in-memory index,
//!   read back positionally (sealed segments served zero-syscall from
//!   `Arc`-mapped buffers), and rewritten by online compaction once
//!   dead bytes pass a threshold — the layout that survives millions
//!   of tiny chunks where file-per-chunk dies on inode exhaustion,
//!   dirent scans, and one fsync per chunk.
//!
//! # Crash consistency (the manifest)
//!
//! Each node directory carries an append-only **manifest**
//! (`manifest.log`): one record per publish (`put <file> <chunk> <len>
//! <crc>`) or removal (`del <file> <chunk>`), fsynced before the
//! operation returns. A chunk is *durable* exactly when its manifest
//! record is — the chunk file itself is fsynced before the rename, and
//! the manifest append is the publish point. Recovery
//! ([`FileBackend::open_existing`]) replays the manifest, drops a torn
//! tail (a record cut short by the crash), verifies every surviving
//! `*.chunk` file against its recorded length and checksum, unlinks
//! chunk files the manifest never published (orphans of a crashed
//! `put`), and rebuilds the in-memory index from what checks out. The
//! replayed manifest is rewritten compacted, so `del` records and torn
//! tails do not accumulate across restarts.
//!
//! With the disk backend the hint-aware cache tier
//! ([`crate::live::LiveTuning::cache_bytes`]) becomes a true
//! memory-over-disk hot tier: a cache hit serves without touching the
//! disk, and `Lifetime=scratch` chunks may skip the spill entirely
//! (see [`crate::live::store`] — dirty cache entries write back on
//! eviction, so correctness never depends on the hint being truthful).

use crate::storage::types::{FileId, StorageError};
use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// Key of one stored chunk: the owning file plus the chunk index.
pub type ChunkKey = (FileId, u64);

/// Debug-only lock-scope guard for the pipelined data path.
///
/// The refactored data path promises that **no store lock is ever held
/// across backend I/O** — the property the `Spilling` cache state and
/// the backend's reserve → write → publish split exist to establish.
/// This module makes the promise checkable: the store wraps every
/// cache-node mutex and namespace-stripe acquisition in a [`token`],
/// and every [`FileBackend`] I/O entry point (and the fault decorator's
/// injected latency spikes) calls [`assert_unlocked`]. A violation —
/// disk I/O re-entering under a store lock — panics immediately in
/// debug builds instead of surfacing as a tail-latency mystery. Release
/// builds compile the whole mechanism to nothing.
pub(crate) mod lockscope {
    #[cfg(debug_assertions)]
    thread_local! {
        static STORE_LOCKS_HELD: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
    }

    /// RAII marker: the creating thread holds a store lock until the
    /// token drops. Create it immediately before taking the lock so
    /// the token outlives the guard by a single stack slot.
    pub(crate) struct Token;

    /// Mark the calling thread as holding a store lock.
    pub(crate) fn token() -> Token {
        #[cfg(debug_assertions)]
        STORE_LOCKS_HELD.with(|d| d.set(d.get() + 1));
        Token
    }

    impl Drop for Token {
        fn drop(&mut self) {
            #[cfg(debug_assertions)]
            STORE_LOCKS_HELD.with(|d| d.set(d.get() - 1));
        }
    }

    /// Panic (debug builds) if the calling thread holds a store lock —
    /// called at every backend I/O entry point.
    pub(crate) fn assert_unlocked(_what: &str) {
        #[cfg(debug_assertions)]
        STORE_LOCKS_HELD.with(|d| {
            assert!(
                d.get() == 0,
                "{_what}: backend I/O while a store lock is held \
                 (the pipelined data path forbids this)"
            );
        });
    }
}

/// Which chunk-backend implementation a live deployment runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// In-memory `HashMap` chunk stores (the PR 3 behaviour, default).
    #[default]
    Memory,
    /// File-backed disk tier: one file per chunk under a per-node
    /// directory (temp-file + fsync + rename writes, manifest-logged).
    Disk,
    /// Packed segment-log disk tier: chunks framed into a few large
    /// append-only `seg-<n>.log` files per node with group-commit
    /// fsyncs and online compaction.
    Seg,
}

impl BackendKind {
    /// Resolve the backend from the `LIVE_BACKEND` environment variable
    /// (`mem` | `disk` | `seg`, same lenient parser as the CLI's
    /// `--backend`),
    /// defaulting to [`BackendKind::Memory`] when unset. This is the CI
    /// matrix hook: `LIVE_BACKEND=disk cargo test` runs every live test
    /// against the spill tier without touching the tests — which is
    /// exactly why an unparseable value panics instead of silently
    /// falling back to memory: a typo'd matrix leg must fail loudly,
    /// not quietly re-run the mem tier.
    pub fn from_env() -> Self {
        match std::env::var("LIVE_BACKEND") {
            Ok(raw) => raw
                .parse()
                .unwrap_or_else(|e| panic!("LIVE_BACKEND: {e}")),
            Err(_) => BackendKind::Memory,
        }
    }

    /// Stable lowercase label (`mem` | `disk` | `seg`) — the value the
    /// reserved `cache_state` attribute reports in its `tier=` field
    /// and the CLI accepts for `--backend`.
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Memory => "mem",
            BackendKind::Disk => "disk",
            BackendKind::Seg => "seg",
        }
    }

    /// Does this backend persist chunks on disk (a durable spill target
    /// under the cache tier, with a `--data-dir` layout to recover)?
    /// True for both disk layouts — file-per-chunk and packed segments.
    pub fn is_persistent(self) -> bool {
        !matches!(self, BackendKind::Memory)
    }
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mem" | "memory" => Ok(BackendKind::Memory),
            "disk" | "file" => Ok(BackendKind::Disk),
            "seg" | "segment" => Ok(BackendKind::Seg),
            other => Err(format!("unknown backend '{other}' (mem|disk|seg)")),
        }
    }
}

/// One storage node's authoritative chunk store, behind a trait so the
/// capacity tier is pluggable. Object-safe and `Send + Sync`: the live
/// store shares `Arc<Vec<Box<dyn ChunkBackend>>>` between the data
/// path and the background replication workers.
///
/// Implementations must make a `put` atomic with respect to concurrent
/// `get`s of the same key: a reader observes either the full chunk or
/// nothing, never a prefix ([`FileBackend`] writes a temp file and
/// renames it into place; [`MemoryBackend`] inserts under a write
/// lock).
pub trait ChunkBackend: Send + Sync {
    /// Store (or overwrite) one chunk.
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError>;

    /// Fetch a chunk's bytes. `Ok(None)` means the chunk is *absent* —
    /// never stored here, or already deleted. `Err` means the chunk
    /// should be present but could not be read back intact (I/O error,
    /// torn or corrupted file): the caller must treat the copy as lost
    /// and fail over, not as never having existed — the distinction is
    /// what separates routine remote traffic from a disk fault. Failed
    /// reads are also counted in [`ChunkBackend::read_errors`].
    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError>;

    /// Remove a chunk (idempotent; absent keys are a no-op). A disk
    /// implementation must remove the chunk's on-disk file *before*
    /// releasing any lock that makes the removal visible, so the index
    /// and the directory never disagree.
    fn delete(&self, key: ChunkKey);

    /// Is the chunk present? (No payload copy.)
    fn contains(&self, key: ChunkKey) -> bool;

    /// Bytes currently stored.
    fn used_bytes(&self) -> u64;

    /// Chunks currently stored.
    fn chunk_count(&self) -> usize;

    /// Chunk reads that failed on a present chunk (I/O error or
    /// checksum mismatch) — the corruption signal a hint-blind caller
    /// would otherwise misread as remote-failover traffic. Memory
    /// backends cannot fail this way, hence the zero default.
    fn read_errors(&self) -> u64 {
        0
    }

    /// Every chunk key currently stored, in no particular order. The
    /// churn and audit machinery cross-references these against the
    /// namespace to find stale copies (a rejoining node's leftovers)
    /// and stray chunks no surviving file claims.
    fn chunk_keys(&self) -> Vec<ChunkKey>;

    /// Run any pending background maintenance — segment compaction for
    /// the packed log, a manifest rewrite for the file tier — and
    /// report whether work was done. The store kicks this on the I/O
    /// pool after delete/reclaim sweeps so reclaimed space actually
    /// returns to the filesystem; a backend with nothing pending must
    /// return immediately. Never called under a store lock.
    fn maintain(&self) -> bool {
        false
    }

    /// Mutations currently executing inside this backend — the
    /// queue-depth half of the bottom-up load signal the adaptive
    /// placement plane consumes (`used_bytes` is the capacity half).
    /// Disk backends report their per-key in-flight mutation slots: a
    /// node mid-spill or mid-compaction shows a non-zero depth and
    /// stops looking like a cheap placement target. Memory backends
    /// complete mutations synchronously under a map lock, hence the
    /// zero default.
    fn io_depth(&self) -> u64 {
        0
    }
}

/// The PR 3 in-memory chunk store: a `RwLock<HashMap>` per node.
/// Readers share the lock; byte copies happen outside every manager
/// lock exactly as before the trait existed. Chunks are held as
/// `Arc<Vec<u8>>` so a `get` clones only the refcount under the read
/// guard and materializes the caller's copy after releasing it —
/// large-chunk reads no longer extend the lock hold time.
#[derive(Default)]
pub struct MemoryBackend {
    chunks: RwLock<HashMap<ChunkKey, Arc<Vec<u8>>>>,
    used: AtomicU64,
}

impl ChunkBackend for MemoryBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        // The payload copy happens before the write lock, so writers
        // hold it only for the map insert.
        let payload = Arc::new(bytes.to_vec());
        let mut chunks = self.chunks.write().unwrap();
        if let Some(old) = chunks.insert(key, payload) {
            self.used.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
        self.used.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        // Snapshot the Arc under the guard (O(1)); the byte clone runs
        // with the lock released.
        let snapshot = self.chunks.read().unwrap().get(&key).cloned();
        Ok(snapshot.map(|arc| arc.as_ref().clone()))
    }

    fn delete(&self, key: ChunkKey) {
        if let Some(old) = self.chunks.write().unwrap().remove(&key) {
            self.used.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.chunks.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.chunks.read().unwrap().len()
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        self.chunks.read().unwrap().keys().copied().collect()
    }
}

/// 64-bit FNV-1a over a byte slice — the chunk checksum recorded in the
/// manifest and re-verified on recovery and on every read. The same
/// cheap, dependency-free hash the dispatcher's path sharding uses.
pub fn chunk_crc(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Name of the per-node append-only chunk manifest.
const MANIFEST: &str = "manifest.log";

/// Dead manifest records (overwritten `put`s plus `del` pairs) that
/// trigger the online manifest rewrite. Low enough that a long-lived
/// node's manifest stays bounded by its live chunk count plus this
/// constant, high enough that steady churn amortizes each rewrite over
/// hundreds of appends.
const MANIFEST_COMPACT_DEAD: u64 = 256;

/// What one node's manifest replay recovered and discarded — the
/// per-backend half of [`crate::live::store::RecoveryReport`].
#[derive(Debug, Clone, Default)]
pub struct NodeRecovery {
    /// Chunks whose manifest record and on-disk file both checked out.
    pub chunks_recovered: usize,
    /// Bytes across the recovered chunks.
    pub bytes_recovered: u64,
    /// Manifest tail records dropped as torn (cut mid-write by the
    /// crash) or unparseable.
    pub torn_records: usize,
    /// Published chunks whose file was missing, short, or failed its
    /// checksum — the entry is dropped and any remnant file unlinked.
    pub corrupt_chunks: usize,
    /// `*.chunk` files the manifest never published (a `put` crashed
    /// between rename and manifest fsync) — unlinked.
    pub orphan_files: usize,
}

impl NodeRecovery {
    fn absorb(&mut self, other: &NodeRecovery) {
        self.chunks_recovered += other.chunks_recovered;
        self.bytes_recovered += other.bytes_recovered;
        self.torn_records += other.torn_records;
        self.corrupt_chunks += other.corrupt_chunks;
        self.orphan_files += other.orphan_files;
    }

    /// Merge per-node reports into one (store-level aggregation).
    pub fn merged<'a>(reports: impl IntoIterator<Item = &'a NodeRecovery>) -> NodeRecovery {
        let mut out = NodeRecovery::default();
        for r in reports {
            out.absorb(r);
        }
        out
    }
}

/// One chunk's manifest record: the length and checksum a recovered
/// file must reproduce.
#[derive(Debug, Clone, Copy)]
struct ChunkRecord {
    len: u64,
    crc: u64,
}

/// An append-only record log (the chunk manifest here, the namespace
/// journal in [`crate::live::store`]) with partial-line poisoning
/// contained: an append that dies mid-write (ENOSPC) can flush part of
/// a record without its newline, and the next record must not fuse
/// onto that wreckage — it would be unparseable at replay even though
/// its own write succeeded. The flag confines the damage to the one
/// wrecked line by newline-terminating it before the next record.
pub(crate) struct AppendLog {
    file: std::fs::File,
    dirty_line: bool,
}

impl AppendLog {
    pub(crate) fn new(file: std::fs::File) -> Self {
        AppendLog {
            file,
            dirty_line: false,
        }
    }

    /// Append one newline-terminated record (terminating any earlier
    /// partial line first), optionally fsyncing it. The dirty flag
    /// clears as soon as the line is fully written — a *failed fsync*
    /// leaves a complete, parseable line, not wreckage.
    pub(crate) fn append(&mut self, line: &str, sync: bool) -> std::io::Result<()> {
        if self.dirty_line {
            self.file.write_all(b"\n")?;
            self.dirty_line = false;
        }
        self.dirty_line = true;
        self.file.write_all(line.as_bytes())?;
        self.dirty_line = false;
        if sync {
            self.file.sync_all()?;
        }
        Ok(())
    }

    /// Flush the log to disk.
    pub(crate) fn sync(&self) -> std::io::Result<()> {
        self.file.sync_all()
    }
}

/// File-backed chunk store: one node directory, one file per chunk
/// (`f<file>_c<chunk>.chunk`) plus the append-only `manifest.log`.
///
/// # Write atomicity & durability
///
/// Writes go to a uniquely named temp file in the same directory,
/// **fsynced**, then renamed into place; the manifest record (`put
/// <file> <chunk> <len> <crc>`) is appended and fsynced before `put`
/// returns. Rename is atomic on POSIX filesystems, so a concurrent
/// reader sees either the complete chunk or no chunk — never a
/// half-written one — and a machine crash after `put` returns can lose
/// neither the bytes nor the record of them. A crash *during* `put`
/// leaves either nothing, an unreferenced temp file, or a renamed
/// chunk with no manifest record; [`FileBackend::open_existing`]
/// removes all three.
///
/// # Lock scope (the pipelined data path)
///
/// **No lock is held across disk I/O.** Mutations reserve a per-key
/// in-flight slot (a `put`/`delete` on the same chunk waits its turn,
/// so same-key mutations stay linearizable), run the temp write +
/// fsync + rename with no lock held, record the publish in the
/// manifest under its own short mutex, and only then touch the index —
/// a metadata-only `RwLock` held for map operations alone. `delete`
/// retires the index entry first, appends its `del` record, and
/// unlinks with no lock held: a concurrent `get` that loses its file
/// mid-read re-checks the index and reports the benign race as
/// *absent*, never as a disk fault. Reads snapshot the record under
/// the read lock, read the file outside it, and verify length +
/// checksum against the snapshot; only a chunk that stays indexed and
/// still fails verification (bounded retries, for the benign
/// same-content republish race) counts as a read error.
///
/// The in-memory index (key → length + checksum) fronts the directory
/// for `contains`/`used_bytes`/`chunk_count`, so only `get`/`put` pay
/// disk I/O — the penalty the hint-aware cache tier is there to
/// absorb. Reads re-verify length and checksum: a present-but-damaged
/// chunk surfaces as `Err` (counted in
/// [`ChunkBackend::read_errors`]), never as silently absent.
pub struct FileBackend {
    dir: PathBuf,
    /// Handle on the directory itself, for fsyncing renames into it.
    dir_handle: std::fs::File,
    /// Metadata-only index: key → published length + checksum. Never
    /// held across file I/O.
    index: RwLock<HashMap<ChunkKey, ChunkRecord>>,
    /// The append-only publish log, under its own short mutex (appends
    /// are the only I/O a lock covers — the log is the serialization
    /// point by design, exactly like the namespace journal).
    manifest: Mutex<AppendLog>,
    /// Per-key in-flight mutation table (see [`Inflight`]).
    inflight: Inflight,
    used: AtomicU64,
    tmp_seq: AtomicU64,
    read_failures: AtomicU64,
    /// Manifest records gone dead since the last compaction:
    /// overwritten `put`s plus `del` pairs. Crossing
    /// [`MANIFEST_COMPACT_DEAD`] triggers the online rewrite.
    dead_records: AtomicU64,
}

/// Per-key in-flight mutation table shared by both disk backends: keys
/// with a put/delete currently between reserve and publish. Same-key
/// mutations queue here instead of on the index lock, so they
/// serialize without stalling unrelated keys or any reader.
#[derive(Default)]
struct Inflight {
    keys: Mutex<HashSet<ChunkKey>>,
    cv: Condvar,
}

impl Inflight {
    /// Reserve the exclusive mutation slot for `key`, waiting out any
    /// in-flight put/delete of the same chunk. This is what keeps
    /// same-key mutations linearizable while their disk I/O runs
    /// outside the index lock.
    fn lock(&self, key: ChunkKey) -> KeySlot<'_> {
        let mut keys = self.keys.lock().unwrap();
        while keys.contains(&key) {
            keys = self.cv.wait(keys).unwrap();
        }
        keys.insert(key);
        KeySlot { table: self, key }
    }
}

/// Exclusive per-key mutation slot: dropped, it releases the key and
/// wakes the next queued mutation.
struct KeySlot<'a> {
    table: &'a Inflight,
    key: ChunkKey,
}

impl Drop for KeySlot<'_> {
    fn drop(&mut self) {
        self.table.keys.lock().unwrap().remove(&self.key);
        self.table.cv.notify_all();
    }
}

impl FileBackend {
    /// Open a **fresh** backend over `dir`, creating the directory and
    /// an empty manifest. Refuses a directory that already carries a
    /// manifest: silently ignoring a previous store's chunks is
    /// exactly the data-loss bug recovery exists to fix — re-open such
    /// a directory with [`FileBackend::open_existing`] instead.
    pub fn new(dir: &Path) -> Result<Self, StorageError> {
        std::fs::create_dir_all(dir).map_err(|e| {
            StorageError::Invalid(format!("create backend dir {}: {e}", dir.display()))
        })?;
        if dir.join(MANIFEST).exists() {
            return Err(StorageError::Invalid(format!(
                "backend dir {} holds a previous store's manifest; open_existing it \
                 instead of silently discarding its chunks",
                dir.display()
            )));
        }
        let manifest = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join(MANIFEST))
            .map_err(|e| StorageError::Invalid(format!("create manifest: {e}")))?;
        let dir_handle = std::fs::File::open(dir)
            .map_err(|e| StorageError::Invalid(format!("open backend dir: {e}")))?;
        let _ = dir_handle.sync_all();
        Ok(FileBackend {
            dir: dir.to_path_buf(),
            dir_handle,
            index: RwLock::new(HashMap::new()),
            manifest: Mutex::new(AppendLog::new(manifest)),
            inflight: Inflight::default(),
            used: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
            read_failures: AtomicU64::new(0),
            dead_records: AtomicU64::new(0),
        })
    }

    /// Re-open a backend directory left by a previous store: replay the
    /// manifest, verify survivors, discard what the crash tore, and
    /// rebuild the index.
    ///
    /// * The manifest is replayed record by record; an unparseable
    ///   line — the unterminated tail a crash tore, or a terminated
    ///   line a failed append damaged — is skipped (counted in
    ///   [`NodeRecovery::torn_records`]) without poisoning the records
    ///   around it, which every verified chunk below re-validates
    ///   anyway.
    /// * Every chunk the replay says should exist is verified against
    ///   its recorded length and checksum; a missing, short, or
    ///   corrupt file drops the entry (and unlinks any remnant).
    /// * `*.chunk` files the surviving records never published — a
    ///   `put` that renamed but crashed before its manifest fsync —
    ///   are unlinked, as are stale `.put-*.tmp` files.
    /// * The manifest is rewritten compacted (surviving `put` records
    ///   only) so torn tails and `del` churn reset at every open.
    pub fn open_existing(dir: &Path) -> Result<(Self, NodeRecovery), StorageError> {
        if !dir.is_dir() {
            return Err(StorageError::Invalid(format!(
                "backend dir {} does not exist",
                dir.display()
            )));
        }
        let mut recovery = NodeRecovery::default();
        let mut replayed: HashMap<ChunkKey, ChunkRecord> = HashMap::new();
        // A manifest that does not exist is a node that crashed before
        // its first publish became durable — legitimately empty. Any
        // other read failure must abort the recovery: replaying
        // "nothing" over a directory full of published chunks would
        // unlink every one of them as an orphan (the exact
        // absent-vs-read-failed confusion `get` refuses to make).
        let raw = match std::fs::read(dir.join(MANIFEST)) {
            Ok(raw) => raw,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => {
                return Err(StorageError::Invalid(format!(
                    "read manifest in {}: {e}",
                    dir.display()
                )));
            }
        };
        let text = String::from_utf8_lossy(&raw);
        for line in text.split_inclusive('\n') {
            // A record is only durable with its terminating newline; a
            // tail without one was torn mid-append. A *terminated* but
            // unparseable line is a record a failed append damaged (the
            // next append newline-terminates the wreckage so its own
            // record survives on a clean line). Either way the damage is
            // that one record: skip it and keep replaying — every
            // surviving entry is independently verified against its
            // chunk file below, so a skipped `put` at worst orphans one
            // file (swept) and a skipped `del` at worst leaves an entry
            // whose file is already gone (dropped by verification).
            let torn_tail = !line.ends_with('\n');
            match parse_manifest_line(line.trim_end_matches('\n')) {
                Some(ManifestOp::Put { key, rec }) if !torn_tail => {
                    replayed.insert(key, rec);
                }
                Some(ManifestOp::Del { key }) if !torn_tail => {
                    replayed.remove(&key);
                }
                _ => recovery.torn_records += 1,
            }
        }

        // Verify survivors against the directory.
        let mut kept: HashMap<ChunkKey, ChunkRecord> = HashMap::new();
        let mut used = 0u64;
        for (key, rec) in replayed {
            let path = chunk_path_in(dir, key);
            let ok = match std::fs::read(&path) {
                Ok(bytes) => bytes.len() as u64 == rec.len && chunk_crc(&bytes) == rec.crc,
                Err(_) => false,
            };
            if ok {
                used += rec.len;
                kept.insert(key, rec);
            } else {
                recovery.corrupt_chunks += 1;
                let _ = std::fs::remove_file(&path);
            }
        }
        recovery.chunks_recovered = kept.len();
        recovery.bytes_recovered = used;

        // Unpublished chunk files (and stale temp files) are orphans of
        // crashed puts: unlink them so nothing resurrects.
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                let orphan_chunk = name.ends_with(".chunk")
                    && match parse_chunk_name(&name) {
                        Some(key) => !kept.contains_key(&key),
                        None => true,
                    };
                let stale_tmp = (name.starts_with(".put-") && name.ends_with(".tmp"))
                    || name == ".manifest.tmp";
                if orphan_chunk {
                    recovery.orphan_files += 1;
                    let _ = std::fs::remove_file(entry.path());
                } else if stale_tmp {
                    // Crashed put temp, or a compaction that died
                    // between writing .manifest.tmp and renaming it —
                    // either way the rewrite below supersedes it.
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }

        // Rewrite the manifest compacted: the survivors are the whole
        // truth now, and the torn tail must not be replayed twice.
        let tmp = dir.join(".manifest.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| StorageError::Invalid(format!("compact manifest: {e}")))?;
            for (key, rec) in &kept {
                writeln!(f, "put {} {} {} {:016x}", key.0 .0, key.1, rec.len, rec.crc)
                    .map_err(|e| StorageError::Invalid(format!("compact manifest: {e}")))?;
            }
            f.sync_all()
                .map_err(|e| StorageError::Invalid(format!("sync manifest: {e}")))?;
        }
        std::fs::rename(&tmp, dir.join(MANIFEST))
            .map_err(|e| StorageError::Invalid(format!("publish manifest: {e}")))?;
        let dir_handle = std::fs::File::open(dir)
            .map_err(|e| StorageError::Invalid(format!("open backend dir: {e}")))?;
        let _ = dir_handle.sync_all();
        let manifest = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(MANIFEST))
            .map_err(|e| StorageError::Invalid(format!("reopen manifest: {e}")))?;
        Ok((
            FileBackend {
                dir: dir.to_path_buf(),
                dir_handle,
                index: RwLock::new(kept),
                manifest: Mutex::new(AppendLog::new(manifest)),
                inflight: Inflight::default(),
                used: AtomicU64::new(used),
                tmp_seq: AtomicU64::new(0),
                read_failures: AtomicU64::new(0),
                dead_records: AtomicU64::new(0),
            },
            recovery,
        ))
    }

    fn chunk_path(&self, key: ChunkKey) -> PathBuf {
        chunk_path_in(&self.dir, key)
    }

    /// The online half of the recovery-time manifest compaction (PR 5
    /// left the rewrite to `open_existing`, so a long-lived node's
    /// manifest grew with its operation history instead of its live
    /// chunk count): once enough records go dead, rewrite the log from
    /// the index and swap the append handle, all under the manifest
    /// mutex so concurrent publishes land in the new file. A failed
    /// rewrite is abandoned — the old log keeps appending, and the
    /// next threshold crossing retries.
    fn maybe_compact_manifest(&self) {
        if self.dead_records.load(Ordering::Relaxed) < MANIFEST_COMPACT_DEAD {
            return;
        }
        let mut log = self.manifest.lock().unwrap();
        // Re-check under the mutex: a racing mutation may have queued
        // behind the compaction that already reset the counter.
        if self.dead_records.load(Ordering::Relaxed) < MANIFEST_COMPACT_DEAD {
            return;
        }
        // Puts publish their index insert under the manifest mutex, so
        // this snapshot is exactly the set of live records the old
        // log's tail describes — nothing mid-publish can be dropped.
        let snapshot: Vec<(ChunkKey, ChunkRecord)> = self
            .index
            .read()
            .unwrap()
            .iter()
            .map(|(k, r)| (*k, *r))
            .collect();
        let tmp = self.dir.join(".manifest.tmp");
        let rewrite = || -> std::io::Result<std::fs::File> {
            let mut f = std::fs::File::create(&tmp)?;
            for (key, rec) in &snapshot {
                writeln!(f, "put {} {} {} {:016x}", key.0 .0, key.1, rec.len, rec.crc)?;
            }
            f.sync_all()?;
            std::fs::rename(&tmp, self.dir.join(MANIFEST))?;
            self.dir_handle.sync_all()?;
            std::fs::OpenOptions::new()
                .append(true)
                .open(self.dir.join(MANIFEST))
        };
        match rewrite() {
            Ok(f) => {
                *log = AppendLog::new(f);
                self.dead_records.store(0, Ordering::Relaxed);
            }
            Err(_) => {
                let _ = std::fs::remove_file(&tmp);
            }
        }
    }

    /// Chunk keys currently indexed (recovery bookkeeping: the store
    /// cross-references these against the recovered namespace to find
    /// chunks no surviving file claims).
    pub fn chunk_keys(&self) -> Vec<ChunkKey> {
        self.index.read().unwrap().keys().copied().collect()
    }
}

/// One parsed manifest record.
enum ManifestOp {
    Put { key: ChunkKey, rec: ChunkRecord },
    Del { key: ChunkKey },
}

fn parse_manifest_line(line: &str) -> Option<ManifestOp> {
    let mut parts = line.split(' ');
    let op = parts.next()?;
    let file = FileId(parts.next()?.parse().ok()?);
    let chunk: u64 = parts.next()?.parse().ok()?;
    match op {
        "put" => {
            let len: u64 = parts.next()?.parse().ok()?;
            let crc = u64::from_str_radix(parts.next()?, 16).ok()?;
            if parts.next().is_some() {
                return None;
            }
            Some(ManifestOp::Put {
                key: (file, chunk),
                rec: ChunkRecord { len, crc },
            })
        }
        "del" => {
            if parts.next().is_some() {
                return None;
            }
            Some(ManifestOp::Del { key: (file, chunk) })
        }
        _ => None,
    }
}

fn chunk_path_in(dir: &Path, key: ChunkKey) -> PathBuf {
    dir.join(format!("f{}_c{}.chunk", key.0 .0, key.1))
}

/// Parse `f<file>_c<chunk>.chunk` back into its key.
fn parse_chunk_name(name: &str) -> Option<ChunkKey> {
    let body = name.strip_suffix(".chunk")?.strip_prefix('f')?;
    let (file, chunk) = body.split_once("_c")?;
    Some((FileId(file.parse().ok()?), chunk.parse().ok()?))
}

impl ChunkBackend for FileBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        lockscope::assert_unlocked("FileBackend::put");
        // Reserve: the per-key slot serializes same-key mutations, so
        // everything below runs without the index lock and still
        // linearizes against a racing put/delete of this chunk.
        let _slot = self.inflight.lock(key);
        let tmp = self.dir.join(format!(
            ".put-{}.tmp",
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        // Byte landing is lock-free: write + fsync the temp file so the
        // rename below publishes fully-durable content.
        let written = std::fs::File::create(&tmp)
            .and_then(|mut f| f.write_all(bytes).and_then(|()| f.sync_all()));
        if let Err(e) = written {
            let _ = std::fs::remove_file(&tmp);
            return Err(StorageError::Invalid(format!(
                "spill chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        let rec = ChunkRecord {
            len: bytes.len() as u64,
            crc: chunk_crc(bytes),
        };
        // Rename + directory fsync + manifest fsync, all outside the
        // index lock. Until the index insert below, a concurrent `get`
        // of a fresh key reports absent (the put has not linearized
        // yet) and a `get` racing an overwrite re-verifies against the
        // old record — same-content republishes (the only overwrites
        // the store issues) still verify.
        if let Err(e) = std::fs::rename(&tmp, self.chunk_path(key)) {
            // Nothing was replaced: a previously published copy (and
            // its index entry) is still intact, only the temp goes.
            let _ = std::fs::remove_file(&tmp);
            return Err(StorageError::Invalid(format!(
                "publish chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        let line = format!("put {} {} {} {:016x}\n", key.0 .0, key.1, rec.len, rec.crc);
        // The manifest mutex covers the append *and* the index insert
        // below: the log is the serialization point by design, and
        // holding it through the publish keeps the online compaction's
        // index snapshot exactly consistent with the log tail — no
        // record can land in the old log after the rewrite snapshots.
        let mut log = self.manifest.lock().unwrap();
        let logged = self
            .dir_handle
            .sync_all()
            .and_then(|()| log.append(&line, true));
        if let Err(e) = logged {
            drop(log);
            // The rename already replaced the on-disk bytes with
            // content the manifest never published — and, on an
            // overwrite, destroyed the copy the old index entry
            // described. Make the failure consistent: the chunk is
            // gone. Leaving the old entry in place would advertise a
            // chunk whose bytes no longer match (every read a spurious
            // checksum failure); leaving the file would strand an
            // unindexed .chunk until the next recovery sweep.
            if let Some(old) = self.index.write().unwrap().remove(&key) {
                self.used.fetch_sub(old.len, Ordering::Relaxed);
            }
            let _ = std::fs::remove_file(self.chunk_path(key));
            return Err(StorageError::Invalid(format!(
                "publish chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        // Publish: the metadata-only index insert is the linearization
        // point.
        if let Some(old) = self.index.write().unwrap().insert(key, rec) {
            self.used.fetch_sub(old.len, Ordering::Relaxed);
            // The overwritten put's manifest record is dead weight now.
            self.dead_records.fetch_add(1, Ordering::Relaxed);
        }
        self.used.fetch_add(rec.len, Ordering::Relaxed);
        drop(log);
        self.maybe_compact_manifest();
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        lockscope::assert_unlocked("FileBackend::get");
        // Snapshot the record under the read lock, read the file with
        // no lock held, verify against the snapshot. A failed
        // verification re-checks the index: entry gone → the benign
        // delete race (absent, not a fault); entry present → retry a
        // bounded number of times (a same-content republish between
        // rename and index insert verifies against either record; the
        // retries cover the theoretical different-content overwrite)
        // before reporting a genuine disk fault.
        const ATTEMPTS: usize = 3;
        let mut failed = String::new();
        for attempt in 0..ATTEMPTS {
            let rec = match self.index.read().unwrap().get(&key) {
                Some(rec) => *rec,
                None => return Ok(None),
            };
            match std::fs::read(self.chunk_path(key)) {
                Ok(bytes) if bytes.len() as u64 == rec.len && chunk_crc(&bytes) == rec.crc => {
                    return Ok(Some(bytes));
                }
                Ok(_) => failed = "length/checksum mismatch".to_string(),
                Err(e) => {
                    if e.kind() == std::io::ErrorKind::NotFound
                        && !self.index.read().unwrap().contains_key(&key)
                    {
                        // The file vanished because a concurrent delete
                        // retired the chunk between our snapshot and
                        // the read: absent, exactly as if we had
                        // arrived a moment later.
                        return Ok(None);
                    }
                    failed = e.to_string();
                }
            }
            if attempt + 1 < ATTEMPTS {
                std::thread::yield_now();
            }
        }
        self.read_failures.fetch_add(1, Ordering::Relaxed);
        Err(StorageError::Invalid(format!(
            "chunk {}#{} unreadable in {}: {failed}",
            key.0 .0,
            key.1,
            self.dir.display()
        )))
    }

    fn delete(&self, key: ChunkKey) {
        lockscope::assert_unlocked("FileBackend::delete");
        // The slot serializes against a racing put of the same key (a
        // fresh chunk cannot be renamed into place mid-delete and get
        // unlinked while the index says present). Retire the index
        // entry first, then log, then unlink — a reader that loses the
        // file mid-read finds the entry gone and reports absent.
        let _slot = self.inflight.lock(key);
        let removed = self.index.write().unwrap().remove(&key);
        if let Some(old) = removed {
            self.used.fetch_sub(old.len, Ordering::Relaxed);
            let _ = self
                .manifest
                .lock()
                .unwrap()
                .append(&format!("del {} {}\n", key.0 .0, key.1), true);
            let _ = std::fs::remove_file(self.chunk_path(key));
            // The retired put record and the del pair are both dead
            // weight in the log now.
            self.dead_records.fetch_add(2, Ordering::Relaxed);
            self.maybe_compact_manifest();
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.index.read().unwrap().len()
    }

    fn read_errors(&self) -> u64 {
        self.read_failures.load(Ordering::Relaxed)
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        FileBackend::chunk_keys(self)
    }

    fn io_depth(&self) -> u64 {
        self.inflight.keys.lock().unwrap().len() as u64
    }
}

/// Count the chunk files (`*.chunk`) anywhere under `dir` — the disk
/// backend's on-disk footprint. The stray-file audits use this: after
/// a store has deleted or reclaimed every file, its `--data-dir` must
/// hold zero chunk files (`scripts/verify.sh` fails the disk test
/// matrix otherwise). Symbolic links are never followed — a cycle
/// inside a data dir must not hang the audit — so only real
/// directories are descended into.
pub fn chunk_files_under(dir: &Path) -> usize {
    let mut count = 0;
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else {
            continue;
        };
        for entry in entries.flatten() {
            // `Path::is_dir()` follows symlinks; `entry.file_type()`
            // reports the link itself, which is what keeps a symlink
            // cycle from turning this walk into an infinite loop.
            let Ok(ftype) = entry.file_type() else {
                continue;
            };
            if ftype.is_dir() {
                stack.push(entry.path());
            } else if ftype.is_file() && entry.path().extension().is_some_and(|e| e == "chunk") {
                count += 1;
            }
        }
    }
    count
}

/// Count the segment files (`seg-*.log`) anywhere under `dir` — the
/// packed backend's on-disk footprint, the number the `seg` acceptance
/// gate requires to stay O(segments) rather than O(chunks). Symbolic
/// links are never followed, exactly as in [`chunk_files_under`].
pub fn segment_files_under(dir: &Path) -> usize {
    let mut count = 0;
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else {
            continue;
        };
        for entry in entries.flatten() {
            let Ok(ftype) = entry.file_type() else {
                continue;
            };
            if ftype.is_dir() {
                stack.push(entry.path());
            } else if ftype.is_file()
                && parse_seg_name(&entry.file_name().to_string_lossy()).is_some()
            {
                count += 1;
            }
        }
    }
    count
}

/// Name of the per-node segment list: the file name of every live
/// segment, one per line, in **replay order**. Rewritten atomically
/// (temp + fsync + rename + directory fsync) at every roll and
/// compaction flip, the list is the single source of truth recovery
/// trusts: segment files it does not name are crash debris and get
/// swept, never replayed.
const SEG_META: &str = "segments.meta";

/// Byte length of one framed record header:
/// `[op:1][file:8][chunk:8][len:8][crc:8]`, all little-endian.
const SEG_HEADER: usize = 33;

/// Record op: chunk publish (header + payload).
const SEG_PUT: u8 = 1;
/// Record op: chunk tombstone (header only).
const SEG_DEL: u8 = 2;

/// Tuning for [`SegBackend`]. The defaults suit real deployments;
/// tests shrink them to exercise rolls and compaction with a handful
/// of tiny chunks.
#[derive(Debug, Clone, Copy)]
pub struct SegConfig {
    /// Seal the active segment and roll to a fresh one once it holds
    /// this many bytes (a single oversized record may still exceed it:
    /// records never split across segments).
    pub segment_bytes: u64,
    /// Group-commit boundary: fsync the active segment once this many
    /// bytes accumulate since the last sync. `0` syncs every record —
    /// the file backend's fsync-per-put discipline.
    pub group_commit_bytes: u64,
    /// Rewrite sealed segments once dead bytes (overwritten, deleted,
    /// and tombstone records, headers included) pass this threshold.
    pub compact_dead_bytes: u64,
    /// Byte budget for sealed segments held whole in memory as
    /// `Arc`-mapped buffers — the mmap-style zero-syscall read path.
    /// Segments past the budget fall back to positional reads.
    pub map_budget_bytes: u64,
}

impl Default for SegConfig {
    fn default() -> Self {
        SegConfig {
            segment_bytes: 8 << 20,
            group_commit_bytes: 256 << 10,
            compact_dead_bytes: 4 << 20,
            map_budget_bytes: 32 << 20,
        }
    }
}

/// One chunk's location in the packed log: which segment, where the
/// payload starts, and the framed record's checksum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SegRecord {
    seg: u64,
    offset: u64,
    len: u64,
    crc: u64,
}

/// One open segment: the shared read/append handle plus its path
/// (non-unix positional reads reopen by path; the mapped read path
/// loads by path).
struct SegmentFile {
    path: PathBuf,
    file: std::fs::File,
}

/// Append-side state, guarded by the writer mutex: the active segment,
/// its append offset, the unsynced byte count for group commit, the
/// next unallocated segment id, and the replay-ordered segment list
/// the on-disk meta mirrors.
struct SegWriter {
    active: u64,
    offset: u64,
    unsynced: u64,
    next_id: u64,
    order: Vec<u64>,
}

/// Sealed segments mapped whole into memory (`Arc<Vec<u8>>`), evicted
/// oldest-first once over the byte budget.
#[derive(Default)]
struct MappedSegs {
    bufs: HashMap<u64, Arc<Vec<u8>>>,
    order: std::collections::VecDeque<u64>,
    bytes: u64,
}

fn seg_file_name(id: u64) -> String {
    format!("seg-{id}.log")
}

fn seg_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(seg_file_name(id))
}

fn tmp_seg_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("seg-{id}.log.tmp"))
}

/// Parse `seg-<n>.log` back into its id.
fn parse_seg_name(name: &str) -> Option<u64> {
    name.strip_prefix("seg-")?
        .strip_suffix(".log")?
        .parse()
        .ok()
}

/// Serialize one framed record header.
fn seg_header_bytes(op: u8, key: ChunkKey, len: u64, crc: u64) -> [u8; SEG_HEADER] {
    let mut out = [0u8; SEG_HEADER];
    out[0] = op;
    out[1..9].copy_from_slice(&key.0 .0.to_le_bytes());
    out[9..17].copy_from_slice(&key.1.to_le_bytes());
    out[17..25].copy_from_slice(&len.to_le_bytes());
    out[25..33].copy_from_slice(&crc.to_le_bytes());
    out
}

/// Parse one framed record header. `None` means the framing itself is
/// lost (unrecognizable op byte) — recovery tears off the rest of the
/// segment.
fn seg_parse_header(raw: &[u8]) -> Option<(u8, ChunkKey, u64, u64)> {
    let op = raw[0];
    if op != SEG_PUT && op != SEG_DEL {
        return None;
    }
    let file = u64::from_le_bytes(raw[1..9].try_into().unwrap());
    let chunk = u64::from_le_bytes(raw[9..17].try_into().unwrap());
    let len = u64::from_le_bytes(raw[17..25].try_into().unwrap());
    let crc = u64::from_le_bytes(raw[25..33].try_into().unwrap());
    Some((op, (FileId(file), chunk), len, crc))
}

/// FNV-1a over the record's meaningful header bytes (op, key, length —
/// everything but the checksum field itself) followed by the payload,
/// so a flipped bit anywhere in the record fails verification, not
/// just payload damage.
fn seg_record_crc(op: u8, key: ChunkKey, payload: &[u8]) -> u64 {
    let head = seg_header_bytes(op, key, payload.len() as u64, 0);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in head[..SEG_HEADER - 8].iter().chain(payload.iter()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Open (or create) one segment file for appending and positional
/// reads. Append mode keeps the kernel positioning every write at the
/// true end of file, so the writer never seeks.
fn open_segment(dir: &Path, id: u64, fresh: bool) -> std::io::Result<std::fs::File> {
    let mut opts = std::fs::OpenOptions::new();
    opts.read(true).append(true);
    if fresh {
        opts.create_new(true);
    }
    opts.open(seg_path(dir, id))
}

/// Positional read of `len` bytes at `offset` — the portable stand-in
/// for an mmap'd view. Unix reads through the shared handle without
/// moving any cursor; elsewhere the segment is reopened by path so the
/// append cursor is never disturbed.
#[cfg(unix)]
fn pread_exact(seg: &SegmentFile, offset: u64, len: usize) -> std::io::Result<Vec<u8>> {
    use std::os::unix::fs::FileExt;
    let mut buf = vec![0u8; len];
    seg.file.read_exact_at(&mut buf, offset)?;
    Ok(buf)
}

#[cfg(not(unix))]
fn pread_exact(seg: &SegmentFile, offset: u64, len: usize) -> std::io::Result<Vec<u8>> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f = std::fs::File::open(&seg.path)?;
    f.seek(SeekFrom::Start(offset))?;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    Ok(buf)
}

/// Packed segment-log chunk store: a node directory holding a few
/// large append-only `seg-<n>.log` files plus the replay-ordered
/// segment list (`segments.meta`). Chunks are framed as
/// `[op][file][chunk][len][crc]` records (33-byte little-endian
/// header, FNV-1a over header + payload) appended to the active
/// segment; a compact in-memory index maps each key to its segment and
/// offset, so `contains`/`used_bytes`/`chunk_count` never touch disk.
///
/// # Why a packed log
///
/// File-per-chunk collapses at the millions-of-tiny-chunks scale the
/// north star demands: one inode, one dirent, and at least one fsync
/// per chunk. The packed log amortizes all three — a tiny put is one
/// buffered append, fsynced on the group-commit boundary
/// ([`SegConfig::group_commit_bytes`]), and the directory holds
/// O(segments) files regardless of chunk count.
///
/// # Durability contract
///
/// A put is durable once its group commits: a crash can cost at most
/// the unsynced tail of the active segment, which
/// [`SegBackend::open_existing`] tears off cleanly. Set
/// [`SegConfig::group_commit_bytes`] to `0` for the file backend's
/// fsync-per-put discipline.
///
/// # Lock scope (the pipelined data path)
///
/// Same discipline as [`FileBackend`]: **no store lock is ever held
/// across segment I/O**. Mutations reserve the per-key in-flight slot,
/// append under the backend's own writer mutex (the log *is* the
/// serialization point, exactly like the manifest), and publish with a
/// metadata-only index insert afterwards. Reads snapshot the record
/// under the index read lock and fetch the payload outside it: sealed
/// segments from an `Arc`-mapped whole-segment buffer (the mmap-style
/// zero-syscall path, byte-budgeted), the active segment via
/// positional reads that never move the append cursor. Checksums are
/// verified on every read; a failure re-checks the index — a delete or
/// compaction race retries against the new truth — before counting a
/// genuine fault in [`ChunkBackend::read_errors`].
///
/// # Compaction
///
/// Overwrites and deletes only append (a tombstone for deletes); the
/// space comes back when [`SegBackend::maintain`] rewrites sealed
/// segments once dead bytes pass [`SegConfig::compact_dead_bytes`].
/// The store kicks `maintain` on its I/O pool after delete, reclaim,
/// and churn sweeps, so reclaimed chunks actually return space.
pub struct SegBackend {
    dir: PathBuf,
    /// Handle on the directory itself, for fsyncing renames into it.
    dir_handle: std::fs::File,
    cfg: SegConfig,
    /// Metadata-only index: key → segment location. Never held across
    /// segment I/O.
    index: RwLock<HashMap<ChunkKey, SegRecord>>,
    /// Open segment handles by id; reads clone the `Arc` under the
    /// read guard and do positional I/O outside it.
    segments: RwLock<HashMap<u64, Arc<SegmentFile>>>,
    /// Append state, under its own short mutex.
    writer: Mutex<SegWriter>,
    mapped: Mutex<MappedSegs>,
    /// Per-key in-flight mutation table (see [`Inflight`]).
    inflight: Inflight,
    /// The active (unsealed) segment id, readable without the writer
    /// mutex so the read path can route sealed segments to the map.
    active_id: AtomicU64,
    /// Live payload bytes.
    used: AtomicU64,
    /// Bytes no live record references (framing headers included).
    dead: AtomicU64,
    /// Single-flight latch for compaction.
    compacting: AtomicBool,
    read_failures: AtomicU64,
}

impl SegBackend {
    /// Open a **fresh** backend over `dir`: create the directory, the
    /// first segment, and the segment list. Refuses a directory that
    /// already carries a segment list — re-open such a directory with
    /// [`SegBackend::open_existing`] instead of silently shadowing its
    /// chunks.
    pub fn new(dir: &Path) -> Result<Self, StorageError> {
        Self::with_config(dir, SegConfig::default())
    }

    /// [`SegBackend::new`] with explicit tuning.
    pub fn with_config(dir: &Path, cfg: SegConfig) -> Result<Self, StorageError> {
        std::fs::create_dir_all(dir).map_err(|e| {
            StorageError::Invalid(format!("create backend dir {}: {e}", dir.display()))
        })?;
        if dir.join(SEG_META).exists() {
            return Err(StorageError::Invalid(format!(
                "backend dir {} holds a previous store's segment list; open_existing it \
                 instead of silently shadowing its chunks",
                dir.display()
            )));
        }
        let dir_handle = std::fs::File::open(dir)
            .map_err(|e| StorageError::Invalid(format!("open backend dir: {e}")))?;
        let file = open_segment(dir, 0, true)
            .map_err(|e| StorageError::Invalid(format!("create segment: {e}")))?;
        let backend = SegBackend {
            dir: dir.to_path_buf(),
            dir_handle,
            cfg,
            index: RwLock::new(HashMap::new()),
            segments: RwLock::new(HashMap::from([(
                0,
                Arc::new(SegmentFile {
                    path: seg_path(dir, 0),
                    file,
                }),
            )])),
            writer: Mutex::new(SegWriter {
                active: 0,
                offset: 0,
                unsynced: 0,
                next_id: 1,
                order: vec![0],
            }),
            mapped: Mutex::new(MappedSegs::default()),
            inflight: Inflight::default(),
            active_id: AtomicU64::new(0),
            used: AtomicU64::new(0),
            dead: AtomicU64::new(0),
            compacting: AtomicBool::new(false),
            read_failures: AtomicU64::new(0),
        };
        backend
            .write_meta(&[0])
            .map_err(|e| StorageError::Invalid(format!("write segment list: {e}")))?;
        Ok(backend)
    }

    /// Re-open a segment directory left by a previous store: replay
    /// every listed segment in order, tear off torn tails, skip
    /// checksum-corrupt records, sweep crash debris, and rebuild the
    /// index.
    ///
    /// * The segment list names the live segments in replay order;
    ///   compaction flips it atomically, so a rewrite the crash
    ///   interrupted leaves only *unlisted* files — swept here
    ///   (counted in [`NodeRecovery::orphan_files`] along with stale
    ///   `*.tmp` files), never replayed. A missing list (the crash
    ///   predates the first publish becoming durable) falls back to
    ///   ascending-id order over whatever segments exist.
    /// * A record cut short by the crash — short header, short
    ///   payload, or unrecognizable op byte — tears off the rest of
    ///   its segment (counted in [`NodeRecovery::torn_records`]); the
    ///   file is truncated back to its last good record so new appends
    ///   never fuse onto wreckage.
    /// * A full-length record whose checksum fails is skipped alone
    ///   (counted in [`NodeRecovery::corrupt_chunks`]) — the framing
    ///   is intact, so the records after it still replay.
    pub fn open_existing(dir: &Path) -> Result<(Self, NodeRecovery), StorageError> {
        Self::open_existing_with(dir, SegConfig::default())
    }

    /// [`SegBackend::open_existing`] with explicit tuning.
    pub fn open_existing_with(
        dir: &Path,
        cfg: SegConfig,
    ) -> Result<(Self, NodeRecovery), StorageError> {
        if !dir.is_dir() {
            return Err(StorageError::Invalid(format!(
                "backend dir {} does not exist",
                dir.display()
            )));
        }
        let mut recovery = NodeRecovery::default();
        let listed: Vec<u64> = match std::fs::read_to_string(dir.join(SEG_META)) {
            Ok(text) => text.lines().filter_map(parse_seg_name).collect(),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // No durable list: best effort over whatever segments
                // exist, oldest id first.
                let mut ids: Vec<u64> = match std::fs::read_dir(dir) {
                    Ok(entries) => entries
                        .flatten()
                        .filter_map(|e| parse_seg_name(&e.file_name().to_string_lossy()))
                        .collect(),
                    Err(_) => Vec::new(),
                };
                ids.sort_unstable();
                ids
            }
            Err(e) => {
                return Err(StorageError::Invalid(format!(
                    "read segment list in {}: {e}",
                    dir.display()
                )));
            }
        };

        let mut replayed: HashMap<ChunkKey, SegRecord> = HashMap::new();
        let mut segments: HashMap<u64, Arc<SegmentFile>> = HashMap::new();
        let mut kept: Vec<u64> = Vec::new();
        let mut total_bytes = 0u64;
        for id in &listed {
            let path = seg_path(dir, *id);
            let raw = match std::fs::read(&path) {
                Ok(raw) => raw,
                Err(_) => {
                    // Listed but unreadable: its records are lost; the
                    // segments around it still replay.
                    recovery.torn_records += 1;
                    continue;
                }
            };
            let mut off = 0usize;
            let mut valid = 0usize;
            loop {
                if off == raw.len() {
                    break;
                }
                if off + SEG_HEADER > raw.len() {
                    recovery.torn_records += 1;
                    break;
                }
                let Some((op, key, len, crc)) = seg_parse_header(&raw[off..off + SEG_HEADER])
                else {
                    recovery.torn_records += 1;
                    break;
                };
                let start = off + SEG_HEADER;
                if start as u64 + len > raw.len() as u64 {
                    recovery.torn_records += 1;
                    break;
                }
                let end = start + len as usize;
                let payload = &raw[start..end];
                if seg_record_crc(op, key, payload) == crc {
                    if op == SEG_PUT {
                        replayed.insert(
                            key,
                            SegRecord {
                                seg: *id,
                                offset: start as u64,
                                len,
                                crc,
                            },
                        );
                    } else {
                        replayed.remove(&key);
                    }
                } else {
                    recovery.corrupt_chunks += 1;
                }
                off = end;
                valid = end;
            }
            let file = open_segment(dir, *id, false)
                .map_err(|e| StorageError::Invalid(format!("reopen segment: {e}")))?;
            if valid < raw.len() {
                // Torn or garbled tail: truncate back to the last good
                // record so new appends start on a clean boundary.
                file.set_len(valid as u64).map_err(|e| {
                    StorageError::Invalid(format!("truncate torn segment: {e}"))
                })?;
            }
            total_bytes += valid as u64;
            segments.insert(*id, Arc::new(SegmentFile { path, file }));
            kept.push(*id);
        }

        // Sweep crash debris: segment files the list never published (a
        // compaction the crash interrupted) and stale temp files.
        // Nothing may resurrect from them.
        let listed_set: HashSet<u64> = listed.iter().copied().collect();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if let Some(id) = parse_seg_name(&name) {
                    if !listed_set.contains(&id) {
                        recovery.orphan_files += 1;
                        let _ = std::fs::remove_file(entry.path());
                    }
                } else if name.ends_with(".tmp") {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }

        // Resume appending to the last listed segment, or a fresh one
        // when nothing survived.
        let dir_handle = std::fs::File::open(dir)
            .map_err(|e| StorageError::Invalid(format!("open backend dir: {e}")))?;
        let mut next_id = kept.iter().copied().max().map_or(0, |m| m + 1);
        let (active, offset) = if let Some(id) = kept.last().copied() {
            let len = std::fs::metadata(seg_path(dir, id))
                .map(|m| m.len())
                .unwrap_or(0);
            (id, len)
        } else {
            let id = next_id;
            next_id += 1;
            let file = open_segment(dir, id, true)
                .map_err(|e| StorageError::Invalid(format!("create segment: {e}")))?;
            segments.insert(
                id,
                Arc::new(SegmentFile {
                    path: seg_path(dir, id),
                    file,
                }),
            );
            kept.push(id);
            (id, 0)
        };

        let used: u64 = replayed.values().map(|r| r.len).sum();
        let live_framed: u64 = replayed.values().map(|r| r.len + SEG_HEADER as u64).sum();
        recovery.chunks_recovered = replayed.len();
        recovery.bytes_recovered = used;
        let backend = SegBackend {
            dir: dir.to_path_buf(),
            dir_handle,
            cfg,
            index: RwLock::new(replayed),
            segments: RwLock::new(segments),
            writer: Mutex::new(SegWriter {
                active,
                offset,
                unsynced: 0,
                next_id,
                order: kept.clone(),
            }),
            mapped: Mutex::new(MappedSegs::default()),
            inflight: Inflight::default(),
            active_id: AtomicU64::new(active),
            used: AtomicU64::new(used),
            dead: AtomicU64::new(total_bytes.saturating_sub(live_framed)),
            compacting: AtomicBool::new(false),
            read_failures: AtomicU64::new(0),
        };
        // Re-publish the list: prunes the fallback ordering and any
        // listed segment that vanished; a no-op otherwise.
        backend
            .write_meta(&kept)
            .map_err(|e| StorageError::Invalid(format!("write segment list: {e}")))?;
        Ok((backend, recovery))
    }

    /// Atomically publish the segment list: temp file + fsync + rename
    /// + directory fsync. The list is recovery's source of truth, so
    /// this rewrite is the commit point of both segment rolls and
    /// compaction flips.
    fn write_meta(&self, order: &[u64]) -> std::io::Result<()> {
        let tmp = self.dir.join(".segments.meta.tmp");
        let mut f = std::fs::File::create(&tmp)?;
        for id in order {
            writeln!(f, "{}", seg_file_name(*id))?;
        }
        f.sync_all()?;
        std::fs::rename(&tmp, self.dir.join(SEG_META))?;
        self.dir_handle.sync_all()
    }

    /// Seal the active segment (final fsync) and start a fresh one,
    /// publishing the extended segment list before any record lands in
    /// the new file. Called under the writer mutex.
    fn roll(&self, w: &mut SegWriter) -> Result<(), StorageError> {
        if w.unsynced > 0 {
            let sealed = self.segments.read().unwrap().get(&w.active).cloned();
            if let Some(sealed) = sealed {
                sealed
                    .file
                    .sync_all()
                    .map_err(|e| StorageError::Invalid(format!("seal segment: {e}")))?;
            }
            w.unsynced = 0;
        }
        let id = w.next_id;
        let file = open_segment(&self.dir, id, true)
            .map_err(|e| StorageError::Invalid(format!("create segment: {e}")))?;
        self.segments.write().unwrap().insert(
            id,
            Arc::new(SegmentFile {
                path: seg_path(&self.dir, id),
                file,
            }),
        );
        let mut order = w.order.clone();
        order.push(id);
        if let Err(e) = self.write_meta(&order) {
            // The new segment never activated: take it back out so a
            // retried roll can re-create it.
            self.segments.write().unwrap().remove(&id);
            let _ = std::fs::remove_file(seg_path(&self.dir, id));
            return Err(StorageError::Invalid(format!("publish segment list: {e}")));
        }
        w.order = order;
        w.next_id = id + 1;
        w.active = id;
        w.offset = 0;
        self.active_id.store(id, Ordering::Release);
        Ok(())
    }

    /// Append one framed record to the active segment under the writer
    /// mutex, rolling first when it would overflow, group-committing
    /// per [`SegConfig::group_commit_bytes`]. Returns the segment id
    /// and payload offset where the record landed.
    fn append_record(
        &self,
        op: u8,
        key: ChunkKey,
        payload: &[u8],
        crc: u64,
    ) -> Result<(u64, u64), StorageError> {
        let total = (SEG_HEADER + payload.len()) as u64;
        let mut w = self.writer.lock().unwrap();
        if w.offset > 0 && w.offset + total > self.cfg.segment_bytes {
            self.roll(&mut w)?;
        }
        let seg = self
            .segments
            .read()
            .unwrap()
            .get(&w.active)
            .cloned()
            .expect("active segment is always open");
        let mut buf = Vec::with_capacity(total as usize);
        buf.extend_from_slice(&seg_header_bytes(op, key, payload.len() as u64, crc));
        buf.extend_from_slice(payload);
        if let Err(e) = (&seg.file).write_all(&buf) {
            // Contain the wreckage: truncate back to the last record
            // boundary so later appends cannot fuse onto a partial
            // record (recovery would tear the whole tail off).
            let _ = seg.file.set_len(w.offset);
            return Err(StorageError::Invalid(format!(
                "append chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        let payload_off = w.offset + SEG_HEADER as u64;
        w.offset += total;
        w.unsynced += total;
        if self.cfg.group_commit_bytes == 0 || w.unsynced >= self.cfg.group_commit_bytes {
            if let Err(e) = seg.file.sync_all() {
                return Err(StorageError::Invalid(format!(
                    "commit segment in {}: {e}",
                    self.dir.display()
                )));
            }
            w.unsynced = 0;
        }
        Ok((w.active, payload_off))
    }

    /// Read one record's payload and verify its checksum. `None` means
    /// it could not be read back intact *right now* — the caller
    /// decides whether that is a benign race (the index moved on) or a
    /// fault.
    fn read_record(&self, key: ChunkKey, rec: SegRecord) -> Option<Vec<u8>> {
        let payload = self.read_payload(rec)?;
        (seg_record_crc(SEG_PUT, key, &payload) == rec.crc).then_some(payload)
    }

    /// Fetch `rec`'s payload bytes: sealed segments serve from the
    /// `Arc`-mapped buffer (no syscall), everything else — the active
    /// segment, or a sealed one past the map budget — takes a
    /// positional read through the shared handle.
    fn read_payload(&self, rec: SegRecord) -> Option<Vec<u8>> {
        if rec.seg != self.active_id.load(Ordering::Acquire) {
            if let Some(buf) = self.mapped_segment(rec.seg) {
                let start = rec.offset as usize;
                let end = start.checked_add(rec.len as usize)?;
                if end <= buf.len() {
                    return Some(buf[start..end].to_vec());
                }
                return None;
            }
        }
        let seg = self.segments.read().unwrap().get(&rec.seg).cloned()?;
        pread_exact(&seg, rec.offset, rec.len as usize).ok()
    }

    /// The whole-segment buffer for a sealed segment, loaded on first
    /// touch and evicted oldest-first past the byte budget. `None`
    /// when the segment alone exceeds the budget (a positional read is
    /// cheaper than churning the whole map) or the load failed.
    fn mapped_segment(&self, id: u64) -> Option<Arc<Vec<u8>>> {
        if let Some(buf) = self.mapped.lock().unwrap().bufs.get(&id) {
            return Some(Arc::clone(buf));
        }
        let seg = self.segments.read().unwrap().get(&id).cloned()?;
        let raw = std::fs::read(&seg.path).ok()?;
        if raw.len() as u64 > self.cfg.map_budget_bytes {
            return None;
        }
        let buf = Arc::new(raw);
        let mut mapped = self.mapped.lock().unwrap();
        if let Some(existing) = mapped.bufs.get(&id) {
            // Two readers raced the first touch; keep one buffer.
            return Some(Arc::clone(existing));
        }
        mapped.bytes += buf.len() as u64;
        mapped.bufs.insert(id, Arc::clone(&buf));
        mapped.order.push_back(id);
        while mapped.bytes > self.cfg.map_budget_bytes {
            let Some(oldest) = mapped.order.pop_front() else {
                break;
            };
            if let Some(b) = mapped.bufs.remove(&oldest) {
                mapped.bytes -= b.len() as u64;
            }
        }
        Some(buf)
    }

    /// Has enough garbage accumulated to justify a rewrite?
    fn compact_pending(&self) -> bool {
        self.dead.load(Ordering::Relaxed) >= self.cfg.compact_dead_bytes
    }

    /// Rewrite sealed segments, dropping dead records. Single-flight;
    /// concurrent callers — and calls with nothing to do — return
    /// `false` immediately. This is [`ChunkBackend::maintain`] for the
    /// packed log; the store schedules it on the I/O pool.
    ///
    /// The protocol, crash-safe at every step because the segment-list
    /// flip is the only commit point:
    /// 1. Snapshot the sealed segment ids (forcing a roll first when
    ///    all the garbage sits in the active segment) and the live
    ///    records pointing into them.
    /// 2. Copy those records into fresh segments written as `*.tmp`,
    ///    fsynced, then renamed into place — still unlisted, so a
    ///    crash here leaves only orphans for recovery to sweep.
    /// 3. Flip: splice the rewrites in front of the surviving order
    ///    and atomically publish the new segment list. Replay order is
    ///    preserved — anything written since the snapshot sits later
    ///    in the list and still wins.
    /// 4. Retarget index entries that still point into the compacted
    ///    segments (a chunk overwritten or deleted mid-compaction
    ///    keeps its newer truth; its copy in the rewrite is just dead
    ///    weight), then drop handles, mapped buffers, and the old
    ///    files. A reader mid-`get` keeps its `Arc`'d handle across
    ///    the unlink; its retry re-reads the index and lands on the
    ///    rewrite.
    pub fn maintain(&self) -> bool {
        lockscope::assert_unlocked("SegBackend::maintain");
        if !self.compact_pending() {
            return false;
        }
        if self.compacting.swap(true, Ordering::SeqCst) {
            return false;
        }
        let did = self.compact().unwrap_or(false);
        self.compacting.store(false, Ordering::SeqCst);
        did
    }

    fn compact(&self) -> Result<bool, StorageError> {
        // Step 1: the sealed snapshot.
        let sealed: Vec<u64> = {
            let mut w = self.writer.lock().unwrap();
            if w.order.len() <= 1 {
                if w.offset == 0 {
                    return Ok(false);
                }
                // All the garbage sits in the active segment: seal it
                // so the rewrite below can reclaim the space.
                self.roll(&mut w)?;
            }
            w.order[..w.order.len() - 1].to_vec()
        };
        if sealed.is_empty() {
            return Ok(false);
        }
        let sealed_set: HashSet<u64> = sealed.iter().copied().collect();
        let mut live: Vec<(ChunkKey, SegRecord)> = self
            .index
            .read()
            .unwrap()
            .iter()
            .filter(|(_, r)| sealed_set.contains(&r.seg))
            .map(|(k, r)| (*k, *r))
            .collect();
        // Deterministic output layout.
        live.sort_unstable_by_key(|(_, r)| (r.seg, r.offset));
        let old_bytes: u64 = sealed
            .iter()
            .filter_map(|id| std::fs::metadata(seg_path(&self.dir, *id)).ok())
            .map(|m| m.len())
            .sum();

        // Step 2: copy live records into fresh segments.
        let io_err = |what: &str, e: std::io::Error| {
            StorageError::Invalid(format!("compact {}: {what}: {e}", self.dir.display()))
        };
        let mut new_segs: Vec<u64> = Vec::new();
        let mut moved: Vec<(ChunkKey, SegRecord, SegRecord)> = Vec::new();
        let mut new_bytes = 0u64;
        let mut cur: Option<(u64, std::fs::File, u64)> = None;
        for (key, old) in live {
            let payload = match self.read_record(key, old) {
                Some(p) => p,
                // A sealed record that cannot be read back intact:
                // abort with everything in place — reads will surface
                // the damage, and unlinking the segment here would
                // destroy the healthy records around it.
                None => return Ok(false),
            };
            let total = (SEG_HEADER + payload.len()) as u64;
            if let Some((id, f, len)) = cur.take() {
                if len > 0 && len + total > self.cfg.segment_bytes {
                    f.sync_all().map_err(|e| io_err("seal rewrite", e))?;
                    new_segs.push(id);
                    new_bytes += len;
                } else {
                    cur = Some((id, f, len));
                }
            }
            if cur.is_none() {
                let id = {
                    let mut w = self.writer.lock().unwrap();
                    let id = w.next_id;
                    w.next_id += 1;
                    id
                };
                let f = std::fs::File::create(tmp_seg_path(&self.dir, id))
                    .map_err(|e| io_err("create rewrite", e))?;
                cur = Some((id, f, 0));
            }
            let (id, mut f, len) = cur.take().unwrap();
            let mut buf = Vec::with_capacity(total as usize);
            buf.extend_from_slice(&seg_header_bytes(SEG_PUT, key, old.len, old.crc));
            buf.extend_from_slice(&payload);
            f.write_all(&buf).map_err(|e| io_err("write rewrite", e))?;
            moved.push((
                key,
                old,
                SegRecord {
                    seg: id,
                    offset: len + SEG_HEADER as u64,
                    len: old.len,
                    crc: old.crc,
                },
            ));
            cur = Some((id, f, len + total));
        }
        if let Some((id, f, len)) = cur.take() {
            f.sync_all().map_err(|e| io_err("seal rewrite", e))?;
            new_segs.push(id);
            new_bytes += len;
        }
        for id in &new_segs {
            std::fs::rename(tmp_seg_path(&self.dir, *id), seg_path(&self.dir, *id))
                .map_err(|e| io_err("publish rewrite", e))?;
        }
        self.dir_handle
            .sync_all()
            .map_err(|e| io_err("sync dir", e))?;
        // Open read handles before the index flip so a get landing on
        // a retargeted record finds its segment.
        {
            let mut segs = self.segments.write().unwrap();
            for id in &new_segs {
                let file = open_segment(&self.dir, *id, false)
                    .map_err(|e| io_err("reopen rewrite", e))?;
                segs.insert(
                    *id,
                    Arc::new(SegmentFile {
                        path: seg_path(&self.dir, *id),
                        file,
                    }),
                );
            }
        }

        // Step 3: the flip.
        {
            let mut w = self.writer.lock().unwrap();
            let mut order = new_segs.clone();
            order.extend(w.order.iter().copied().filter(|id| !sealed_set.contains(id)));
            self.write_meta(&order)
                .map_err(|e| io_err("publish segment list", e))?;
            w.order = order;
        }

        // Step 4: retarget, unaccount, drop.
        let mut stale = 0u64;
        {
            let mut idx = self.index.write().unwrap();
            for (key, old, new) in &moved {
                match idx.get_mut(key) {
                    Some(r) if *r == *old => *r = *new,
                    _ => stale += SEG_HEADER as u64 + old.len,
                }
            }
        }
        {
            let mut segs = self.segments.write().unwrap();
            for id in &sealed {
                segs.remove(id);
            }
        }
        {
            let mut mapped = self.mapped.lock().unwrap();
            for id in &sealed {
                if let Some(buf) = mapped.bufs.remove(id) {
                    mapped.bytes -= buf.len() as u64;
                }
            }
            mapped.order.retain(|id| !sealed_set.contains(id));
        }
        for id in &sealed {
            let _ = std::fs::remove_file(seg_path(&self.dir, *id));
        }
        let freed = old_bytes.saturating_sub(new_bytes);
        let freed_now = freed.min(self.dead.load(Ordering::Relaxed));
        self.dead.fetch_sub(freed_now, Ordering::Relaxed);
        self.dead.fetch_add(stale, Ordering::Relaxed);
        Ok(true)
    }
}

impl ChunkBackend for SegBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        lockscope::assert_unlocked("SegBackend::put");
        // Reserve → write → publish, exactly the file backend's
        // discipline: the per-key slot serializes same-key mutations,
        // the append runs under the backend's writer mutex alone, and
        // the metadata-only index insert afterwards is the
        // linearization point.
        let _slot = self.inflight.lock(key);
        let crc = seg_record_crc(SEG_PUT, key, bytes);
        match self.append_record(SEG_PUT, key, bytes, crc) {
            Ok((seg, offset)) => {
                let rec = SegRecord {
                    seg,
                    offset,
                    len: bytes.len() as u64,
                    crc,
                };
                if let Some(old) = self.index.write().unwrap().insert(key, rec) {
                    self.used.fetch_sub(old.len, Ordering::Relaxed);
                    self.dead
                        .fetch_add(old.len + SEG_HEADER as u64, Ordering::Relaxed);
                }
                self.used.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                // The record's durability is undefined (a group-commit
                // fsync can fail after the bytes landed). Make the
                // failure consistent, exactly like the file backend:
                // the chunk is gone — retire any old entry and lay a
                // best-effort tombstone so replay cannot resurrect the
                // half-committed record.
                if let Some(old) = self.index.write().unwrap().remove(&key) {
                    self.used.fetch_sub(old.len, Ordering::Relaxed);
                    self.dead
                        .fetch_add(old.len + SEG_HEADER as u64, Ordering::Relaxed);
                }
                let del_crc = seg_record_crc(SEG_DEL, key, &[]);
                let _ = self.append_record(SEG_DEL, key, &[], del_crc);
                Err(e)
            }
        }
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        lockscope::assert_unlocked("SegBackend::get");
        // Snapshot the record under the read lock, read the segment
        // with no lock held, verify against the snapshot. On failure
        // re-check the index: entry gone → the benign delete race
        // (absent, not a fault); entry moved → a compaction retargeted
        // it — retry against the new truth before declaring a genuine
        // disk fault.
        const ATTEMPTS: usize = 3;
        for attempt in 0..ATTEMPTS {
            let rec = match self.index.read().unwrap().get(&key) {
                Some(rec) => *rec,
                None => return Ok(None),
            };
            if let Some(bytes) = self.read_record(key, rec) {
                return Ok(Some(bytes));
            }
            if attempt + 1 < ATTEMPTS {
                std::thread::yield_now();
            }
        }
        self.read_failures.fetch_add(1, Ordering::Relaxed);
        Err(StorageError::Invalid(format!(
            "chunk {}#{} unreadable in {}",
            key.0 .0,
            key.1,
            self.dir.display()
        )))
    }

    fn delete(&self, key: ChunkKey) {
        lockscope::assert_unlocked("SegBackend::delete");
        // Retire the index entry first — a reader that loses the race
        // finds the entry gone and reports absent — then log the
        // tombstone so replay agrees.
        let _slot = self.inflight.lock(key);
        let removed = self.index.write().unwrap().remove(&key);
        if let Some(old) = removed {
            self.used.fetch_sub(old.len, Ordering::Relaxed);
            // The retired record and the tombstone itself are both
            // dead weight in the log now.
            self.dead
                .fetch_add(old.len + 2 * SEG_HEADER as u64, Ordering::Relaxed);
            let crc = seg_record_crc(SEG_DEL, key, &[]);
            let _ = self.append_record(SEG_DEL, key, &[], crc);
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.index.read().unwrap().len()
    }

    fn read_errors(&self) -> u64 {
        self.read_failures.load(Ordering::Relaxed)
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        self.index.read().unwrap().keys().copied().collect()
    }

    fn maintain(&self) -> bool {
        SegBackend::maintain(self)
    }

    fn io_depth(&self) -> u64 {
        self.inflight.keys.lock().unwrap().len() as u64
    }
}

/// Owner of an auto-created `--data-dir`: removes the whole tree on
/// drop. Only directories the store itself created are guarded —
/// a user-supplied `data_dir` is never deleted.
pub(crate) struct DirGuard {
    pub(crate) path: PathBuf,
}

impl Drop for DirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// A process-unique directory for a store that asked for the disk
/// backend without naming a `data_dir`. Rooted at `WOSS_DATA_DIR` when
/// set (the CI matrix points this into a tempdir it can audit for
/// stray files), else the system temp directory.
pub(crate) fn auto_data_dir() -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let base = std::env::var_os("WOSS_DATA_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    base.join(format!(
        "woss-live-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn key(f: u64, c: u64) -> ChunkKey {
        (FileId(f), c)
    }

    fn temp_backend(tag: &str) -> (PathBuf, FileBackend) {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-test-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let backend = FileBackend::new(&dir).unwrap();
        (dir, backend)
    }

    #[test]
    fn memory_roundtrip_and_accounting() {
        let b = MemoryBackend::default();
        assert!(b.put(key(1, 0), &[7u8; 100]).is_ok());
        assert!(b.put(key(1, 1), &[8u8; 50]).is_ok());
        assert_eq!(b.used_bytes(), 150);
        assert_eq!(b.chunk_count(), 2);
        assert_eq!(b.get(key(1, 0)).unwrap(), Some(vec![7u8; 100]));
        assert!(b.contains(key(1, 1)));
        // Overwrite replaces the accounting, not adds to it.
        assert!(b.put(key(1, 0), &[9u8; 10]).is_ok());
        assert_eq!(b.used_bytes(), 60);
        b.delete(key(1, 0));
        b.delete(key(1, 0)); // idempotent
        assert_eq!(b.used_bytes(), 50);
        assert!(!b.contains(key(1, 0)));
        assert_eq!(b.read_errors(), 0);
    }

    #[test]
    fn file_backend_roundtrip_and_disk_files() {
        let (dir, b) = temp_backend("roundtrip");
        let payload: Vec<u8> = (0..70_000u32).map(|i| (i % 251) as u8).collect();
        b.put(key(3, 2), &payload).unwrap();
        assert!(dir.join("f3_c2.chunk").exists(), "one file per chunk");
        assert_eq!(b.get(key(3, 2)).unwrap(), Some(payload));
        assert_eq!(b.used_bytes(), 70_000);
        assert_eq!(b.chunk_count(), 1);
        assert!(b.get(key(3, 3)).unwrap().is_none());

        // Delete removes the on-disk file; only the manifest remains in
        // the directory afterwards.
        b.delete(key(3, 2));
        assert!(!dir.join("f3_c2.chunk").exists(), "delete unlinks");
        assert_eq!(b.used_bytes(), 0);
        assert_eq!(chunk_files_under(&dir), 0, "no stray chunk files");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_backend_put_leaves_no_temp_files() {
        let (dir, b) = temp_backend("tmpfiles");
        for c in 0..8u64 {
            b.put(key(1, c), &vec![c as u8; 1000]).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names.len(), 9, "8 chunks + the manifest");
        assert!(
            names
                .iter()
                .all(|n| n.ends_with(".chunk") || n == MANIFEST),
            "temp files must not survive a completed put: {names:?}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fresh_open_refuses_previous_store_dir() {
        let (dir, b) = temp_backend("refuse");
        b.put(key(1, 0), &[1u8; 100]).unwrap();
        drop(b);
        assert!(
            FileBackend::new(&dir).is_err(),
            "a dir with a manifest must be open_existing'd, not blanked"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recovery_roundtrips_published_chunks() {
        let (dir, b) = temp_backend("recover");
        let p0: Vec<u8> = (0..50_000u32).map(|i| (i % 13) as u8).collect();
        let p1: Vec<u8> = (0..70_000u32).map(|i| (i % 17) as u8).collect();
        b.put(key(1, 0), &p0).unwrap();
        b.put(key(1, 1), &p1).unwrap();
        b.put(key(2, 0), &p0).unwrap();
        b.delete(key(2, 0));
        drop(b); // crash: no clean shutdown exists at this layer
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 2);
        assert_eq!(rec.bytes_recovered, 120_000);
        assert_eq!(rec.torn_records, 0);
        assert_eq!(rec.corrupt_chunks, 0);
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(p0));
        assert_eq!(b2.get(key(1, 1)).unwrap(), Some(p1));
        assert!(!b2.contains(key(2, 0)), "deleted chunk stays deleted");
        assert_eq!(b2.used_bytes(), 120_000);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_manifest_tail_is_discarded_valid_prefix_kept() {
        let (dir, b) = temp_backend("torn");
        b.put(key(1, 0), &[1u8; 1000]).unwrap();
        b.put(key(1, 1), &[2u8; 1000]).unwrap();
        drop(b);
        // Simulate a crash mid-append: a record without its newline.
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(MANIFEST))
            .unwrap();
        f.write_all(b"put 1 2 10").unwrap();
        drop(f);
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 2, "valid prefix survives");
        assert_eq!(rec.torn_records, 1, "torn tail dropped");
        assert!(!b2.contains(key(1, 2)));
        // The compacted manifest replays clean a second time.
        drop(b2);
        let (_b3, rec3) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec3.torn_records, 0, "compaction erased the torn tail");
        assert_eq!(rec3.chunks_recovered, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn garbled_manifest_record_skipped_later_records_survive() {
        let (dir, b) = temp_backend("garbled");
        b.put(key(1, 0), &[1u8; 500]).unwrap();
        drop(b);
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(MANIFEST))
            .unwrap();
        // A terminated-but-garbled line (a damaged record) followed by
        // a well-formed record whose chunk file never existed. The
        // damage must stay confined to the garbled line — the later
        // record replays and then falls to chunk verification.
        f.write_all(b"zzz not a record\nput 9 9 5 0000000000000000\n")
            .unwrap();
        f.sync_all().unwrap();
        drop(f);
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 1);
        assert_eq!(rec.torn_records, 1, "only the garbled line is dropped");
        assert_eq!(rec.corrupt_chunks, 1, "the replayed record had no file");
        assert!(!b2.contains(key(9, 9)));
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(vec![1u8; 500]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn orphan_chunk_files_are_salvage_cleaned() {
        let (dir, b) = temp_backend("orphan");
        b.put(key(1, 0), &[3u8; 400]).unwrap();
        drop(b);
        // A put that renamed but crashed before its manifest fsync, and
        // a stale temp file.
        std::fs::write(dir.join("f8_c0.chunk"), [9u8; 100]).unwrap();
        std::fs::write(dir.join(".put-77.tmp"), [9u8; 100]).unwrap();
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.orphan_files, 1);
        assert_eq!(rec.chunks_recovered, 1);
        assert!(!dir.join("f8_c0.chunk").exists(), "orphan unlinked");
        assert!(!dir.join(".put-77.tmp").exists(), "temp swept");
        assert!(!b2.contains(key(8, 0)));
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(vec![3u8; 400]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_chunk_file_dropped_at_recovery() {
        let (dir, b) = temp_backend("corrupt");
        b.put(key(1, 0), &[4u8; 600]).unwrap();
        b.put(key(1, 1), &[5u8; 600]).unwrap();
        drop(b);
        // Same length, different bytes: only the checksum catches it.
        std::fs::write(dir.join("f1_c0.chunk"), [0u8; 600]).unwrap();
        // Truncated: the length check catches it.
        std::fs::write(dir.join("f1_c1.chunk"), [5u8; 10]).unwrap();
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.corrupt_chunks, 2);
        assert_eq!(rec.chunks_recovered, 0);
        assert!(!b2.contains(key(1, 0)));
        assert!(!dir.join("f1_c0.chunk").exists(), "damaged file removed");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn get_distinguishes_absent_from_read_failure() {
        let (dir, b) = temp_backend("readfail");
        b.put(key(1, 0), &[6u8; 800]).unwrap();
        // Absent is a clean miss, not an error.
        assert_eq!(b.get(key(1, 9)).unwrap(), None);
        assert_eq!(b.read_errors(), 0);
        // Corrupt the file behind the index's back: the read must
        // surface a failure, not report the chunk absent.
        std::fs::write(dir.join("f1_c0.chunk"), [0u8; 800]).unwrap();
        assert!(b.get(key(1, 0)).is_err(), "corruption is an error");
        std::fs::remove_file(dir.join("f1_c0.chunk")).unwrap();
        assert!(b.get(key(1, 0)).is_err(), "vanished-but-indexed is an error");
        assert_eq!(b.read_errors(), 2);
        assert!(b.contains(key(1, 0)), "index still claims it — that is the point");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn put_delete_race_never_leaves_index_and_disk_disagreeing() {
        // The regression this guards: delete removed the index entry
        // under the lock but unlinked after dropping it, so a racing
        // put could rename a fresh chunk into place and have it
        // unlinked while the index said present (contains() true,
        // get() None). With rename/unlink serialized under the lock,
        // an indexed chunk always has its file.
        let (dir, b) = temp_backend("race");
        let b = Arc::new(b);
        let payload = vec![7u8; 4096];
        std::thread::scope(|scope| {
            let putter = Arc::clone(&b);
            let p = payload.clone();
            scope.spawn(move || {
                for _ in 0..300 {
                    putter.put(key(1, 0), &p).unwrap();
                }
            });
            let deleter = Arc::clone(&b);
            scope.spawn(move || {
                for _ in 0..300 {
                    deleter.delete(key(1, 0));
                }
            });
            let checker = Arc::clone(&b);
            let p = payload.clone();
            scope.spawn(move || {
                for _ in 0..300 {
                    // Present implies readable with the right bytes;
                    // absent is fine. Never "present but unreadable".
                    match checker.get(key(1, 0)) {
                        Ok(Some(bytes)) => assert_eq!(bytes, p),
                        Ok(None) => {}
                        Err(e) => panic!("indexed chunk unreadable mid-race: {e}"),
                    }
                }
            });
        });
        // Settle into a known state and re-check the invariant cold.
        b.put(key(1, 0), &payload).unwrap();
        assert!(b.contains(key(1, 0)));
        assert_eq!(b.get(key(1, 0)).unwrap(), Some(payload));
        assert_eq!(b.read_errors(), 0, "the race must not manufacture disk faults");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn chunk_files_under_survives_symlink_cycle() {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-test-{}-symlink",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("sub/f1_c0.chunk"), [1u8; 10]).unwrap();
        // A cycle: sub/loop → the data dir itself. Following it would
        // recurse forever; the audit must skip it and still count the
        // real chunk file.
        std::os::unix::fs::symlink(&dir, dir.join("sub/loop")).unwrap();
        assert_eq!(chunk_files_under(&dir), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn backend_kind_parse_and_label() {
        assert_eq!("mem".parse::<BackendKind>().unwrap(), BackendKind::Memory);
        assert_eq!("DISK".parse::<BackendKind>().unwrap(), BackendKind::Disk);
        assert_eq!("seg".parse::<BackendKind>().unwrap(), BackendKind::Seg);
        assert_eq!("Segment".parse::<BackendKind>().unwrap(), BackendKind::Seg);
        assert!("floppy".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::Memory.label(), "mem");
        assert_eq!(BackendKind::Disk.label(), "disk");
        assert_eq!(BackendKind::Seg.label(), "seg");
        assert!(!BackendKind::Memory.is_persistent());
        assert!(BackendKind::Disk.is_persistent());
        assert!(BackendKind::Seg.is_persistent());
    }

    #[test]
    fn long_lived_manifest_stays_bounded() {
        // The PR 5 follow-on bug: the manifest only compacted at
        // reopen, so a long-lived node's log grew with its operation
        // history. Churn one small key set far past the dead-record
        // threshold and require the file to stay bounded by live
        // chunks + threshold, not by the churn count.
        let (dir, b) = temp_backend("boundedlog");
        let rounds = MANIFEST_COMPACT_DEAD * 2;
        for round in 0..rounds {
            let k = key(1, round % 4);
            b.put(k, &[round as u8; 64]).unwrap();
            b.delete(k);
        }
        b.put(key(2, 0), &[9u8; 64]).unwrap();
        let lines = std::fs::read_to_string(dir.join(MANIFEST))
            .unwrap()
            .lines()
            .count() as u64;
        assert!(
            lines <= MANIFEST_COMPACT_DEAD + 8,
            "manifest must stay bounded under churn: {lines} lines after {rounds} rounds"
        );
        // The compacted log still replays to the live truth.
        drop(b);
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 1);
        assert_eq!(b2.get(key(2, 0)).unwrap(), Some(vec![9u8; 64]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Tiny segments + per-record fsync: every structural edge (rolls,
    /// group commit, compaction) triggers with a handful of small
    /// chunks.
    fn tiny_cfg() -> SegConfig {
        SegConfig {
            segment_bytes: 4096,
            group_commit_bytes: 0,
            compact_dead_bytes: 2048,
            map_budget_bytes: 1 << 20,
        }
    }

    fn temp_seg(tag: &str, cfg: SegConfig) -> (PathBuf, SegBackend) {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-test-{}-seg-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let backend = SegBackend::with_config(&dir, cfg).unwrap();
        (dir, backend)
    }

    fn seg_disk_bytes(dir: &PathBuf) -> u64 {
        std::fs::read_dir(dir)
            .unwrap()
            .flatten()
            .filter(|e| parse_seg_name(&e.file_name().to_string_lossy()).is_some())
            .map(|e| e.metadata().unwrap().len())
            .sum()
    }

    #[test]
    fn seg_roundtrip_and_accounting() {
        let (dir, b) = temp_seg("roundtrip", tiny_cfg());
        let payload: Vec<u8> = (0..3000u32).map(|i| (i % 251) as u8).collect();
        b.put(key(3, 2), &payload).unwrap();
        assert_eq!(b.get(key(3, 2)).unwrap(), Some(payload));
        assert_eq!(b.used_bytes(), 3000);
        assert_eq!(b.chunk_count(), 1);
        assert!(b.get(key(3, 3)).unwrap().is_none());
        // Overwrite replaces the accounting; delete zeroes it.
        b.put(key(3, 2), &[9u8; 10]).unwrap();
        assert_eq!(b.used_bytes(), 10);
        assert_eq!(b.get(key(3, 2)).unwrap(), Some(vec![9u8; 10]));
        b.delete(key(3, 2));
        b.delete(key(3, 2)); // idempotent
        assert_eq!(b.used_bytes(), 0);
        assert!(!b.contains(key(3, 2)));
        assert_eq!(b.read_errors(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_packs_many_chunks_into_few_files() {
        let (dir, b) = temp_seg("packed", tiny_cfg());
        for c in 0..200u64 {
            b.put(key(1, c), &[c as u8; 64]).unwrap();
        }
        for c in 0..200u64 {
            assert_eq!(b.get(key(1, c)).unwrap(), Some(vec![c as u8; 64]));
        }
        let files = segment_files_under(&dir);
        assert!(files > 1, "4 KiB segments must have rolled: {files}");
        assert!(files < 20, "file count stays O(segments): {files}");
        assert_eq!(chunk_files_under(&dir), 0, "no per-chunk files");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_fresh_open_refuses_previous_store_dir() {
        let (dir, b) = temp_seg("refuse", tiny_cfg());
        b.put(key(1, 0), &[1u8; 100]).unwrap();
        drop(b);
        assert!(
            SegBackend::new(&dir).is_err(),
            "a dir with a segment list must be open_existing'd, not shadowed"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_recovery_roundtrips_published_chunks() {
        let (dir, b) = temp_seg("recover", tiny_cfg());
        let p0: Vec<u8> = (0..5000u32).map(|i| (i % 13) as u8).collect();
        let p1: Vec<u8> = (0..7000u32).map(|i| (i % 17) as u8).collect();
        b.put(key(1, 0), &p0).unwrap();
        b.put(key(1, 1), &p1).unwrap();
        b.put(key(2, 0), &p0).unwrap();
        b.delete(key(2, 0));
        drop(b); // crash: no clean shutdown exists at this layer
        let (b2, rec) = SegBackend::open_existing_with(&dir, tiny_cfg()).unwrap();
        assert_eq!(rec.chunks_recovered, 2);
        assert_eq!(rec.bytes_recovered, 12_000);
        assert_eq!(rec.torn_records, 0);
        assert_eq!(rec.corrupt_chunks, 0);
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(p0));
        assert_eq!(b2.get(key(1, 1)).unwrap(), Some(p1));
        assert!(!b2.contains(key(2, 0)), "deleted chunk stays deleted");
        assert_eq!(b2.used_bytes(), 12_000);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_torn_tail_is_discarded_valid_prefix_kept() {
        let (dir, b) = temp_seg("torn", tiny_cfg());
        b.put(key(1, 0), &[1u8; 100]).unwrap();
        b.put(key(1, 1), &[2u8; 100]).unwrap();
        drop(b);
        // Simulate a crash mid-append: a record header cut short at
        // the tail of the active segment.
        let meta = std::fs::read_to_string(dir.join(SEG_META)).unwrap();
        let last = meta.lines().last().unwrap().to_string();
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(&last))
            .unwrap();
        f.write_all(&[SEG_PUT, 9, 9, 9]).unwrap();
        drop(f);
        let (b2, rec) = SegBackend::open_existing_with(&dir, tiny_cfg()).unwrap();
        assert_eq!(rec.chunks_recovered, 2, "valid prefix survives");
        assert_eq!(rec.torn_records, 1, "torn tail dropped");
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(vec![1u8; 100]));
        assert_eq!(b2.get(key(1, 1)).unwrap(), Some(vec![2u8; 100]));
        // The truncation erased the tail: a second replay is clean.
        drop(b2);
        let (_b3, rec3) = SegBackend::open_existing_with(&dir, tiny_cfg()).unwrap();
        assert_eq!(rec3.torn_records, 0, "truncation erased the torn tail");
        assert_eq!(rec3.chunks_recovered, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_corrupt_record_skipped_later_records_survive() {
        let (dir, b) = temp_seg("corrupt", tiny_cfg());
        b.put(key(1, 0), &[1u8; 100]).unwrap();
        b.put(key(1, 1), &[2u8; 100]).unwrap();
        b.put(key(1, 2), &[3u8; 100]).unwrap();
        drop(b);
        // Flip one payload byte in the middle record, framing intact:
        // only that record may fall, and only to the checksum.
        let seg0 = dir.join("seg-0.log");
        let mut raw = std::fs::read(&seg0).unwrap();
        let mid = (SEG_HEADER + 100) + SEG_HEADER + 50;
        raw[mid] ^= 0xff;
        std::fs::write(&seg0, &raw).unwrap();
        let (b2, rec) = SegBackend::open_existing_with(&dir, tiny_cfg()).unwrap();
        assert_eq!(rec.corrupt_chunks, 1, "the damaged record alone");
        assert_eq!(rec.torn_records, 0);
        assert_eq!(rec.chunks_recovered, 2, "records after the damage replay");
        assert!(!b2.contains(key(1, 1)));
        assert_eq!(b2.get(key(1, 2)).unwrap(), Some(vec![3u8; 100]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_orphan_segments_and_tmp_files_swept() {
        let (dir, b) = temp_seg("orphan", tiny_cfg());
        b.put(key(1, 0), &[3u8; 100]).unwrap();
        drop(b);
        // A compaction the crash interrupted: an unlisted segment full
        // of stale (but well-formed) records, plus a temp file caught
        // mid-rewrite. Neither may resurrect anything.
        let stale_key = key(8, 0);
        let stale_crc = seg_record_crc(SEG_PUT, stale_key, b"dead");
        let mut stale = seg_header_bytes(SEG_PUT, stale_key, 4, stale_crc).to_vec();
        stale.extend_from_slice(b"dead");
        std::fs::write(dir.join("seg-77.log"), &stale).unwrap();
        std::fs::write(dir.join("seg-78.log.tmp"), &stale).unwrap();
        let (b2, rec) = SegBackend::open_existing_with(&dir, tiny_cfg()).unwrap();
        assert_eq!(rec.orphan_files, 1, "unlisted segment swept");
        assert!(!dir.join("seg-77.log").exists());
        assert!(!dir.join("seg-78.log.tmp").exists());
        assert!(!b2.contains(stale_key), "nothing resurrects from debris");
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(vec![3u8; 100]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_compaction_reclaims_dead_bytes_and_preserves_live_chunks() {
        let (dir, b) = temp_seg("compact", tiny_cfg());
        for c in 0..40u64 {
            b.put(key(1, c), &[c as u8; 200]).unwrap();
        }
        for c in 0..30u64 {
            b.delete(key(1, c));
        }
        let before = seg_disk_bytes(&dir);
        assert!(b.maintain(), "dead bytes past threshold must compact");
        let after = seg_disk_bytes(&dir);
        assert!(
            after < before,
            "compaction must shrink the log: {before} -> {after}"
        );
        for c in 30..40u64 {
            assert_eq!(b.get(key(1, c)).unwrap(), Some(vec![c as u8; 200]));
        }
        for c in 0..30u64 {
            assert!(b.get(key(1, c)).unwrap().is_none());
        }
        assert!(!b.maintain(), "nothing left to compact");
        // Survives a reopen: the flipped segment list is the truth.
        drop(b);
        let (b2, rec) = SegBackend::open_existing_with(&dir, tiny_cfg()).unwrap();
        assert_eq!(rec.chunks_recovered, 10);
        for c in 30..40u64 {
            assert_eq!(b2.get(key(1, c)).unwrap(), Some(vec![c as u8; 200]));
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_compaction_rolls_active_when_garbage_is_unsealed() {
        let cfg = SegConfig {
            segment_bytes: 1 << 20,
            group_commit_bytes: 0,
            compact_dead_bytes: 512,
            map_budget_bytes: 1 << 20,
        };
        let (dir, b) = temp_seg("rollcompact", cfg);
        for c in 0..10u64 {
            b.put(key(1, c), &[c as u8; 100]).unwrap();
        }
        for c in 0..9u64 {
            b.delete(key(1, c));
        }
        // Everything sits in the one active segment; maintain must
        // seal it first, then reclaim.
        assert!(b.maintain());
        assert_eq!(b.get(key(1, 9)).unwrap(), Some(vec![9u8; 100]));
        assert_eq!(b.chunk_count(), 1);
        let files = segment_files_under(&dir);
        assert!(files <= 2, "rewrite + fresh active: {files}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seg_put_delete_race_never_leaves_index_and_log_disagreeing() {
        let cfg = SegConfig {
            segment_bytes: 1 << 18,
            group_commit_bytes: 4096,
            compact_dead_bytes: 16 << 10,
            map_budget_bytes: 1 << 20,
        };
        let (dir, b) = temp_seg("race", cfg);
        let b = Arc::new(b);
        let payload = vec![7u8; 2048];
        std::thread::scope(|scope| {
            let putter = Arc::clone(&b);
            let p = payload.clone();
            scope.spawn(move || {
                for _ in 0..300 {
                    putter.put(key(1, 0), &p).unwrap();
                }
            });
            let deleter = Arc::clone(&b);
            scope.spawn(move || {
                for _ in 0..300 {
                    deleter.delete(key(1, 0));
                }
            });
            // Compaction churns underneath the race: retargeted
            // records must stay readable throughout.
            let compactor = Arc::clone(&b);
            scope.spawn(move || {
                for _ in 0..50 {
                    compactor.maintain();
                    std::thread::yield_now();
                }
            });
            let checker = Arc::clone(&b);
            let p = payload.clone();
            scope.spawn(move || {
                for _ in 0..300 {
                    // Present implies readable with the right bytes;
                    // absent is fine. Never "present but unreadable".
                    match checker.get(key(1, 0)) {
                        Ok(Some(bytes)) => assert_eq!(bytes, p),
                        Ok(None) => {}
                        Err(e) => panic!("indexed chunk unreadable mid-race: {e}"),
                    }
                }
            });
        });
        b.put(key(1, 0), &payload).unwrap();
        assert_eq!(b.get(key(1, 0)).unwrap(), Some(payload));
        assert_eq!(b.read_errors(), 0, "the race must not manufacture disk faults");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
