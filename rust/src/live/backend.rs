//! Pluggable per-node chunk storage for the live store.
//!
//! PR 3 left the live store an in-memory toy: every chunk was a
//! `Vec<u8>` in a per-node `HashMap`, so a workload whose intermediate
//! footprint exceeds RAM was simply impossible. This module extracts
//! that storage behind the object-safe [`ChunkBackend`] trait and adds
//! a second implementation:
//!
//! * [`MemoryBackend`] — the PR 3 `HashMap` store, byte for byte. The
//!   default, so existing deployments reproduce exactly.
//! * [`FileBackend`] — a file-backed **spill tier**: one file per chunk
//!   under a per-node directory, written via temp-file + rename so a
//!   chunk is never observable half-written. Deleting or reclaiming a
//!   chunk removes its on-disk file; a node directory owns no state
//!   beyond its chunk files.
//!
//! With the disk backend the hint-aware cache tier
//! ([`crate::live::LiveTuning::cache_bytes`]) becomes a true
//! memory-over-disk hot tier: a cache hit serves without touching the
//! disk, and `Lifetime=scratch` chunks may skip the spill entirely
//! (see [`crate::live::store`] — dirty cache entries write back on
//! eviction, so correctness never depends on the hint being truthful).

use crate::storage::types::{FileId, StorageError};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Key of one stored chunk: the owning file plus the chunk index.
pub type ChunkKey = (FileId, u64);

/// Which chunk-backend implementation a live deployment runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// In-memory `HashMap` chunk stores (the PR 3 behaviour, default).
    #[default]
    Memory,
    /// File-backed spill tier: one file per chunk under a per-node
    /// directory (temp-file + rename writes).
    Disk,
}

impl BackendKind {
    /// Resolve the backend from the `LIVE_BACKEND` environment variable
    /// (`mem` | `disk`, same lenient parser as the CLI's `--backend`),
    /// defaulting to [`BackendKind::Memory`] when unset. This is the CI
    /// matrix hook: `LIVE_BACKEND=disk cargo test` runs every live test
    /// against the spill tier without touching the tests — which is
    /// exactly why an unparseable value panics instead of silently
    /// falling back to memory: a typo'd matrix leg must fail loudly,
    /// not quietly re-run the mem tier.
    pub fn from_env() -> Self {
        match std::env::var("LIVE_BACKEND") {
            Ok(raw) => raw
                .parse()
                .unwrap_or_else(|e| panic!("LIVE_BACKEND: {e}")),
            Err(_) => BackendKind::Memory,
        }
    }

    /// Stable lowercase label (`mem` | `disk`) — the value the reserved
    /// `cache_state` attribute reports in its `tier=` field and the CLI
    /// accepts for `--backend`.
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Memory => "mem",
            BackendKind::Disk => "disk",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mem" | "memory" => Ok(BackendKind::Memory),
            "disk" | "file" => Ok(BackendKind::Disk),
            other => Err(format!("unknown backend '{other}' (mem|disk)")),
        }
    }
}

/// One storage node's authoritative chunk store, behind a trait so the
/// capacity tier is pluggable. Object-safe and `Send + Sync`: the live
/// store shares `Arc<Vec<Box<dyn ChunkBackend>>>` between the data
/// path and the background replication workers.
///
/// Implementations must make a `put` atomic with respect to concurrent
/// `get`s of the same key: a reader observes either the full chunk or
/// nothing, never a prefix ([`FileBackend`] writes a temp file and
/// renames it into place; [`MemoryBackend`] inserts under a write
/// lock).
pub trait ChunkBackend: Send + Sync {
    /// Store (or overwrite) one chunk.
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError>;

    /// Fetch a chunk's bytes, `None` when absent.
    fn get(&self, key: ChunkKey) -> Option<Vec<u8>>;

    /// Remove a chunk (idempotent; absent keys are a no-op). A disk
    /// implementation must remove the chunk's on-disk file.
    fn delete(&self, key: ChunkKey);

    /// Is the chunk present? (No payload copy.)
    fn contains(&self, key: ChunkKey) -> bool;

    /// Bytes currently stored.
    fn used_bytes(&self) -> u64;

    /// Chunks currently stored.
    fn chunk_count(&self) -> usize;
}

/// The PR 3 in-memory chunk store: a `RwLock<HashMap>` per node.
/// Readers share the lock; byte copies happen outside every manager
/// lock exactly as before the trait existed.
#[derive(Default)]
pub struct MemoryBackend {
    chunks: RwLock<HashMap<ChunkKey, Vec<u8>>>,
    used: AtomicU64,
}

impl ChunkBackend for MemoryBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        let mut chunks = self.chunks.write().unwrap();
        if let Some(old) = chunks.insert(key, bytes.to_vec()) {
            self.used.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
        self.used.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Option<Vec<u8>> {
        self.chunks.read().unwrap().get(&key).cloned()
    }

    fn delete(&self, key: ChunkKey) {
        if let Some(old) = self.chunks.write().unwrap().remove(&key) {
            self.used.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.chunks.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.chunks.read().unwrap().len()
    }
}

/// File-backed chunk store: one node directory, one file per chunk
/// (`f<file>_c<chunk>.chunk`).
///
/// # Write atomicity
///
/// Writes go to a uniquely named temp file in the same directory and
/// are renamed into place. Rename is atomic on POSIX filesystems, so a
/// concurrent reader sees either the complete chunk or no chunk —
/// never a half-written one. (This is an atomicity guarantee for live
/// readers, not a power-loss durability guarantee: the temp file is
/// not fsynced before the rename, so a crashed *machine* may leave a
/// renamed-but-partial chunk. Harmless today — `FileBackend::new`
/// deliberately ignores pre-existing files; a restart story would need
/// the fsync, see ROADMAP.) Failed writes remove their temp file;
/// `delete` unlinks the chunk file, so a swept node directory is empty
/// on disk, which `scripts/verify.sh`'s stray-file gate checks after
/// the disk-matrix test run.
///
/// An in-memory index (key → length) fronts the directory for
/// `contains`/`used_bytes`/`chunk_count`, so only `get`/`put` pay disk
/// I/O — the penalty the hint-aware cache tier is there to absorb.
pub struct FileBackend {
    dir: PathBuf,
    index: RwLock<HashMap<ChunkKey, u64>>,
    used: AtomicU64,
    tmp_seq: AtomicU64,
}

impl FileBackend {
    /// Open (creating if needed) a backend over `dir`. The directory is
    /// expected to be private to this node: any chunk files already
    /// present are ignored (the live store has no restart story yet —
    /// see ROADMAP).
    pub fn new(dir: &Path) -> Result<Self, StorageError> {
        std::fs::create_dir_all(dir).map_err(|e| {
            StorageError::Invalid(format!("create backend dir {}: {e}", dir.display()))
        })?;
        Ok(FileBackend {
            dir: dir.to_path_buf(),
            index: RwLock::new(HashMap::new()),
            used: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
        })
    }

    fn chunk_path(&self, key: ChunkKey) -> PathBuf {
        self.dir.join(format!("f{}_c{}.chunk", key.0 .0, key.1))
    }
}

impl ChunkBackend for FileBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        let tmp = self.dir.join(format!(
            ".put-{}.tmp",
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        let publish = std::fs::write(&tmp, bytes)
            .and_then(|()| std::fs::rename(&tmp, self.chunk_path(key)));
        if let Err(e) = publish {
            let _ = std::fs::remove_file(&tmp);
            return Err(StorageError::Invalid(format!(
                "spill chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        let mut index = self.index.write().unwrap();
        if let Some(old) = index.insert(key, bytes.len() as u64) {
            self.used.fetch_sub(old, Ordering::Relaxed);
        }
        self.used.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Option<Vec<u8>> {
        // The index check keeps misses off the disk; the hit pays the
        // real read (the penalty a cache hit avoids).
        if !self.contains(key) {
            return None;
        }
        std::fs::read(self.chunk_path(key)).ok()
    }

    fn delete(&self, key: ChunkKey) {
        let removed = self.index.write().unwrap().remove(&key);
        if let Some(old) = removed {
            self.used.fetch_sub(old, Ordering::Relaxed);
            let _ = std::fs::remove_file(self.chunk_path(key));
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.index.read().unwrap().len()
    }
}

/// Count the chunk files (`*.chunk`) anywhere under `dir` — the disk
/// backend's on-disk footprint. The stray-file audits use this: after
/// a store has deleted or reclaimed every file, its `--data-dir` must
/// hold zero chunk files (`scripts/verify.sh` fails the disk test
/// matrix otherwise).
pub fn chunk_files_under(dir: &Path) -> usize {
    let mut count = 0;
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else {
            continue;
        };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "chunk") {
                count += 1;
            }
        }
    }
    count
}

/// Owner of an auto-created `--data-dir`: removes the whole tree on
/// drop. Only directories the store itself created are guarded —
/// a user-supplied `data_dir` is never deleted.
pub(crate) struct DirGuard {
    pub(crate) path: PathBuf,
}

impl Drop for DirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// A process-unique directory for a store that asked for the disk
/// backend without naming a `data_dir`. Rooted at `WOSS_DATA_DIR` when
/// set (the CI matrix points this into a tempdir it can audit for
/// stray files), else the system temp directory.
pub(crate) fn auto_data_dir() -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let base = std::env::var_os("WOSS_DATA_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    base.join(format!(
        "woss-live-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(f: u64, c: u64) -> ChunkKey {
        (FileId(f), c)
    }

    fn temp_backend(tag: &str) -> (PathBuf, FileBackend) {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-test-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let backend = FileBackend::new(&dir).unwrap();
        (dir, backend)
    }

    #[test]
    fn memory_roundtrip_and_accounting() {
        let b = MemoryBackend::default();
        assert!(b.put(key(1, 0), &[7u8; 100]).is_ok());
        assert!(b.put(key(1, 1), &[8u8; 50]).is_ok());
        assert_eq!(b.used_bytes(), 150);
        assert_eq!(b.chunk_count(), 2);
        assert_eq!(b.get(key(1, 0)), Some(vec![7u8; 100]));
        assert!(b.contains(key(1, 1)));
        // Overwrite replaces the accounting, not adds to it.
        assert!(b.put(key(1, 0), &[9u8; 10]).is_ok());
        assert_eq!(b.used_bytes(), 60);
        b.delete(key(1, 0));
        b.delete(key(1, 0)); // idempotent
        assert_eq!(b.used_bytes(), 50);
        assert!(!b.contains(key(1, 0)));
    }

    #[test]
    fn file_backend_roundtrip_and_disk_files() {
        let (dir, b) = temp_backend("roundtrip");
        let payload: Vec<u8> = (0..70_000u32).map(|i| (i % 251) as u8).collect();
        b.put(key(3, 2), &payload).unwrap();
        assert!(dir.join("f3_c2.chunk").exists(), "one file per chunk");
        assert_eq!(b.get(key(3, 2)), Some(payload));
        assert_eq!(b.used_bytes(), 70_000);
        assert_eq!(b.chunk_count(), 1);
        assert!(b.get(key(3, 3)).is_none());

        // Delete removes the on-disk file; the directory holds nothing
        // but chunk files, so it is empty afterwards.
        b.delete(key(3, 2));
        assert!(!dir.join("f3_c2.chunk").exists(), "delete unlinks");
        assert_eq!(b.used_bytes(), 0);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0, "no stray files");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_backend_put_leaves_no_temp_files() {
        let (dir, b) = temp_backend("tmpfiles");
        for c in 0..8u64 {
            b.put(key(1, c), &vec![c as u8; 1000]).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names.len(), 8);
        assert!(
            names.iter().all(|n| n.ends_with(".chunk")),
            "temp files must not survive a completed put: {names:?}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn backend_kind_parse_and_label() {
        assert_eq!("mem".parse::<BackendKind>().unwrap(), BackendKind::Memory);
        assert_eq!("DISK".parse::<BackendKind>().unwrap(), BackendKind::Disk);
        assert!("floppy".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::Memory.label(), "mem");
        assert_eq!(BackendKind::Disk.label(), "disk");
    }
}
