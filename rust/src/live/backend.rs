//! Pluggable per-node chunk storage for the live store.
//!
//! PR 3 left the live store an in-memory toy: every chunk was a
//! `Vec<u8>` in a per-node `HashMap`, so a workload whose intermediate
//! footprint exceeds RAM was simply impossible. This module extracts
//! that storage behind the object-safe [`ChunkBackend`] trait and adds
//! a second implementation:
//!
//! * [`MemoryBackend`] — the PR 3 `HashMap` store, byte for byte. The
//!   default, so existing deployments reproduce exactly.
//! * [`FileBackend`] — a file-backed **disk tier**: one file per chunk
//!   under a per-node directory, written via temp-file + fsync +
//!   rename so a chunk is never observable half-written *and* survives
//!   a machine crash once published. Deleting or reclaiming a chunk
//!   removes its on-disk file.
//!
//! # Crash consistency (the manifest)
//!
//! Each node directory carries an append-only **manifest**
//! (`manifest.log`): one record per publish (`put <file> <chunk> <len>
//! <crc>`) or removal (`del <file> <chunk>`), fsynced before the
//! operation returns. A chunk is *durable* exactly when its manifest
//! record is — the chunk file itself is fsynced before the rename, and
//! the manifest append is the publish point. Recovery
//! ([`FileBackend::open_existing`]) replays the manifest, drops a torn
//! tail (a record cut short by the crash), verifies every surviving
//! `*.chunk` file against its recorded length and checksum, unlinks
//! chunk files the manifest never published (orphans of a crashed
//! `put`), and rebuilds the in-memory index from what checks out. The
//! replayed manifest is rewritten compacted, so `del` records and torn
//! tails do not accumulate across restarts.
//!
//! With the disk backend the hint-aware cache tier
//! ([`crate::live::LiveTuning::cache_bytes`]) becomes a true
//! memory-over-disk hot tier: a cache hit serves without touching the
//! disk, and `Lifetime=scratch` chunks may skip the spill entirely
//! (see [`crate::live::store`] — dirty cache entries write back on
//! eviction, so correctness never depends on the hint being truthful).

use crate::storage::types::{FileId, StorageError};
use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// Key of one stored chunk: the owning file plus the chunk index.
pub type ChunkKey = (FileId, u64);

/// Debug-only lock-scope guard for the pipelined data path.
///
/// The refactored data path promises that **no store lock is ever held
/// across backend I/O** — the property the `Spilling` cache state and
/// the backend's reserve → write → publish split exist to establish.
/// This module makes the promise checkable: the store wraps every
/// cache-node mutex and namespace-stripe acquisition in a [`token`],
/// and every [`FileBackend`] I/O entry point (and the fault decorator's
/// injected latency spikes) calls [`assert_unlocked`]. A violation —
/// disk I/O re-entering under a store lock — panics immediately in
/// debug builds instead of surfacing as a tail-latency mystery. Release
/// builds compile the whole mechanism to nothing.
pub(crate) mod lockscope {
    #[cfg(debug_assertions)]
    thread_local! {
        static STORE_LOCKS_HELD: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
    }

    /// RAII marker: the creating thread holds a store lock until the
    /// token drops. Create it immediately before taking the lock so
    /// the token outlives the guard by a single stack slot.
    pub(crate) struct Token;

    /// Mark the calling thread as holding a store lock.
    pub(crate) fn token() -> Token {
        #[cfg(debug_assertions)]
        STORE_LOCKS_HELD.with(|d| d.set(d.get() + 1));
        Token
    }

    impl Drop for Token {
        fn drop(&mut self) {
            #[cfg(debug_assertions)]
            STORE_LOCKS_HELD.with(|d| d.set(d.get() - 1));
        }
    }

    /// Panic (debug builds) if the calling thread holds a store lock —
    /// called at every backend I/O entry point.
    pub(crate) fn assert_unlocked(_what: &str) {
        #[cfg(debug_assertions)]
        STORE_LOCKS_HELD.with(|d| {
            assert!(
                d.get() == 0,
                "{_what}: backend I/O while a store lock is held \
                 (the pipelined data path forbids this)"
            );
        });
    }
}

/// Which chunk-backend implementation a live deployment runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// In-memory `HashMap` chunk stores (the PR 3 behaviour, default).
    #[default]
    Memory,
    /// File-backed disk tier: one file per chunk under a per-node
    /// directory (temp-file + fsync + rename writes, manifest-logged).
    Disk,
}

impl BackendKind {
    /// Resolve the backend from the `LIVE_BACKEND` environment variable
    /// (`mem` | `disk`, same lenient parser as the CLI's `--backend`),
    /// defaulting to [`BackendKind::Memory`] when unset. This is the CI
    /// matrix hook: `LIVE_BACKEND=disk cargo test` runs every live test
    /// against the spill tier without touching the tests — which is
    /// exactly why an unparseable value panics instead of silently
    /// falling back to memory: a typo'd matrix leg must fail loudly,
    /// not quietly re-run the mem tier.
    pub fn from_env() -> Self {
        match std::env::var("LIVE_BACKEND") {
            Ok(raw) => raw
                .parse()
                .unwrap_or_else(|e| panic!("LIVE_BACKEND: {e}")),
            Err(_) => BackendKind::Memory,
        }
    }

    /// Stable lowercase label (`mem` | `disk`) — the value the reserved
    /// `cache_state` attribute reports in its `tier=` field and the CLI
    /// accepts for `--backend`.
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Memory => "mem",
            BackendKind::Disk => "disk",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mem" | "memory" => Ok(BackendKind::Memory),
            "disk" | "file" => Ok(BackendKind::Disk),
            other => Err(format!("unknown backend '{other}' (mem|disk)")),
        }
    }
}

/// One storage node's authoritative chunk store, behind a trait so the
/// capacity tier is pluggable. Object-safe and `Send + Sync`: the live
/// store shares `Arc<Vec<Box<dyn ChunkBackend>>>` between the data
/// path and the background replication workers.
///
/// Implementations must make a `put` atomic with respect to concurrent
/// `get`s of the same key: a reader observes either the full chunk or
/// nothing, never a prefix ([`FileBackend`] writes a temp file and
/// renames it into place; [`MemoryBackend`] inserts under a write
/// lock).
pub trait ChunkBackend: Send + Sync {
    /// Store (or overwrite) one chunk.
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError>;

    /// Fetch a chunk's bytes. `Ok(None)` means the chunk is *absent* —
    /// never stored here, or already deleted. `Err` means the chunk
    /// should be present but could not be read back intact (I/O error,
    /// torn or corrupted file): the caller must treat the copy as lost
    /// and fail over, not as never having existed — the distinction is
    /// what separates routine remote traffic from a disk fault. Failed
    /// reads are also counted in [`ChunkBackend::read_errors`].
    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError>;

    /// Remove a chunk (idempotent; absent keys are a no-op). A disk
    /// implementation must remove the chunk's on-disk file *before*
    /// releasing any lock that makes the removal visible, so the index
    /// and the directory never disagree.
    fn delete(&self, key: ChunkKey);

    /// Is the chunk present? (No payload copy.)
    fn contains(&self, key: ChunkKey) -> bool;

    /// Bytes currently stored.
    fn used_bytes(&self) -> u64;

    /// Chunks currently stored.
    fn chunk_count(&self) -> usize;

    /// Chunk reads that failed on a present chunk (I/O error or
    /// checksum mismatch) — the corruption signal a hint-blind caller
    /// would otherwise misread as remote-failover traffic. Memory
    /// backends cannot fail this way, hence the zero default.
    fn read_errors(&self) -> u64 {
        0
    }

    /// Every chunk key currently stored, in no particular order. The
    /// churn and audit machinery cross-references these against the
    /// namespace to find stale copies (a rejoining node's leftovers)
    /// and stray chunks no surviving file claims.
    fn chunk_keys(&self) -> Vec<ChunkKey>;
}

/// The PR 3 in-memory chunk store: a `RwLock<HashMap>` per node.
/// Readers share the lock; byte copies happen outside every manager
/// lock exactly as before the trait existed. Chunks are held as
/// `Arc<Vec<u8>>` so a `get` clones only the refcount under the read
/// guard and materializes the caller's copy after releasing it —
/// large-chunk reads no longer extend the lock hold time.
#[derive(Default)]
pub struct MemoryBackend {
    chunks: RwLock<HashMap<ChunkKey, Arc<Vec<u8>>>>,
    used: AtomicU64,
}

impl ChunkBackend for MemoryBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        // The payload copy happens before the write lock, so writers
        // hold it only for the map insert.
        let payload = Arc::new(bytes.to_vec());
        let mut chunks = self.chunks.write().unwrap();
        if let Some(old) = chunks.insert(key, payload) {
            self.used.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
        self.used.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        // Snapshot the Arc under the guard (O(1)); the byte clone runs
        // with the lock released.
        let snapshot = self.chunks.read().unwrap().get(&key).cloned();
        Ok(snapshot.map(|arc| arc.as_ref().clone()))
    }

    fn delete(&self, key: ChunkKey) {
        if let Some(old) = self.chunks.write().unwrap().remove(&key) {
            self.used.fetch_sub(old.len() as u64, Ordering::Relaxed);
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.chunks.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.chunks.read().unwrap().len()
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        self.chunks.read().unwrap().keys().copied().collect()
    }
}

/// 64-bit FNV-1a over a byte slice — the chunk checksum recorded in the
/// manifest and re-verified on recovery and on every read. The same
/// cheap, dependency-free hash the dispatcher's path sharding uses.
pub fn chunk_crc(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Name of the per-node append-only chunk manifest.
const MANIFEST: &str = "manifest.log";

/// What one node's manifest replay recovered and discarded — the
/// per-backend half of [`crate::live::store::RecoveryReport`].
#[derive(Debug, Clone, Default)]
pub struct NodeRecovery {
    /// Chunks whose manifest record and on-disk file both checked out.
    pub chunks_recovered: usize,
    /// Bytes across the recovered chunks.
    pub bytes_recovered: u64,
    /// Manifest tail records dropped as torn (cut mid-write by the
    /// crash) or unparseable.
    pub torn_records: usize,
    /// Published chunks whose file was missing, short, or failed its
    /// checksum — the entry is dropped and any remnant file unlinked.
    pub corrupt_chunks: usize,
    /// `*.chunk` files the manifest never published (a `put` crashed
    /// between rename and manifest fsync) — unlinked.
    pub orphan_files: usize,
}

impl NodeRecovery {
    fn absorb(&mut self, other: &NodeRecovery) {
        self.chunks_recovered += other.chunks_recovered;
        self.bytes_recovered += other.bytes_recovered;
        self.torn_records += other.torn_records;
        self.corrupt_chunks += other.corrupt_chunks;
        self.orphan_files += other.orphan_files;
    }

    /// Merge per-node reports into one (store-level aggregation).
    pub fn merged<'a>(reports: impl IntoIterator<Item = &'a NodeRecovery>) -> NodeRecovery {
        let mut out = NodeRecovery::default();
        for r in reports {
            out.absorb(r);
        }
        out
    }
}

/// One chunk's manifest record: the length and checksum a recovered
/// file must reproduce.
#[derive(Debug, Clone, Copy)]
struct ChunkRecord {
    len: u64,
    crc: u64,
}

/// An append-only record log (the chunk manifest here, the namespace
/// journal in [`crate::live::store`]) with partial-line poisoning
/// contained: an append that dies mid-write (ENOSPC) can flush part of
/// a record without its newline, and the next record must not fuse
/// onto that wreckage — it would be unparseable at replay even though
/// its own write succeeded. The flag confines the damage to the one
/// wrecked line by newline-terminating it before the next record.
pub(crate) struct AppendLog {
    file: std::fs::File,
    dirty_line: bool,
}

impl AppendLog {
    pub(crate) fn new(file: std::fs::File) -> Self {
        AppendLog {
            file,
            dirty_line: false,
        }
    }

    /// Append one newline-terminated record (terminating any earlier
    /// partial line first), optionally fsyncing it. The dirty flag
    /// clears as soon as the line is fully written — a *failed fsync*
    /// leaves a complete, parseable line, not wreckage.
    pub(crate) fn append(&mut self, line: &str, sync: bool) -> std::io::Result<()> {
        if self.dirty_line {
            self.file.write_all(b"\n")?;
            self.dirty_line = false;
        }
        self.dirty_line = true;
        self.file.write_all(line.as_bytes())?;
        self.dirty_line = false;
        if sync {
            self.file.sync_all()?;
        }
        Ok(())
    }

    /// Flush the log to disk.
    pub(crate) fn sync(&self) -> std::io::Result<()> {
        self.file.sync_all()
    }
}

/// File-backed chunk store: one node directory, one file per chunk
/// (`f<file>_c<chunk>.chunk`) plus the append-only `manifest.log`.
///
/// # Write atomicity & durability
///
/// Writes go to a uniquely named temp file in the same directory,
/// **fsynced**, then renamed into place; the manifest record (`put
/// <file> <chunk> <len> <crc>`) is appended and fsynced before `put`
/// returns. Rename is atomic on POSIX filesystems, so a concurrent
/// reader sees either the complete chunk or no chunk — never a
/// half-written one — and a machine crash after `put` returns can lose
/// neither the bytes nor the record of them. A crash *during* `put`
/// leaves either nothing, an unreferenced temp file, or a renamed
/// chunk with no manifest record; [`FileBackend::open_existing`]
/// removes all three.
///
/// # Lock scope (the pipelined data path)
///
/// **No lock is held across disk I/O.** Mutations reserve a per-key
/// in-flight slot (a `put`/`delete` on the same chunk waits its turn,
/// so same-key mutations stay linearizable), run the temp write +
/// fsync + rename with no lock held, record the publish in the
/// manifest under its own short mutex, and only then touch the index —
/// a metadata-only `RwLock` held for map operations alone. `delete`
/// retires the index entry first, appends its `del` record, and
/// unlinks with no lock held: a concurrent `get` that loses its file
/// mid-read re-checks the index and reports the benign race as
/// *absent*, never as a disk fault. Reads snapshot the record under
/// the read lock, read the file outside it, and verify length +
/// checksum against the snapshot; only a chunk that stays indexed and
/// still fails verification (bounded retries, for the benign
/// same-content republish race) counts as a read error.
///
/// The in-memory index (key → length + checksum) fronts the directory
/// for `contains`/`used_bytes`/`chunk_count`, so only `get`/`put` pay
/// disk I/O — the penalty the hint-aware cache tier is there to
/// absorb. Reads re-verify length and checksum: a present-but-damaged
/// chunk surfaces as `Err` (counted in
/// [`ChunkBackend::read_errors`]), never as silently absent.
pub struct FileBackend {
    dir: PathBuf,
    /// Handle on the directory itself, for fsyncing renames into it.
    dir_handle: std::fs::File,
    /// Metadata-only index: key → published length + checksum. Never
    /// held across file I/O.
    index: RwLock<HashMap<ChunkKey, ChunkRecord>>,
    /// The append-only publish log, under its own short mutex (appends
    /// are the only I/O a lock covers — the log is the serialization
    /// point by design, exactly like the namespace journal).
    manifest: Mutex<AppendLog>,
    /// Per-key in-flight table: keys with a mutation (put/delete)
    /// currently between reserve and publish. Same-key mutations queue
    /// here instead of on the index lock, so they serialize without
    /// stalling unrelated keys or any reader.
    inflight: Mutex<HashSet<ChunkKey>>,
    inflight_cv: Condvar,
    used: AtomicU64,
    tmp_seq: AtomicU64,
    read_failures: AtomicU64,
}

/// Exclusive per-key mutation slot: dropped, it releases the key and
/// wakes the next queued mutation.
struct KeySlot<'a> {
    backend: &'a FileBackend,
    key: ChunkKey,
}

impl Drop for KeySlot<'_> {
    fn drop(&mut self) {
        self.backend.inflight.lock().unwrap().remove(&self.key);
        self.backend.inflight_cv.notify_all();
    }
}

impl FileBackend {
    /// Open a **fresh** backend over `dir`, creating the directory and
    /// an empty manifest. Refuses a directory that already carries a
    /// manifest: silently ignoring a previous store's chunks is
    /// exactly the data-loss bug recovery exists to fix — re-open such
    /// a directory with [`FileBackend::open_existing`] instead.
    pub fn new(dir: &Path) -> Result<Self, StorageError> {
        std::fs::create_dir_all(dir).map_err(|e| {
            StorageError::Invalid(format!("create backend dir {}: {e}", dir.display()))
        })?;
        if dir.join(MANIFEST).exists() {
            return Err(StorageError::Invalid(format!(
                "backend dir {} holds a previous store's manifest; open_existing it \
                 instead of silently discarding its chunks",
                dir.display()
            )));
        }
        let manifest = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join(MANIFEST))
            .map_err(|e| StorageError::Invalid(format!("create manifest: {e}")))?;
        let dir_handle = std::fs::File::open(dir)
            .map_err(|e| StorageError::Invalid(format!("open backend dir: {e}")))?;
        let _ = dir_handle.sync_all();
        Ok(FileBackend {
            dir: dir.to_path_buf(),
            dir_handle,
            index: RwLock::new(HashMap::new()),
            manifest: Mutex::new(AppendLog::new(manifest)),
            inflight: Mutex::new(HashSet::new()),
            inflight_cv: Condvar::new(),
            used: AtomicU64::new(0),
            tmp_seq: AtomicU64::new(0),
            read_failures: AtomicU64::new(0),
        })
    }

    /// Re-open a backend directory left by a previous store: replay the
    /// manifest, verify survivors, discard what the crash tore, and
    /// rebuild the index.
    ///
    /// * The manifest is replayed record by record; an unparseable
    ///   line — the unterminated tail a crash tore, or a terminated
    ///   line a failed append damaged — is skipped (counted in
    ///   [`NodeRecovery::torn_records`]) without poisoning the records
    ///   around it, which every verified chunk below re-validates
    ///   anyway.
    /// * Every chunk the replay says should exist is verified against
    ///   its recorded length and checksum; a missing, short, or
    ///   corrupt file drops the entry (and unlinks any remnant).
    /// * `*.chunk` files the surviving records never published — a
    ///   `put` that renamed but crashed before its manifest fsync —
    ///   are unlinked, as are stale `.put-*.tmp` files.
    /// * The manifest is rewritten compacted (surviving `put` records
    ///   only) so torn tails and `del` churn reset at every open.
    pub fn open_existing(dir: &Path) -> Result<(Self, NodeRecovery), StorageError> {
        if !dir.is_dir() {
            return Err(StorageError::Invalid(format!(
                "backend dir {} does not exist",
                dir.display()
            )));
        }
        let mut recovery = NodeRecovery::default();
        let mut replayed: HashMap<ChunkKey, ChunkRecord> = HashMap::new();
        // A manifest that does not exist is a node that crashed before
        // its first publish became durable — legitimately empty. Any
        // other read failure must abort the recovery: replaying
        // "nothing" over a directory full of published chunks would
        // unlink every one of them as an orphan (the exact
        // absent-vs-read-failed confusion `get` refuses to make).
        let raw = match std::fs::read(dir.join(MANIFEST)) {
            Ok(raw) => raw,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => {
                return Err(StorageError::Invalid(format!(
                    "read manifest in {}: {e}",
                    dir.display()
                )));
            }
        };
        let text = String::from_utf8_lossy(&raw);
        for line in text.split_inclusive('\n') {
            // A record is only durable with its terminating newline; a
            // tail without one was torn mid-append. A *terminated* but
            // unparseable line is a record a failed append damaged (the
            // next append newline-terminates the wreckage so its own
            // record survives on a clean line). Either way the damage is
            // that one record: skip it and keep replaying — every
            // surviving entry is independently verified against its
            // chunk file below, so a skipped `put` at worst orphans one
            // file (swept) and a skipped `del` at worst leaves an entry
            // whose file is already gone (dropped by verification).
            let torn_tail = !line.ends_with('\n');
            match parse_manifest_line(line.trim_end_matches('\n')) {
                Some(ManifestOp::Put { key, rec }) if !torn_tail => {
                    replayed.insert(key, rec);
                }
                Some(ManifestOp::Del { key }) if !torn_tail => {
                    replayed.remove(&key);
                }
                _ => recovery.torn_records += 1,
            }
        }

        // Verify survivors against the directory.
        let mut kept: HashMap<ChunkKey, ChunkRecord> = HashMap::new();
        let mut used = 0u64;
        for (key, rec) in replayed {
            let path = chunk_path_in(dir, key);
            let ok = match std::fs::read(&path) {
                Ok(bytes) => bytes.len() as u64 == rec.len && chunk_crc(&bytes) == rec.crc,
                Err(_) => false,
            };
            if ok {
                used += rec.len;
                kept.insert(key, rec);
            } else {
                recovery.corrupt_chunks += 1;
                let _ = std::fs::remove_file(&path);
            }
        }
        recovery.chunks_recovered = kept.len();
        recovery.bytes_recovered = used;

        // Unpublished chunk files (and stale temp files) are orphans of
        // crashed puts: unlink them so nothing resurrects.
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                let orphan_chunk = name.ends_with(".chunk")
                    && match parse_chunk_name(&name) {
                        Some(key) => !kept.contains_key(&key),
                        None => true,
                    };
                let stale_tmp = (name.starts_with(".put-") && name.ends_with(".tmp"))
                    || name == ".manifest.tmp";
                if orphan_chunk {
                    recovery.orphan_files += 1;
                    let _ = std::fs::remove_file(entry.path());
                } else if stale_tmp {
                    // Crashed put temp, or a compaction that died
                    // between writing .manifest.tmp and renaming it —
                    // either way the rewrite below supersedes it.
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }

        // Rewrite the manifest compacted: the survivors are the whole
        // truth now, and the torn tail must not be replayed twice.
        let tmp = dir.join(".manifest.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| StorageError::Invalid(format!("compact manifest: {e}")))?;
            for (key, rec) in &kept {
                writeln!(f, "put {} {} {} {:016x}", key.0 .0, key.1, rec.len, rec.crc)
                    .map_err(|e| StorageError::Invalid(format!("compact manifest: {e}")))?;
            }
            f.sync_all()
                .map_err(|e| StorageError::Invalid(format!("sync manifest: {e}")))?;
        }
        std::fs::rename(&tmp, dir.join(MANIFEST))
            .map_err(|e| StorageError::Invalid(format!("publish manifest: {e}")))?;
        let dir_handle = std::fs::File::open(dir)
            .map_err(|e| StorageError::Invalid(format!("open backend dir: {e}")))?;
        let _ = dir_handle.sync_all();
        let manifest = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(MANIFEST))
            .map_err(|e| StorageError::Invalid(format!("reopen manifest: {e}")))?;
        Ok((
            FileBackend {
                dir: dir.to_path_buf(),
                dir_handle,
                index: RwLock::new(kept),
                manifest: Mutex::new(AppendLog::new(manifest)),
                inflight: Mutex::new(HashSet::new()),
                inflight_cv: Condvar::new(),
                used: AtomicU64::new(used),
                tmp_seq: AtomicU64::new(0),
                read_failures: AtomicU64::new(0),
            },
            recovery,
        ))
    }

    fn chunk_path(&self, key: ChunkKey) -> PathBuf {
        chunk_path_in(&self.dir, key)
    }

    /// Reserve the exclusive mutation slot for `key`, waiting out any
    /// in-flight put/delete of the same chunk. This is what keeps
    /// same-key mutations linearizable now that their disk I/O runs
    /// outside the index lock.
    fn lock_key(&self, key: ChunkKey) -> KeySlot<'_> {
        let mut inflight = self.inflight.lock().unwrap();
        while inflight.contains(&key) {
            inflight = self.inflight_cv.wait(inflight).unwrap();
        }
        inflight.insert(key);
        KeySlot { backend: self, key }
    }

    /// Chunk keys currently indexed (recovery bookkeeping: the store
    /// cross-references these against the recovered namespace to find
    /// chunks no surviving file claims).
    pub fn chunk_keys(&self) -> Vec<ChunkKey> {
        self.index.read().unwrap().keys().copied().collect()
    }
}

/// One parsed manifest record.
enum ManifestOp {
    Put { key: ChunkKey, rec: ChunkRecord },
    Del { key: ChunkKey },
}

fn parse_manifest_line(line: &str) -> Option<ManifestOp> {
    let mut parts = line.split(' ');
    let op = parts.next()?;
    let file = FileId(parts.next()?.parse().ok()?);
    let chunk: u64 = parts.next()?.parse().ok()?;
    match op {
        "put" => {
            let len: u64 = parts.next()?.parse().ok()?;
            let crc = u64::from_str_radix(parts.next()?, 16).ok()?;
            if parts.next().is_some() {
                return None;
            }
            Some(ManifestOp::Put {
                key: (file, chunk),
                rec: ChunkRecord { len, crc },
            })
        }
        "del" => {
            if parts.next().is_some() {
                return None;
            }
            Some(ManifestOp::Del { key: (file, chunk) })
        }
        _ => None,
    }
}

fn chunk_path_in(dir: &Path, key: ChunkKey) -> PathBuf {
    dir.join(format!("f{}_c{}.chunk", key.0 .0, key.1))
}

/// Parse `f<file>_c<chunk>.chunk` back into its key.
fn parse_chunk_name(name: &str) -> Option<ChunkKey> {
    let body = name.strip_suffix(".chunk")?.strip_prefix('f')?;
    let (file, chunk) = body.split_once("_c")?;
    Some((FileId(file.parse().ok()?), chunk.parse().ok()?))
}

impl ChunkBackend for FileBackend {
    fn put(&self, key: ChunkKey, bytes: &[u8]) -> Result<(), StorageError> {
        lockscope::assert_unlocked("FileBackend::put");
        // Reserve: the per-key slot serializes same-key mutations, so
        // everything below runs without the index lock and still
        // linearizes against a racing put/delete of this chunk.
        let _slot = self.lock_key(key);
        let tmp = self.dir.join(format!(
            ".put-{}.tmp",
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        // Byte landing is lock-free: write + fsync the temp file so the
        // rename below publishes fully-durable content.
        let written = std::fs::File::create(&tmp)
            .and_then(|mut f| f.write_all(bytes).and_then(|()| f.sync_all()));
        if let Err(e) = written {
            let _ = std::fs::remove_file(&tmp);
            return Err(StorageError::Invalid(format!(
                "spill chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        let rec = ChunkRecord {
            len: bytes.len() as u64,
            crc: chunk_crc(bytes),
        };
        // Rename + directory fsync + manifest fsync, all outside the
        // index lock. Until the index insert below, a concurrent `get`
        // of a fresh key reports absent (the put has not linearized
        // yet) and a `get` racing an overwrite re-verifies against the
        // old record — same-content republishes (the only overwrites
        // the store issues) still verify.
        if let Err(e) = std::fs::rename(&tmp, self.chunk_path(key)) {
            // Nothing was replaced: a previously published copy (and
            // its index entry) is still intact, only the temp goes.
            let _ = std::fs::remove_file(&tmp);
            return Err(StorageError::Invalid(format!(
                "publish chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        let line = format!("put {} {} {} {:016x}\n", key.0 .0, key.1, rec.len, rec.crc);
        let logged = self.dir_handle.sync_all().and_then(|()| {
            // The manifest mutex covers only the append — the one
            // serialization point the log needs.
            self.manifest.lock().unwrap().append(&line, true)
        });
        if let Err(e) = logged {
            // The rename already replaced the on-disk bytes with
            // content the manifest never published — and, on an
            // overwrite, destroyed the copy the old index entry
            // described. Make the failure consistent: the chunk is
            // gone. Leaving the old entry in place would advertise a
            // chunk whose bytes no longer match (every read a spurious
            // checksum failure); leaving the file would strand an
            // unindexed .chunk until the next recovery sweep.
            if let Some(old) = self.index.write().unwrap().remove(&key) {
                self.used.fetch_sub(old.len, Ordering::Relaxed);
            }
            let _ = std::fs::remove_file(self.chunk_path(key));
            return Err(StorageError::Invalid(format!(
                "publish chunk {}#{} to {}: {e}",
                key.0 .0,
                key.1,
                self.dir.display()
            )));
        }
        // Publish: the metadata-only index insert is the linearization
        // point.
        if let Some(old) = self.index.write().unwrap().insert(key, rec) {
            self.used.fetch_sub(old.len, Ordering::Relaxed);
        }
        self.used.fetch_add(rec.len, Ordering::Relaxed);
        Ok(())
    }

    fn get(&self, key: ChunkKey) -> Result<Option<Vec<u8>>, StorageError> {
        lockscope::assert_unlocked("FileBackend::get");
        // Snapshot the record under the read lock, read the file with
        // no lock held, verify against the snapshot. A failed
        // verification re-checks the index: entry gone → the benign
        // delete race (absent, not a fault); entry present → retry a
        // bounded number of times (a same-content republish between
        // rename and index insert verifies against either record; the
        // retries cover the theoretical different-content overwrite)
        // before reporting a genuine disk fault.
        const ATTEMPTS: usize = 3;
        let mut failed = String::new();
        for attempt in 0..ATTEMPTS {
            let rec = match self.index.read().unwrap().get(&key) {
                Some(rec) => *rec,
                None => return Ok(None),
            };
            match std::fs::read(self.chunk_path(key)) {
                Ok(bytes) if bytes.len() as u64 == rec.len && chunk_crc(&bytes) == rec.crc => {
                    return Ok(Some(bytes));
                }
                Ok(_) => failed = "length/checksum mismatch".to_string(),
                Err(e) => {
                    if e.kind() == std::io::ErrorKind::NotFound
                        && !self.index.read().unwrap().contains_key(&key)
                    {
                        // The file vanished because a concurrent delete
                        // retired the chunk between our snapshot and
                        // the read: absent, exactly as if we had
                        // arrived a moment later.
                        return Ok(None);
                    }
                    failed = e.to_string();
                }
            }
            if attempt + 1 < ATTEMPTS {
                std::thread::yield_now();
            }
        }
        self.read_failures.fetch_add(1, Ordering::Relaxed);
        Err(StorageError::Invalid(format!(
            "chunk {}#{} unreadable in {}: {failed}",
            key.0 .0,
            key.1,
            self.dir.display()
        )))
    }

    fn delete(&self, key: ChunkKey) {
        lockscope::assert_unlocked("FileBackend::delete");
        // The slot serializes against a racing put of the same key (a
        // fresh chunk cannot be renamed into place mid-delete and get
        // unlinked while the index says present). Retire the index
        // entry first, then log, then unlink — a reader that loses the
        // file mid-read finds the entry gone and reports absent.
        let _slot = self.lock_key(key);
        let removed = self.index.write().unwrap().remove(&key);
        if let Some(old) = removed {
            self.used.fetch_sub(old.len, Ordering::Relaxed);
            let _ = self
                .manifest
                .lock()
                .unwrap()
                .append(&format!("del {} {}\n", key.0 .0, key.1), true);
            let _ = std::fs::remove_file(self.chunk_path(key));
        }
    }

    fn contains(&self, key: ChunkKey) -> bool {
        self.index.read().unwrap().contains_key(&key)
    }

    fn used_bytes(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    fn chunk_count(&self) -> usize {
        self.index.read().unwrap().len()
    }

    fn read_errors(&self) -> u64 {
        self.read_failures.load(Ordering::Relaxed)
    }

    fn chunk_keys(&self) -> Vec<ChunkKey> {
        FileBackend::chunk_keys(self)
    }
}

/// Count the chunk files (`*.chunk`) anywhere under `dir` — the disk
/// backend's on-disk footprint. The stray-file audits use this: after
/// a store has deleted or reclaimed every file, its `--data-dir` must
/// hold zero chunk files (`scripts/verify.sh` fails the disk test
/// matrix otherwise). Symbolic links are never followed — a cycle
/// inside a data dir must not hang the audit — so only real
/// directories are descended into.
pub fn chunk_files_under(dir: &Path) -> usize {
    let mut count = 0;
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else {
            continue;
        };
        for entry in entries.flatten() {
            // `Path::is_dir()` follows symlinks; `entry.file_type()`
            // reports the link itself, which is what keeps a symlink
            // cycle from turning this walk into an infinite loop.
            let Ok(ftype) = entry.file_type() else {
                continue;
            };
            if ftype.is_dir() {
                stack.push(entry.path());
            } else if ftype.is_file() && entry.path().extension().is_some_and(|e| e == "chunk") {
                count += 1;
            }
        }
    }
    count
}

/// Owner of an auto-created `--data-dir`: removes the whole tree on
/// drop. Only directories the store itself created are guarded —
/// a user-supplied `data_dir` is never deleted.
pub(crate) struct DirGuard {
    pub(crate) path: PathBuf,
}

impl Drop for DirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// A process-unique directory for a store that asked for the disk
/// backend without naming a `data_dir`. Rooted at `WOSS_DATA_DIR` when
/// set (the CI matrix points this into a tempdir it can audit for
/// stray files), else the system temp directory.
pub(crate) fn auto_data_dir() -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let base = std::env::var_os("WOSS_DATA_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir);
    base.join(format!(
        "woss-live-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn key(f: u64, c: u64) -> ChunkKey {
        (FileId(f), c)
    }

    fn temp_backend(tag: &str) -> (PathBuf, FileBackend) {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-test-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let backend = FileBackend::new(&dir).unwrap();
        (dir, backend)
    }

    #[test]
    fn memory_roundtrip_and_accounting() {
        let b = MemoryBackend::default();
        assert!(b.put(key(1, 0), &[7u8; 100]).is_ok());
        assert!(b.put(key(1, 1), &[8u8; 50]).is_ok());
        assert_eq!(b.used_bytes(), 150);
        assert_eq!(b.chunk_count(), 2);
        assert_eq!(b.get(key(1, 0)).unwrap(), Some(vec![7u8; 100]));
        assert!(b.contains(key(1, 1)));
        // Overwrite replaces the accounting, not adds to it.
        assert!(b.put(key(1, 0), &[9u8; 10]).is_ok());
        assert_eq!(b.used_bytes(), 60);
        b.delete(key(1, 0));
        b.delete(key(1, 0)); // idempotent
        assert_eq!(b.used_bytes(), 50);
        assert!(!b.contains(key(1, 0)));
        assert_eq!(b.read_errors(), 0);
    }

    #[test]
    fn file_backend_roundtrip_and_disk_files() {
        let (dir, b) = temp_backend("roundtrip");
        let payload: Vec<u8> = (0..70_000u32).map(|i| (i % 251) as u8).collect();
        b.put(key(3, 2), &payload).unwrap();
        assert!(dir.join("f3_c2.chunk").exists(), "one file per chunk");
        assert_eq!(b.get(key(3, 2)).unwrap(), Some(payload));
        assert_eq!(b.used_bytes(), 70_000);
        assert_eq!(b.chunk_count(), 1);
        assert!(b.get(key(3, 3)).unwrap().is_none());

        // Delete removes the on-disk file; only the manifest remains in
        // the directory afterwards.
        b.delete(key(3, 2));
        assert!(!dir.join("f3_c2.chunk").exists(), "delete unlinks");
        assert_eq!(b.used_bytes(), 0);
        assert_eq!(chunk_files_under(&dir), 0, "no stray chunk files");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_backend_put_leaves_no_temp_files() {
        let (dir, b) = temp_backend("tmpfiles");
        for c in 0..8u64 {
            b.put(key(1, c), &vec![c as u8; 1000]).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names.len(), 9, "8 chunks + the manifest");
        assert!(
            names
                .iter()
                .all(|n| n.ends_with(".chunk") || n == MANIFEST),
            "temp files must not survive a completed put: {names:?}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fresh_open_refuses_previous_store_dir() {
        let (dir, b) = temp_backend("refuse");
        b.put(key(1, 0), &[1u8; 100]).unwrap();
        drop(b);
        assert!(
            FileBackend::new(&dir).is_err(),
            "a dir with a manifest must be open_existing'd, not blanked"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recovery_roundtrips_published_chunks() {
        let (dir, b) = temp_backend("recover");
        let p0: Vec<u8> = (0..50_000u32).map(|i| (i % 13) as u8).collect();
        let p1: Vec<u8> = (0..70_000u32).map(|i| (i % 17) as u8).collect();
        b.put(key(1, 0), &p0).unwrap();
        b.put(key(1, 1), &p1).unwrap();
        b.put(key(2, 0), &p0).unwrap();
        b.delete(key(2, 0));
        drop(b); // crash: no clean shutdown exists at this layer
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 2);
        assert_eq!(rec.bytes_recovered, 120_000);
        assert_eq!(rec.torn_records, 0);
        assert_eq!(rec.corrupt_chunks, 0);
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(p0));
        assert_eq!(b2.get(key(1, 1)).unwrap(), Some(p1));
        assert!(!b2.contains(key(2, 0)), "deleted chunk stays deleted");
        assert_eq!(b2.used_bytes(), 120_000);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_manifest_tail_is_discarded_valid_prefix_kept() {
        let (dir, b) = temp_backend("torn");
        b.put(key(1, 0), &[1u8; 1000]).unwrap();
        b.put(key(1, 1), &[2u8; 1000]).unwrap();
        drop(b);
        // Simulate a crash mid-append: a record without its newline.
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(MANIFEST))
            .unwrap();
        f.write_all(b"put 1 2 10").unwrap();
        drop(f);
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 2, "valid prefix survives");
        assert_eq!(rec.torn_records, 1, "torn tail dropped");
        assert!(!b2.contains(key(1, 2)));
        // The compacted manifest replays clean a second time.
        drop(b2);
        let (_b3, rec3) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec3.torn_records, 0, "compaction erased the torn tail");
        assert_eq!(rec3.chunks_recovered, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn garbled_manifest_record_skipped_later_records_survive() {
        let (dir, b) = temp_backend("garbled");
        b.put(key(1, 0), &[1u8; 500]).unwrap();
        drop(b);
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(MANIFEST))
            .unwrap();
        // A terminated-but-garbled line (a damaged record) followed by
        // a well-formed record whose chunk file never existed. The
        // damage must stay confined to the garbled line — the later
        // record replays and then falls to chunk verification.
        f.write_all(b"zzz not a record\nput 9 9 5 0000000000000000\n")
            .unwrap();
        f.sync_all().unwrap();
        drop(f);
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.chunks_recovered, 1);
        assert_eq!(rec.torn_records, 1, "only the garbled line is dropped");
        assert_eq!(rec.corrupt_chunks, 1, "the replayed record had no file");
        assert!(!b2.contains(key(9, 9)));
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(vec![1u8; 500]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn orphan_chunk_files_are_salvage_cleaned() {
        let (dir, b) = temp_backend("orphan");
        b.put(key(1, 0), &[3u8; 400]).unwrap();
        drop(b);
        // A put that renamed but crashed before its manifest fsync, and
        // a stale temp file.
        std::fs::write(dir.join("f8_c0.chunk"), [9u8; 100]).unwrap();
        std::fs::write(dir.join(".put-77.tmp"), [9u8; 100]).unwrap();
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.orphan_files, 1);
        assert_eq!(rec.chunks_recovered, 1);
        assert!(!dir.join("f8_c0.chunk").exists(), "orphan unlinked");
        assert!(!dir.join(".put-77.tmp").exists(), "temp swept");
        assert!(!b2.contains(key(8, 0)));
        assert_eq!(b2.get(key(1, 0)).unwrap(), Some(vec![3u8; 400]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_chunk_file_dropped_at_recovery() {
        let (dir, b) = temp_backend("corrupt");
        b.put(key(1, 0), &[4u8; 600]).unwrap();
        b.put(key(1, 1), &[5u8; 600]).unwrap();
        drop(b);
        // Same length, different bytes: only the checksum catches it.
        std::fs::write(dir.join("f1_c0.chunk"), [0u8; 600]).unwrap();
        // Truncated: the length check catches it.
        std::fs::write(dir.join("f1_c1.chunk"), [5u8; 10]).unwrap();
        let (b2, rec) = FileBackend::open_existing(&dir).unwrap();
        assert_eq!(rec.corrupt_chunks, 2);
        assert_eq!(rec.chunks_recovered, 0);
        assert!(!b2.contains(key(1, 0)));
        assert!(!dir.join("f1_c0.chunk").exists(), "damaged file removed");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn get_distinguishes_absent_from_read_failure() {
        let (dir, b) = temp_backend("readfail");
        b.put(key(1, 0), &[6u8; 800]).unwrap();
        // Absent is a clean miss, not an error.
        assert_eq!(b.get(key(1, 9)).unwrap(), None);
        assert_eq!(b.read_errors(), 0);
        // Corrupt the file behind the index's back: the read must
        // surface a failure, not report the chunk absent.
        std::fs::write(dir.join("f1_c0.chunk"), [0u8; 800]).unwrap();
        assert!(b.get(key(1, 0)).is_err(), "corruption is an error");
        std::fs::remove_file(dir.join("f1_c0.chunk")).unwrap();
        assert!(b.get(key(1, 0)).is_err(), "vanished-but-indexed is an error");
        assert_eq!(b.read_errors(), 2);
        assert!(b.contains(key(1, 0)), "index still claims it — that is the point");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn put_delete_race_never_leaves_index_and_disk_disagreeing() {
        // The regression this guards: delete removed the index entry
        // under the lock but unlinked after dropping it, so a racing
        // put could rename a fresh chunk into place and have it
        // unlinked while the index said present (contains() true,
        // get() None). With rename/unlink serialized under the lock,
        // an indexed chunk always has its file.
        let (dir, b) = temp_backend("race");
        let b = Arc::new(b);
        let payload = vec![7u8; 4096];
        std::thread::scope(|scope| {
            let putter = Arc::clone(&b);
            let p = payload.clone();
            scope.spawn(move || {
                for _ in 0..300 {
                    putter.put(key(1, 0), &p).unwrap();
                }
            });
            let deleter = Arc::clone(&b);
            scope.spawn(move || {
                for _ in 0..300 {
                    deleter.delete(key(1, 0));
                }
            });
            let checker = Arc::clone(&b);
            let p = payload.clone();
            scope.spawn(move || {
                for _ in 0..300 {
                    // Present implies readable with the right bytes;
                    // absent is fine. Never "present but unreadable".
                    match checker.get(key(1, 0)) {
                        Ok(Some(bytes)) => assert_eq!(bytes, p),
                        Ok(None) => {}
                        Err(e) => panic!("indexed chunk unreadable mid-race: {e}"),
                    }
                }
            });
        });
        // Settle into a known state and re-check the invariant cold.
        b.put(key(1, 0), &payload).unwrap();
        assert!(b.contains(key(1, 0)));
        assert_eq!(b.get(key(1, 0)).unwrap(), Some(payload));
        assert_eq!(b.read_errors(), 0, "the race must not manufacture disk faults");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn chunk_files_under_survives_symlink_cycle() {
        let dir = std::env::temp_dir().join(format!(
            "woss-backend-test-{}-symlink",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("sub/f1_c0.chunk"), [1u8; 10]).unwrap();
        // A cycle: sub/loop → the data dir itself. Following it would
        // recurse forever; the audit must skip it and still count the
        // real chunk file.
        std::os::unix::fs::symlink(&dir, dir.join("sub/loop")).unwrap();
        assert_eq!(chunk_files_under(&dir), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn backend_kind_parse_and_label() {
        assert_eq!("mem".parse::<BackendKind>().unwrap(), BackendKind::Memory);
        assert_eq!("DISK".parse::<BackendKind>().unwrap(), BackendKind::Disk);
        assert!("floppy".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::Memory.label(), "mem");
        assert_eq!(BackendKind::Disk.label(), "disk");
    }
}
